"""Shared benchmark machinery: profile caching + table formatting."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.predictor import PPTMulticorePredictor
from repro.core.reuse.distance import reuse_distances
from repro.core.reuse.profile import profile_from_distances
from repro.core.trace.interleave import interleave_traces
from repro.core.trace.mimic import gen_private_traces

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "results"


class ProfileCache:
    """Reuse profiles are a function of (workload, cores, strategy, line)
    only — identical across the three CPU targets (64 B lines), so the
    expensive Fenwick pass runs once per key.  This is the paper's own
    amortization argument (collect once, predict everything)."""

    def __init__(self):
        self.traces: dict[str, object] = {}
        self.profiles: dict[tuple, tuple] = {}
        self.mimicked: dict[tuple, tuple] = {}

    def trace(self, workload):
        if workload.abbr not in self.traces:
            self.traces[workload.abbr] = workload.trace()
        return self.traces[workload.abbr]

    def traces_for(self, workload, cores: int, strategy: str, seed: int = 0):
        key = (workload.abbr, cores, strategy, seed)
        if key not in self.mimicked:
            tr = self.trace(workload)
            if cores == 1:
                self.mimicked[key] = ([tr], tr)
            else:
                privs = gen_private_traces(tr, cores)
                shared = interleave_traces(privs, strategy, seed=seed)
                self.mimicked[key] = (privs, shared)
        return self.mimicked[key]

    def profiles_for(self, workload, cores: int, strategy: str,
                     line: int = 64, seed: int = 0):
        key = (workload.abbr, cores, strategy, line, seed)
        if key not in self.profiles:
            privs, shared = self.traces_for(workload, cores, strategy, seed)
            prd = profile_from_distances(
                reuse_distances(privs[0].addresses, line))
            crd = (prd if cores == 1 else profile_from_distances(
                reuse_distances(shared.addresses, line)))
            self.profiles[key] = (prd, crd)
        return self.profiles[key]


def hit_rates_from_profiles(target, prd, crd):
    """SDCM per level using the cached profiles (predictor logic,
    minus the re-tracing)."""
    from repro.core import sdcm

    shared_idx = target.shared_level % len(target.levels)
    rates = {}
    for i, lvl in enumerate(target.levels):
        prof = crd if i >= shared_idx else prd
        rates[lvl.name] = sdcm.hit_rate(prof, lvl.effective_assoc,
                                        lvl.num_lines)
    return rates


def save_json(name: str, payload) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=float))
    return path


def fmt_table(headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows),
                                   default=0))
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
