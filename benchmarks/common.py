"""Shared benchmark machinery.

Profile amortization is no longer benchmark-local: the old
``ProfileCache`` is superseded by ``repro.api.Session``, whose
content-hash artifact caches implement the same collect-once /
predict-everything discipline for ALL callers.  Benchmarks construct
one Session (batched SDCM backend) and issue declarative requests.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.api import AnalyticalSDCM, Session

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "results"
REPO_ROOT = Path(__file__).resolve().parents[1]


def make_session(batched: bool = True) -> Session:
    """The benchmark Session: batched JAX SDCM over the whole grid."""
    backend = "batched" if batched else "numpy"
    return Session(cache_model=AnalyticalSDCM(backend=backend))


def save_json(name: str, payload) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=float))
    return path


def fmt_table(headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows),
                                   default=0))
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
