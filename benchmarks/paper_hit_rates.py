"""Figs. 5–6 analog: SDCM-predicted cache hit rates vs exact LRU
simulation (the PAPI stand-in), per CPU target x core count x level.

Paper's claim: 1.23% overall average error (with known weak spots:
gramschmidt & symm L2).  This benchmark reproduces the comparison and
reports the same aggregate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    ProfileCache, fmt_table, hit_rates_from_profiles, save_json,
)
from repro.core.cachesim import simulate_hierarchy
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import all_workloads

QUICK_SUBSET = ["atx", "bcg", "mvt", "jcb", "grm", "blk"]
QUICK_CORES = [1, 4]
FULL_CORES = [1, 2, 4, 8, 16]


def exact_hit_rates(target, privs, shared):
    shared_idx = target.shared_level % len(target.levels)
    out = {}
    if len(privs) == 1:
        res = simulate_hierarchy(privs[0].addresses, list(target.levels))
        return {r.name: r.cumulative_hit_rate for r in res}
    res_priv = simulate_hierarchy(
        privs[0].addresses, list(target.levels[:shared_idx]))
    for r in res_priv:
        out[r.name] = r.cumulative_hit_rate
    res_shared = simulate_hierarchy(shared.addresses, list(target.levels))
    for r, lvl in zip(res_shared, target.levels):
        out.setdefault(lvl.name, r.cumulative_hit_rate)
    return out


def run(quick: bool = True, strategy: str = "round_robin") -> dict:
    workloads = all_workloads(QUICK_SUBSET if quick else None)
    cores_list = QUICK_CORES if quick else FULL_CORES
    cache = ProfileCache()
    rows, records = [], []
    errors = []
    per_level_err: dict[str, list] = {}

    for target in CPU_TARGETS.values():
        for w in workloads:
            for cores in cores_list:
                if cores > target.cores:
                    continue
                prd, crd = cache.profiles_for(w, cores, strategy,
                                              target.levels[0].line_size)
                pred = hit_rates_from_profiles(target, prd, crd)
                privs, shared = cache.traces_for(w, cores, strategy)
                exact = exact_hit_rates(target, privs, shared)
                for lvl in pred:
                    err = abs(pred[lvl] - exact[lvl]) * 100
                    errors.append(err)
                    per_level_err.setdefault(lvl, []).append(err)
                    records.append({
                        "target": target.name, "workload": w.abbr,
                        "cores": cores, "level": lvl,
                        "predicted": pred[lvl], "exact": exact[lvl],
                        "abs_err_pct": err,
                    })
                rows.append([
                    target.name, w.abbr, cores,
                    *(f"{pred[l]:.4f}/{exact[l]:.4f}" for l in pred),
                ])

    overall = float(np.mean(errors))
    headers = ["target", "app", "cores"] + [
        f"{l} pred/exact" for l in per_level_err
    ]
    table = fmt_table(headers, rows)
    summary = {
        "overall_avg_abs_err_pct": overall,
        "per_level_avg_err_pct": {
            k: float(np.mean(v)) for k, v in per_level_err.items()
        },
        "paper_claim_pct": 1.23,
        "strategy": strategy,
        "records": records,
    }
    save_json("paper_hit_rates" + ("_quick" if quick else ""), summary)
    print(table)
    print(f"\noverall avg |err|: {overall:.2f}%  "
          f"(paper's PAPI-vs-SDCM claim: 1.23%)")
    for k, v in summary["per_level_avg_err_pct"].items():
        print(f"  {k}: {v:.2f}%")
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
