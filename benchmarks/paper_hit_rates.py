"""Figs. 5–6 analog: SDCM-predicted cache hit rates vs exact LRU
simulation (the PAPI stand-in), per CPU target x core count x level.

Paper's claim: 1.23% overall average error (with known weak spots:
gramschmidt & symm L2).  This benchmark reproduces the comparison
through `repro.api`: one Session, one declarative request per
workload, the analytical grid evaluated by the batched SDCM kernel and
the ground truth by the ExactLRU stage over the SAME cached artifacts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, make_session, save_json
from repro.api import PredictionRequest
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import all_workloads

QUICK_SUBSET = ["atx", "bcg", "mvt", "jcb", "grm", "blk"]
QUICK_CORES = [1, 4]
FULL_CORES = [1, 2, 4, 8, 16]


def run(quick: bool = True, strategy: str = "round_robin") -> dict:
    workloads = all_workloads(QUICK_SUBSET if quick else None)
    cores_list = QUICK_CORES if quick else FULL_CORES
    session = make_session()
    rows, records = [], []
    errors = []
    per_level_err: dict[str, list] = {}

    for w in workloads:
        request = PredictionRequest(
            targets=tuple(CPU_TARGETS),
            core_counts=tuple(cores_list),
            strategies=(strategy,),
        )
        predset = session.predict(w, request)
        for cell in predset:
            target = CPU_TARGETS[cell.target]
            exact = session.ground_truth_hit_rates(
                w, target, cell.cores, strategy=cell.strategy
            )
            for lvl in cell.hit_rates:
                err = abs(cell.hit_rates[lvl] - exact[lvl]) * 100
                errors.append(err)
                per_level_err.setdefault(lvl, []).append(err)
                records.append({
                    "target": cell.target, "workload": w.abbr,
                    "cores": cell.cores, "level": lvl,
                    "predicted": cell.hit_rates[lvl], "exact": exact[lvl],
                    "abs_err_pct": err,
                })
            rows.append([
                cell.target, w.abbr, cell.cores,
                *(f"{cell.hit_rates[l]:.4f}/{exact[l]:.4f}"
                  for l in cell.hit_rates),
            ])

    overall = float(np.mean(errors))
    headers = ["target", "app", "cores"] + [
        f"{l} pred/exact" for l in per_level_err
    ]
    table = fmt_table(headers, rows)
    summary = {
        "overall_avg_abs_err_pct": overall,
        "per_level_avg_err_pct": {
            k: float(np.mean(v)) for k, v in per_level_err.items()
        },
        "paper_claim_pct": 1.23,
        "strategy": strategy,
        "profile_builds": session.stats.profile_builds,
        "profile_cache_hits": session.stats.profile_hits,
        "records": records,
    }
    save_json("paper_hit_rates" + ("_quick" if quick else ""), summary)
    print(table)
    print(f"\noverall avg |err|: {overall:.2f}%  "
          f"(paper's PAPI-vs-SDCM claim: 1.23%)")
    for k, v in summary["per_level_avg_err_pct"].items():
        print(f"  {k}: {v:.2f}%")
    print(f"artifact cache: {session.stats.profile_builds} profile builds, "
          f"{session.stats.profile_hits} hits")
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
