"""§Perf hillclimb harness: rebuild one cell with overrides, re-lower,
re-analyse, print the three roofline terms + memory fit.

    PYTHONPATH=src python -m benchmarks.hillclimb deepseek-67b train_4k \
        --accum 1 --set sp_residuals=True --tag iter1

Each invocation is one hypothesis->change->measure cycle; results land
in experiments/hillclimb/<arch>__<shape>__<tag>.json and the log goes
into EXPERIMENTS.md §Perf by hand.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

OUT = Path(__file__).resolve().parents[1] / "experiments" / "hillclimb"


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False", "None"):
        return {"True": True, "False": False, "None": None}[v]
    return v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--set", nargs="*", default=[],
                    help="config field overrides k=v (dataclasses.replace)")
    ap.add_argument("--rules", nargs="*", default=[],
                    help="sharding rule overrides k=v (v in dp/tp/None)")
    ap.add_argument("--opt-rules", nargs="*", default=[],
                    help="optimizer-state rule overrides (ZeRO-1 style)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args(argv)

    from repro.analysis.buffers import bf16_legalization_overhead
    from repro.analysis.hlo_cost import loop_aware_cost
    from repro.configs import SHAPES, get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, lower_cell

    spec = get_arch(args.arch)
    if args.set:
        overrides = {k: parse_value(v) for k, v in
                     (s.split("=", 1) for s in args.set)}
        spec = dataclasses.replace(
            spec, config=dataclasses.replace(spec.config, **overrides))
    if args.rules:
        rules = dict(spec.rules)
        rules.update({k: parse_value(v) for k, v in
                      (s.split("=", 1) for s in args.rules)})
        spec = dataclasses.replace(spec, rules=rules)
    if args.opt_rules:
        opt_rules = dict(spec.opt_rules)
        opt_rules.update({k: parse_value(v) for k, v in
                          (s.split("=", 1) for s in args.opt_rules)})
        spec = dataclasses.replace(spec, opt_rules=opt_rules)
    if args.accum is not None:
        ga = dict(spec.grad_accum)
        ga[args.shape] = args.accum
        spec = dataclasses.replace(spec, grad_accum=ga)

    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    t0 = time.time()
    cell = build_cell(spec, shape, mesh)
    compiled = lower_cell(cell).compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    cost = loop_aware_cost(txt)
    ovh = bf16_legalization_overhead(txt)
    raw = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
           + mem.output_size_in_bytes - mem.alias_size_in_bytes)

    terms = {
        "compute_s": cost["flops"] / 197e12,
        "memory_s": cost["bytes"] / 819e9,
        "collective_s": cost["ici_bytes"] / 50e9,
    }
    rec = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "tag": args.tag, "overrides": args.set, "rules": args.rules,
        "accum": args.accum, "compile_s": round(t_compile, 1),
        "terms": terms,
        "bound": max(terms, key=terms.get),
        "t_bound_s": max(terms.values()),
        "mem_raw_gib": raw / 2**30,
        "mem_adj_gib": (raw - ovh) / 2**30,
        "collective_counts": cost["collective_counts"],
        "collective_bytes": cost["collective_bytes"],
        "flops": cost["flops"], "bytes": cost["bytes"],
        "ici_bytes": cost["ici_bytes"],
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{args.arch}__{args.shape}__{args.tag}.json").write_text(
        json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("collective_counts",)}, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
