"""Figs. 8–10 analog: Eq. 4–7 runtime prediction across core counts.

No real multicore exists in this container, so the ground truth is the
same analytical chain evaluated with *exact* (simulated-LRU) hit rates
— the error isolates the SDCM approximation, which is the paper's
modeling contribution.  Both sides run through `repro.api`: the
predicted grid via one request per workload (batched SDCM), the exact
side via the ExactLRU stage + the same EqRuntimeModel, on artifacts
the Session computes once.  A secondary absolute anchor measures the
JAX kernel wall-clock at 1 core (reported, not scored; DESIGN.md §7).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, make_session, save_json
from repro.api import (
    EqRuntimeModel,
    PredictionRequest,
    resolve_runtime_model,
    supported_runtime_models,
)
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import all_workloads

QUICK_SUBSET = ["atx", "bcg", "mvt", "jcb", "blk", "2mm"]


def wallclock_anchor(w, repeats: int = 5) -> float | None:
    if w.jax_fn is None:
        return None
    import jax

    args = w.jax_args(jax.random.key(0))
    fn = jax.jit(w.jax_fn)
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def run(quick: bool = True, strategy: str = "round_robin") -> dict:
    workloads = all_workloads(QUICK_SUBSET if quick else None)
    cores_list = [1, 4] if quick else [1, 2, 4, 8, 16]
    session = make_session()
    runtime_model = EqRuntimeModel()
    rows, records, errs = [], [], []
    model_errs: dict[str, list[float]] = {}

    for w in workloads:
        request = PredictionRequest(
            targets=tuple(CPU_TARGETS),
            core_counts=tuple(cores_list),
            strategies=(strategy,),
            counts=w.op_counts,
        )
        predset = session.predict(w, request)
        for cell in predset:
            target = CPU_TARGETS[cell.target]
            exact_rates = session.ground_truth_hit_rates(
                w, target, cell.cores, strategy=cell.strategy
            )
            t_true = runtime_model.runtime(
                target, exact_rates, w.op_counts, cell.cores
            )
            err = (abs(cell.t_pred_s - t_true["t_pred_s"])
                   / max(t_true["t_pred_s"], 1e-12) * 100)
            errs.append(err)
            # every registered stage-4 model against the same
            # exact-rates reference (mirrors repro.validate's
            # runtime-model tier; "eq" reproduces `err` above)
            cell_models = {}
            for mname in supported_runtime_models(target):
                model = resolve_runtime_model(mname, target)
                t_m = model.runtime(
                    target, cell.hit_rates, w.op_counts, cell.cores
                )["t_pred_s"]
                m_err = (abs(t_m - t_true["t_pred_s"])
                         / max(t_true["t_pred_s"], 1e-12) * 100)
                cell_models[mname] = {
                    "t_pred_s": float(t_m), "rel_err_pct": m_err,
                }
                model_errs.setdefault(mname, []).append(m_err)
            records.append({
                "target": cell.target, "workload": w.abbr,
                "cores": cell.cores,
                "t_pred_s": cell.t_pred_s,
                "t_exact_rates_s": t_true["t_pred_s"],
                "t_mem_s": cell.t_mem_s,
                "t_cpu_s": cell.t_cpu_s,
                "rel_err_pct": err,
                "runtime_models": cell_models,
            })
            rows.append([
                cell.target, w.abbr, cell.cores,
                f"{cell.t_pred_s:.3e}",
                f"{t_true['t_pred_s']:.3e}", f"{err:.2f}%",
            ])

    anchors = {}
    for w in workloads:
        wc = wallclock_anchor(w)
        if wc is not None:
            anchors[w.abbr] = wc

    overall = float(np.mean(errs))
    model_summary = {
        m: float(np.mean(v)) for m, v in sorted(model_errs.items())
    }
    print(fmt_table(
        ["target", "app", "cores", "T_pred", "T_exact-rates", "err"], rows))
    print(f"\noverall avg runtime err (SDCM vs exact rates): "
          f"{overall:.2f}%  (paper's HW claim: 9.08%)")
    print("per-model avg err vs exact-rates reference:",
          {m: f"{v:.2f}%" for m, v in model_summary.items()})
    print("1-core JAX wall-clock anchors (s):",
          {k: f"{v:.2e}" for k, v in anchors.items()})
    summary = {
        "overall_avg_rel_err_pct": overall,
        "runtime_model_avg_rel_err_pct": model_summary,
        "paper_claim_pct": 9.08,
        "wallclock_anchors_s": anchors,
        "profile_builds": session.stats.profile_builds,
        "profile_cache_hits": session.stats.profile_hits,
        "records": records,
    }
    save_json("paper_runtimes" + ("_quick" if quick else ""), summary)
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
