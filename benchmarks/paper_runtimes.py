"""Figs. 8–10 analog: Eq. 4–7 runtime prediction across core counts.

No real multicore exists in this container, so the ground truth is the
same analytical chain evaluated with *exact* (simulated-LRU) hit rates
— the error isolates the SDCM approximation, which is the paper's
modeling contribution.  A secondary absolute anchor measures the JAX
kernel wall-clock at 1 core (reported, not scored: XLA-vectorized
kernels are not the paper's -O2 scalar loops; DESIGN.md §7).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    ProfileCache, fmt_table, hit_rates_from_profiles, save_json,
)
from benchmarks.paper_hit_rates import exact_hit_rates
from repro.core.runtime_model import predict_runtime_s
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import all_workloads

QUICK_SUBSET = ["atx", "bcg", "mvt", "jcb", "blk", "2mm"]


def wallclock_anchor(w, repeats: int = 5) -> float | None:
    if w.jax_fn is None:
        return None
    import jax

    args = w.jax_args(jax.random.key(0))
    fn = jax.jit(w.jax_fn)
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def run(quick: bool = True, strategy: str = "round_robin") -> dict:
    workloads = all_workloads(QUICK_SUBSET if quick else None)
    cores_list = [1, 4] if quick else [1, 2, 4, 8, 16]
    cache = ProfileCache()
    rows, records, errs = [], [], []

    for target in CPU_TARGETS.values():
        for w in workloads:
            for cores in cores_list:
                if cores > target.cores:
                    continue
                prd, crd = cache.profiles_for(w, cores, strategy,
                                              target.levels[0].line_size)
                pred_rates = hit_rates_from_profiles(target, prd, crd)
                privs, shared = cache.traces_for(w, cores, strategy)
                exact_rates = exact_hit_rates(target, privs, shared)
                order = [l.name for l in target.levels]
                t_pred = predict_runtime_s(
                    target, [pred_rates[l] for l in order], w.op_counts,
                    cores)
                t_true = predict_runtime_s(
                    target, [exact_rates[l] for l in order], w.op_counts,
                    cores)
                err = (abs(t_pred["t_pred_s"] - t_true["t_pred_s"])
                       / max(t_true["t_pred_s"], 1e-12) * 100)
                errs.append(err)
                records.append({
                    "target": target.name, "workload": w.abbr,
                    "cores": cores,
                    "t_pred_s": t_pred["t_pred_s"],
                    "t_exact_rates_s": t_true["t_pred_s"],
                    "t_mem_s": t_pred["t_mem_s"],
                    "t_cpu_s": t_pred["t_cpu_s"],
                    "rel_err_pct": err,
                })
                rows.append([
                    target.name, w.abbr, cores,
                    f"{t_pred['t_pred_s']:.3e}",
                    f"{t_true['t_pred_s']:.3e}", f"{err:.2f}%",
                ])

    anchors = {}
    for w in workloads:
        wc = wallclock_anchor(w)
        if wc is not None:
            anchors[w.abbr] = wc

    overall = float(np.mean(errs))
    print(fmt_table(
        ["target", "app", "cores", "T_pred", "T_exact-rates", "err"], rows))
    print(f"\noverall avg runtime err (SDCM vs exact rates): "
          f"{overall:.2f}%  (paper's HW claim: 9.08%)")
    print("1-core JAX wall-clock anchors (s):",
          {k: f"{v:.2e}" for k, v in anchors.items()})
    summary = {
        "overall_avg_rel_err_pct": overall,
        "paper_claim_pct": 9.08,
        "wallclock_anchors_s": anchors,
        "records": records,
    }
    save_json("paper_runtimes" + ("_quick" if quick else ""), summary)
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
