"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

* paper_hit_rates  — Figs. 5-6 analog (SDCM vs exact LRU, 3 CPU targets)
* paper_runtimes   — Figs. 8-10 analog (Eq. 4-7 vs exact-rate runtimes)
* reuse_throughput — §3.3.1 (tree vs stack reuse-profile throughput)
  + the Session-vs-legacy grid timing (BENCH_api_grid.json)
  + the batched-fused profile-build benchmark (BENCH_profile.json;
    standalone via ``-m benchmarks.reuse_throughput --profile-gate``)
* roofline_table   — §Roofline (the cell table from the dry-run records)
* service_load     — coalesced PredictionService vs naive per-request
  loop at 1/8/64 concurrent clients (BENCH_service.json)
* explore_sweep    — fused device-resident config sweep vs per-config
  Session.predict loop (BENCH_explore.json; standalone via
  ``-m benchmarks.explore_sweep --smoke``)

``--smoke`` runs a minimal Session grid + the api-grid timing only —
the CI sanity job.
"""
from __future__ import annotations

import sys
import time


def smoke() -> int:
    """CI smoke: tiny end-to-end grid through repro.api + grid timing."""
    from benchmarks.reuse_throughput import api_grid_benchmark
    from repro.api import PredictionRequest, Session
    from repro.hw.targets import CPU_TARGETS
    from repro.workloads.polybench import make_atax

    w = make_atax(n=32)
    session = Session()
    result = session.predict(
        w,
        PredictionRequest(
            targets=tuple(CPU_TARGETS) + ("tpu-v5e",),
            core_counts=(1, 2, 4),
            counts=w.op_counts,
        ),
    )
    print(result.to_table())
    assert len(result) == 12 and all(p.t_pred_s > 0 for p in result)
    grid = api_grid_benchmark(n=32, core_counts=(1, 2, 4))
    assert grid["speedup"] > 1.0, grid
    print("SMOKE-OK")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--smoke" in argv:
        return smoke()
    quick = "--full" not in argv
    t0 = time.time()
    print("=" * 72)
    print(f"PPT-Multicore-on-TPU benchmark suite "
          f"({'quick' if quick else 'full'} mode)")
    print("=" * 72)

    from benchmarks import (
        explore_sweep, paper_hit_rates, paper_runtimes, reuse_throughput,
        roofline_table, service_load,
    )

    print("\n### [1/6] cache hit rates: SDCM prediction vs exact LRU "
          "(paper Figs. 5-6)\n")
    hr = paper_hit_rates.run(quick=quick)

    print("\n### [2/6] runtime prediction: Eq. 4-7 (paper Figs. 8-10)\n")
    rt = paper_runtimes.run(quick=quick)

    print("\n### [3/6] reuse-profile throughput (paper §3.3.1) + "
          "batched-fused profile builds\n")
    reuse_throughput.run(quick=quick)

    print("\n### [4/6] roofline table from dry-run records (§Roofline)\n")
    try:
        roofline_table.run("pod")
    except Exception as e:  # records may not exist yet
        print(f"  (roofline table unavailable: {e})")

    print("\n### [5/6] prediction-service throughput: coalesced vs "
          "naive per-request loop\n")
    service_load.run(quick=quick)

    print("\n### [6/6] fused config sweep vs per-config predict loop "
          "(repro.explore)\n")
    explore_sweep.run(quick=quick)

    print("\n" + "=" * 72)
    print(f"hit-rate avg |err| {hr['overall_avg_abs_err_pct']:.2f}% "
          f"(paper claim 1.23%) | runtime avg err "
          f"{rt['overall_avg_rel_err_pct']:.2f}% (paper claim 9.08%) | "
          f"total {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
