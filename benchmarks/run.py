"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

* paper_hit_rates  — Figs. 5-6 analog (SDCM vs exact LRU, 3 CPU targets)
* paper_runtimes   — Figs. 8-10 analog (Eq. 4-7 vs exact-rate runtimes)
* reuse_throughput — §3.3.1 (tree vs stack reuse-profile throughput)
* roofline_table   — §Roofline (the cell table from the dry-run records)
"""
from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--full" not in argv
    t0 = time.time()
    print("=" * 72)
    print(f"PPT-Multicore-on-TPU benchmark suite "
          f"({'quick' if quick else 'full'} mode)")
    print("=" * 72)

    from benchmarks import (
        paper_hit_rates, paper_runtimes, reuse_throughput, roofline_table,
    )

    print("\n### [1/4] cache hit rates: SDCM prediction vs exact LRU "
          "(paper Figs. 5-6)\n")
    hr = paper_hit_rates.run(quick=quick)

    print("\n### [2/4] runtime prediction: Eq. 4-7 (paper Figs. 8-10)\n")
    rt = paper_runtimes.run(quick=quick)

    print("\n### [3/4] reuse-profile throughput (paper §3.3.1)\n")
    reuse_throughput.run(quick=quick)

    print("\n### [4/4] roofline table from dry-run records (§Roofline)\n")
    try:
        roofline_table.run("pod")
    except Exception as e:  # records may not exist yet
        print(f"  (roofline table unavailable: {e})")

    print("\n" + "=" * 72)
    print(f"hit-rate avg |err| {hr['overall_avg_abs_err_pct']:.2f}% "
          f"(paper claim 1.23%) | runtime avg err "
          f"{rt['overall_avg_rel_err_pct']:.2f}% (paper claim 9.08%) | "
          f"total {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
