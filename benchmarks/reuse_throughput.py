"""§3.3.1 table analog: reuse-profile computation throughput.

The paper's speed contribution is replacing the O(N·M) stack method
with an O(N·log M) tree; this benchmark measures both on the same
traces (refs/s), plus the per-set variant the exact simulator uses.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.core.reuse.distance import (
    per_set_reuse_distances, reuse_distances, reuse_distances_ref,
)


def synthetic_trace(n: int, working_set: int, seed: int = 0) -> np.ndarray:
    """Zipf-ish reuse: mixes hot lines with cold streaming."""
    rng = np.random.default_rng(seed)
    hot = rng.integers(0, working_set // 8, n // 2)
    cold = rng.integers(0, working_set, n - n // 2)
    mix = np.concatenate([hot, cold])
    rng.shuffle(mix)
    return (mix * 64 + 4096).astype(np.int64)


def run(quick: bool = True) -> dict:
    sizes = [20_000, 60_000] if quick else [20_000, 60_000, 200_000]
    rows, records = [], []
    for n in sizes:
        tr = synthetic_trace(n, working_set=n // 4)
        t0 = time.perf_counter()
        rd_tree = reuse_distances(tr, 64)
        t_tree = time.perf_counter() - t0

        t_stack = None
        if n <= 60_000:
            t0 = time.perf_counter()
            rd_stack = reuse_distances_ref((tr // 64))
            t_stack = time.perf_counter() - t0
            assert np.array_equal(rd_tree, rd_stack), "tree != stack oracle"

        t0 = time.perf_counter()
        per_set_reuse_distances(tr, line_size=64, num_sets=64)
        t_set = time.perf_counter() - t0

        rows.append([
            n,
            f"{n / t_tree:,.0f}",
            f"{n / t_stack:,.0f}" if t_stack else "-",
            f"{n / t_set:,.0f}",
            f"{t_stack / t_tree:.1f}x" if t_stack else "-",
        ])
        records.append({
            "n": n, "tree_refs_per_s": n / t_tree,
            "stack_refs_per_s": (n / t_stack) if t_stack else None,
            "per_set_refs_per_s": n / t_set,
        })
    print(fmt_table(
        ["refs", "tree refs/s", "stack refs/s", "per-set refs/s",
         "tree speedup"], rows))
    summary = {"records": records}
    save_json("reuse_throughput" + ("_quick" if quick else ""), summary)
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
