"""§3.3.1 table analog: reuse-profile computation throughput, plus the
`repro.api` grid amortization benchmark and the ISSUE-2 streaming
peak-memory benchmark.

The paper's speed contribution is replacing the O(N·M) stack method
with an O(N·log M) tree; this benchmark measures both on the same
traces (refs/s), plus the per-set variant the exact simulator uses.

The second half times the SAME 3-target x {1,2,4,8}-core prediction
grid two ways — the legacy per-call predictor loop (profiles recomputed
per cell, seed-quickstart style) vs one cached `Session` request — and
writes the speedup to ``BENCH_api_grid.json`` at the repo root.

The streaming benchmark drives ``reuse_distance_windows`` over a
synthetic :class:`SyntheticChunkSource` whose trace never exists in
memory, measuring peak RSS (each probe in its own subprocess, so
high-water marks don't bleed between runs) and throughput, and records
``BENCH_streaming.json`` at the repo root for the canonical >= 10M-ref
configuration (``--streaming-full``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import warnings

import numpy as np

from benchmarks.common import REPO_ROOT, fmt_table, make_session, save_json
from repro.core.reuse.distance import (
    per_set_reuse_distances, reuse_distances, reuse_distances_ref,
)
from repro.core.trace.types import LabeledTrace, rebatch_windows


def synthetic_trace(n: int, working_set: int, seed: int = 0) -> np.ndarray:
    """Zipf-ish reuse: mixes hot lines with cold streaming."""
    rng = np.random.default_rng(seed)
    hot = rng.integers(0, working_set // 8, n // 2)
    cold = rng.integers(0, working_set, n - n // 2)
    mix = np.concatenate([hot, cold])
    rng.shuffle(mix)
    return (mix * 64 + 4096).astype(np.int64)


class SyntheticChunkSource:
    """ChunkedTraceSource whose windows are generated on demand.

    Zipf-ish reuse over a FIXED working set (``lines`` distinct cache
    lines, independent of ``n``): half the references hammer a hot
    eighth of the lines.  Each window is derived from ``(seed, window
    index)``, so no O(N) array ever exists — this is what lets the
    peak-RSS benchmark feed >= 10M references through the streaming scan
    inside a bounded-memory process.
    """

    def __init__(self, n: int, lines: int = 1 << 16, seed: int = 0):
        self.n = int(n)
        self.lines = int(lines)
        self.seed = int(seed)

    def __len__(self) -> int:
        return self.n

    _GEN_BLOCK = 1 << 14  # content is fixed per (seed, block index) —
    # the trace is identical for every requested window size

    def _blocks(self):
        for i, start in enumerate(range(0, self.n, self._GEN_BLOCK)):
            w = min(self._GEN_BLOCK, self.n - start)
            rng = np.random.default_rng((self.seed, i))
            hot = rng.integers(0, self.lines // 8, w // 2)
            cold = rng.integers(0, self.lines, w - w // 2)
            mix = np.concatenate([hot, cold])
            rng.shuffle(mix)
            yield mix * 64 + 4096

    def windows(self, window_size: int):
        pieces = (
            LabeledTrace(
                b, np.zeros(len(b), dtype=np.int32),
                np.zeros(len(b), dtype=bool),
            )
            for b in self._blocks()
        )
        yield from rebatch_windows(pieces, window_size)

    def materialize(self) -> np.ndarray:
        """Flat addresses (small-n equivalence/comparison probes only)."""
        return np.concatenate(list(self._blocks()))


_PROBE_CODE = r"""
import json, resource, sys, time
import numpy as np

kind, n, lines, window, seed, rate = sys.argv[1:7]
n, lines, window, seed = int(n), int(lines), int(window), int(seed)
rate = float(rate)

from benchmarks.reuse_throughput import SyntheticChunkSource
from repro.core.reuse.distance import (
    reuse_distance_windows, reuse_distances,
)
from repro.core.reuse.profile import (
    profile_from_distances, profile_from_distances_incremental,
)
from repro.core.reuse.sampled import (
    sampled_profile_windows, sampled_reuse_profile,
)

src = SyntheticChunkSource(n, lines, seed)
t0 = time.perf_counter()
if kind == "baseline":
    # import-only RSS floor (plus one tiny scan so the XLA arena and
    # jit machinery are warm, comparable with the real probes)
    prof = profile_from_distances_incremental(
        reuse_distance_windows(
            SyntheticChunkSource(min(n, 4096), lines, seed),
            64, window_size=window,
        )
    )
elif kind == "streaming":
    prof = profile_from_distances_incremental(
        reuse_distance_windows(src, 64, window_size=window)
    )
elif kind == "sampled":
    # SHARDS path: windows are hash-filtered before the streaming scan,
    # so state tracks only the sampled slice of the working set
    prof = sampled_profile_windows(src, 64, rate=rate,
                                   window_size=window)
elif kind == "sampled_mem":
    prof = sampled_reuse_profile(src.materialize(), 64, rate=rate)
else:  # in-memory path: materialize + reuse_distances (auto engine —
    # the offline vectorized pass at these sizes since ISSUE-5); the
    # profile-equality assertion below doubles as a cross-engine check
    prof = profile_from_distances(
        reuse_distances(src.materialize(), 64)
    )
dt = time.perf_counter() - t0
peak_kib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print(json.dumps({
    "kind": kind, "n": n, "lines": lines, "window": window,
    "seconds": dt, "refs_per_s": n / dt,
    "peak_rss_mib": peak_kib / 1024.0,
    "profile_total": int(prof.total),
    "profile_distinct_distances": int(len(prof.distances)),
    "error_bound": prof.error_bound,
}))
"""


def _rss_probe(kind: str, n: int, *, lines: int, window: int = 0,
               seed: int = 0, rate: float = 1.0) -> dict:
    """Run one scan in a fresh subprocess; return its self-reported
    stats (ru_maxrss is a per-process high-water mark)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(REPO_ROOT / "src") + os.pathsep + str(REPO_ROOT)
        + (os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    )
    out = subprocess.run(
        [sys.executable, "-c", _PROBE_CODE,
         kind, str(n), str(lines), str(window), str(seed), str(rate)],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, check=True,
    )
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"  {kind:11s} n={n:>11,} window={window:>8,}: "
          f"{rec['refs_per_s']:>10,.0f} refs/s, "
          f"peak RSS {rec['peak_rss_mib']:.0f} MiB")
    return rec


def streaming_benchmark(full: bool = False) -> dict:
    """Peak-RSS + throughput: streaming vs in-memory reuse scans.

    ``full`` runs the ISSUE-2 acceptance configuration (>= 10M refs);
    the default is the CI smoke size.  The acceptance evidence is the
    ``rss_growth`` ratio: multiplying the trace length by
    ``large_n/small_n`` must leave streaming peak RSS ~flat, because the
    scan state is bounded by O(window + working set), never O(N).
    """
    if full:
        small_n, large_n = 1_000_000, 10_000_000
        lines, windows, compare_n = 1 << 16, (8_192, 16_384), 200_000
    else:
        small_n, large_n = 60_000, 240_000
        lines, windows, compare_n = 1 << 13, (8_192,), 60_000

    baseline = _rss_probe("baseline", small_n, lines=lines,
                          window=windows[0])
    streaming_rows = []
    for window in windows:
        rec_small = _rss_probe("streaming", small_n, lines=lines,
                               window=window)
        rec_large = _rss_probe("streaming", large_n, lines=lines,
                               window=window)
        streaming_rows.append({
            "window": window,
            "small": rec_small,
            "large": rec_large,
            "rss_growth": rec_large["peak_rss_mib"]
            / max(rec_small["peak_rss_mib"], 1e-9),
            # scan-state RSS with the import/XLA floor removed
            "small_delta_mib": rec_small["peak_rss_mib"]
            - baseline["peak_rss_mib"],
            "large_delta_mib": rec_large["peak_rss_mib"]
            - baseline["peak_rss_mib"],
            "throughput_ratio": rec_large["refs_per_s"]
            / max(rec_small["refs_per_s"], 1e-9),
        })
    inmem = _rss_probe("inmemory", compare_n, lines=lines)
    stream_cmp = _rss_probe("streaming", compare_n, lines=lines,
                            window=windows[0])
    # same trace -> identical profiles, or the scans disagree
    for key in ("profile_total", "profile_distinct_distances"):
        assert inmem[key] == stream_cmp[key], (key, inmem, stream_cmp)

    payload = {
        "config": {
            "full": full, "small_n": small_n, "large_n": large_n,
            "working_set_lines": lines, "windows": list(windows),
            "compare_n": compare_n,
            "trace_bytes_if_materialized": large_n * 8,
        },
        "baseline": baseline,
        "streaming": streaming_rows,
        "inmemory_compare": inmem,
        "streaming_compare": stream_cmp,
        "speedup_vs_inmemory_at_compare_n":
            stream_cmp["refs_per_s"] / inmem["refs_per_s"],
    }
    growth = max(r["rss_growth"] for r in streaming_rows)
    scale = large_n / small_n
    print(f"  -> peak-RSS growth {growth:.2f}x for a {scale:.0f}x longer "
          f"trace (streaming state is O(window + working set)); "
          f"streaming runs at "
          f"{payload['speedup_vs_inmemory_at_compare_n']:.2f}x the "
          f"in-memory (offline-engine) pass at n={compare_n:,} — it "
          f"trades throughput for bounded memory")
    # regression gates (the CI smoke job runs these at small sizes):
    # 1. throughput must stay ~flat in n — an O(N)-per-step fallback to
    #    the monolithic scan tanks the large/small ratio (measured:
    #    in-memory drops ~4x from 60k to 200k refs, streaming doesn't)
    for row in streaming_rows:
        assert row["throughput_ratio"] > 0.5, row
    # 2. the baseline-subtracted high-water mark must not grow with the
    #    trace length (generous slack: RSS deltas are noisy at MiB
    #    scale next to the ~400 MiB import/XLA floor)
    for row in streaming_rows:
        assert row["large_delta_mib"] < row["small_delta_mib"] + 96, row
    assert growth < 1.5, payload
    if full:
        (REPO_ROOT / "BENCH_streaming.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("streaming" + ("_full" if full else "_smoke"), payload)
    return payload


def sampling_benchmark(full: bool = False) -> dict:
    """Peak-RSS gate for the SHARDS-sampled profile path (ISSUE-9).

    The sampled windowed pass hash-filters every address window before
    the streaming scan, so its state is O(window + rate * working set)
    — growing the trace past 1M references must leave peak RSS ~flat.
    Each probe runs in its own subprocess (``ru_maxrss`` high-water
    marks don't bleed), mirroring the streaming benchmark; the smoke
    gate (CI ``sampling-gate`` job) runs the >= 1M-ref point, ``full``
    the 10M one and records ``BENCH_sampling.json``.
    """
    if full:
        small_n, large_n = 1_000_000, 10_000_000
        lines, window, compare_n = 1 << 16, 8_192, 200_000
    else:
        small_n, large_n = 250_000, 1_000_000
        lines, window, compare_n = 1 << 13, 8_192, 60_000
    rate = 0.25

    baseline = _rss_probe("baseline", small_n, lines=lines, window=window)
    rec_small = _rss_probe("sampled", small_n, lines=lines, window=window,
                           rate=rate)
    rec_large = _rss_probe("sampled", large_n, lines=lines, window=window,
                           rate=rate)
    # the windowed sampled pass must agree with the in-memory sampled
    # pass on the same trace (bit-identity is property-tested; this is
    # the cross-subprocess end-to-end check, error bound included)
    win_cmp = _rss_probe("sampled", compare_n, lines=lines, window=window,
                         rate=rate)
    mem_cmp = _rss_probe("sampled_mem", compare_n, lines=lines, rate=rate)
    for key in ("profile_total", "profile_distinct_distances",
                "error_bound"):
        assert win_cmp[key] == mem_cmp[key], (key, win_cmp, mem_cmp)

    payload = {
        "config": {
            "full": full, "small_n": small_n, "large_n": large_n,
            "rate": rate, "working_set_lines": lines, "window": window,
            "compare_n": compare_n,
        },
        "baseline": baseline,
        "small": rec_small,
        "large": rec_large,
        "rss_growth": rec_large["peak_rss_mib"]
        / max(rec_small["peak_rss_mib"], 1e-9),
        "small_delta_mib": rec_small["peak_rss_mib"]
        - baseline["peak_rss_mib"],
        "large_delta_mib": rec_large["peak_rss_mib"]
        - baseline["peak_rss_mib"],
        "declared_error_bound": rec_large["error_bound"],
        "windowed_vs_inmemory_identical": True,
    }
    scale = large_n / small_n
    print(f"  -> peak-RSS growth {payload['rss_growth']:.2f}x for a "
          f"{scale:.0f}x longer trace at rate {rate} (sampled state is "
          f"O(window + rate * working set)); declared error bound "
          f"{rec_large['error_bound']:.4f} at n={large_n:,}")
    # gates: flat high-water mark in n (same slack policy as the
    # streaming gate — RSS deltas are noisy next to the XLA floor),
    # and a nontrivial declared bound.  The bound need not shrink with
    # n here: the working set is FIXED, so line masses grow with n and
    # the cluster variance stays ~constant (only the uniform-trace
    # bound is monotone in n).
    assert payload["rss_growth"] < 1.5, payload
    assert payload["large_delta_mib"] < payload["small_delta_mib"] + 96, \
        payload
    assert 0.0 < rec_large["error_bound"] < 1.0, payload
    if full:
        (REPO_ROOT / "BENCH_sampling.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("sampling" + ("_full" if full else "_smoke"), payload)
    return payload


# ---------------------------------------------------------------------------
# Profile-build benchmark (ISSUE-5): batched-fused vs sequential host path.
# ---------------------------------------------------------------------------


def _profile_case_per_set(n: int, num_sets: int, lines: int) -> dict:
    """Per-set distance pass: batched engine vs sequential streaming scan.

    The sequential host path is the pre-batching production pipeline —
    ONE chunked Fenwick scan over the stably-concatenated per-set
    subtraces (bit-identical to the monolithic scan, and the only
    sequential engine that stays feasible at 1M refs).
    """
    from repro.core.reuse.distance import (
        per_set_reuse_distances, reuse_distances_streaming, split_by_set,
    )

    addrs = SyntheticChunkSource(n, lines).materialize()
    segments, order = split_by_set(addrs, line_size=64, num_sets=num_sets)
    concat = np.concatenate(segments)

    t0 = time.perf_counter()
    rd_seq_sorted = reuse_distances_streaming(concat)
    t_seq = time.perf_counter() - t0
    rd_seq = np.empty_like(rd_seq_sorted)
    rd_seq[order] = rd_seq_sorted

    # first batched run pays the per-shape-bucket XLA compiles (cached
    # for the life of the process, like every other jit in the repo);
    # the gate measures steady state and reports the cold time alongside
    t0 = time.perf_counter()
    rd_bat = per_set_reuse_distances(addrs, line_size=64,
                                     num_sets=num_sets, method="batched")
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    rd_bat = per_set_reuse_distances(addrs, line_size=64,
                                     num_sets=num_sets, method="batched")
    t_bat = time.perf_counter() - t0

    assert np.array_equal(rd_seq, rd_bat), "per-set batched != sequential"
    return {
        "shape": "per_set", "n": n, "num_sets": num_sets,
        "working_set_lines": lines,
        "sequential_s": t_seq, "batched_s": t_bat, "batched_cold_s": t_cold,
        "sequential_refs_per_s": n / t_seq, "batched_refs_per_s": n / t_bat,
        "speedup": t_seq / max(t_bat, 1e-12), "bit_identical": True,
    }


def _profile_case_multicore(n: int, cores: int, lines: int) -> dict:
    """Per-core profile builds: batched + fused histogram vs the
    sequential streaming scan + host np.unique accumulation."""
    import jax.numpy as jnp

    from repro.core.reuse.batched import reuse_distances_batched
    from repro.core.reuse.distance import reuse_distance_windows
    from repro.core.reuse.fused import (
        FusedReuseHistogram, profile_from_binned_hist,
    )
    from repro.core.reuse.profile import (
        profile_from_distances, profile_from_distances_incremental,
    )
    from repro.kernels.reuse_hist import reuse_hist_ref

    per_core = n // cores
    segments = [
        SyntheticChunkSource(per_core, lines, seed=c).materialize() // 64
        for c in range(cores)
    ]

    t0 = time.perf_counter()
    seq_profiles = [
        profile_from_distances_incremental(reuse_distance_windows(s))
        for s in segments
    ]
    t_seq = time.perf_counter() - t0

    def batched_build():
        rds = reuse_distances_batched(segments)
        accs = []
        for rd in rds:
            acc = FusedReuseHistogram()
            acc.update(jnp.asarray(rd))
            accs.append(acc)
        profiles = [a.profile() for a in accs]
        return rds, accs, profiles

    t0 = time.perf_counter()
    batched_build()  # pays the histogram-kernel compiles once
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    rds, accs, binned_profiles = batched_build()
    t_bat = time.perf_counter() - t0

    # identity: exact distances reproduce the sequential profiles bit
    # for bit; the fused histograms equal the reference binning of the
    # exact distances (counts exactly, distance mass to f32 tolerance)
    for rd, sp, acc in zip(rds, seq_profiles, accs):
        p = profile_from_distances(rd)
        assert np.array_equal(p.distances, sp.distances)
        assert np.array_equal(p.counts, sp.counts)
        ref = np.asarray(reuse_hist_ref(
            jnp.asarray(rd.astype(np.float32)),
            jnp.ones((len(rd),), jnp.float32),
        ))
        hist = acc.histogram()
        assert np.array_equal(hist[0], ref), "fused counts != ref binning"
    del binned_profiles
    return {
        "shape": "multi_core", "n": n, "cores": cores,
        "working_set_lines": lines,
        "sequential_s": t_seq, "batched_s": t_bat, "batched_cold_s": t_cold,
        "sequential_refs_per_s": n / t_seq, "batched_refs_per_s": n / t_bat,
        "speedup": t_seq / max(t_bat, 1e-12), "bit_identical": True,
    }


def profile_build_benchmark(full: bool = True) -> dict:
    """Batched-fused profile pipeline vs the sequential host path.

    Two shapes per size: the per-set decomposition (one segment per
    cache set — exact-LRU's workload; wide buckets routed to the
    vmapped Fenwick engine) and per-core profile builds (few long
    segments routed to the offline engine, fused into the Pallas
    histogram).  The CI gate (``--profile-gate``) asserts bit-/
    tolerance-identity and >= 3x speedup for both shapes at the 1M
    point; ``BENCH_profile.json`` records the canonical run.
    """
    sizes = (100_000, 1_000_000) if full else (60_000,)
    rows = []
    for n in sizes:
        per_set = _profile_case_per_set(n, num_sets=512, lines=1 << 16)
        multi = _profile_case_multicore(n, cores=8, lines=1 << 13)
        rows.extend([per_set, multi])
        for r in (per_set, multi):
            print(f"  {r['shape']:10s} n={n:>10,}: "
                  f"seq {r['sequential_refs_per_s']:>10,.0f} refs/s, "
                  f"batched {r['batched_refs_per_s']:>10,.0f} refs/s "
                  f"-> {r['speedup']:.1f}x")
    payload = {
        "config": {"full": full, "sizes": list(sizes), "gate_n": 1_000_000,
                   "gate_speedup": 3.0},
        "cases": rows,
    }
    gate_rows = [r for r in rows if r["n"] == 1_000_000]
    for r in gate_rows:
        assert r["bit_identical"], r
        assert r["speedup"] >= 3.0, (
            f"profile-build gate: {r['shape']} at 1M is only "
            f"{r['speedup']:.2f}x the sequential host path", r,
        )
    if full:
        (REPO_ROOT / "BENCH_profile.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("profile_build" + ("" if full else "_smoke"), payload)
    return payload


CANONICAL_CORES = (1, 2, 4, 8)  # the acceptance grid (3 targets x these)


def api_grid_benchmark(n: int = 64, core_counts=CANONICAL_CORES) -> dict:
    """Legacy per-call loop vs one cached Session request on an
    identical 3-CPU-target grid (the ISSUE-1 acceptance number).

    The repo-root ``BENCH_api_grid.json`` is only (re)written for the
    canonical 3-target x {1,2,4,8} grid — smoke runs with toy grids
    must not clobber the recorded baseline.  Every run also lands in
    experiments/results/ via save_json.
    """
    import json

    from repro.api import PredictionRequest
    from repro.core.predictor import PPTMulticorePredictor
    from repro.hw.targets import CPU_TARGETS
    from repro.workloads.polybench import make_atax

    workload = make_atax(n=n)
    trace = workload.trace()

    # legacy: one predictor per target, one predict() per cell — every
    # call re-derives mimicked traces + reuse profiles from scratch
    t0 = time.perf_counter()
    legacy_cells = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for target in CPU_TARGETS.values():
            predictor = PPTMulticorePredictor(target)
            for cores in core_counts:
                predictor.predict(trace, cores, workload.op_counts)
                legacy_cells += 1
    t_legacy = time.perf_counter() - t0

    # new API: one declarative request, artifacts computed once
    request = PredictionRequest(
        targets=tuple(CPU_TARGETS),
        core_counts=tuple(core_counts),
        counts=workload.op_counts,
    )
    # cold run on a throwaway session pays the one-time XLA compile of
    # the batched SDCM kernel; the timed run measures steady state
    # (the legacy numpy loop has no compile cost to exclude)
    t0 = time.perf_counter()
    make_session().predict(trace, request)
    t_cold = time.perf_counter() - t0
    session = make_session()
    t0 = time.perf_counter()
    result = session.predict(trace, request)
    t_session = time.perf_counter() - t0

    assert len(result) == legacy_cells, (len(result), legacy_cells)
    payload = {
        "grid": {
            "targets": list(CPU_TARGETS),
            "core_counts": list(core_counts),
            "cells": legacy_cells,
            "workload": workload.name,
            "trace_refs": len(trace),
        },
        "legacy_s": t_legacy,
        "session_s": t_session,
        "session_cold_s": t_cold,
        "speedup": t_legacy / max(t_session, 1e-12),
        "profile_builds": session.stats.profile_builds,
        "profile_cache_hits": session.stats.profile_hits,
    }
    if tuple(core_counts) == CANONICAL_CORES:
        (REPO_ROOT / "BENCH_api_grid.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("BENCH_api_grid", payload)
    print(f"\napi grid ({legacy_cells} cells): legacy loop {t_legacy:.2f}s, "
          f"Session {t_session:.2f}s -> {payload['speedup']:.1f}x "
          f"({session.stats.profile_builds} profile builds, "
          f"{session.stats.profile_hits} cache hits)")
    return payload


def run(quick: bool = True) -> dict:
    sizes = [20_000, 60_000] if quick else [20_000, 60_000, 200_000]
    rows, records = [], []
    for n in sizes:
        tr = synthetic_trace(n, working_set=n // 4)
        t0 = time.perf_counter()
        rd_tree = reuse_distances(tr, 64)
        t_tree = time.perf_counter() - t0

        t_stack = None
        if n <= 60_000:
            t0 = time.perf_counter()
            rd_stack = reuse_distances_ref((tr // 64))
            t_stack = time.perf_counter() - t0
            assert np.array_equal(rd_tree, rd_stack), "tree != stack oracle"

        t0 = time.perf_counter()
        per_set_reuse_distances(tr, line_size=64, num_sets=64)
        t_set = time.perf_counter() - t0

        rows.append([
            n,
            f"{n / t_tree:,.0f}",
            f"{n / t_stack:,.0f}" if t_stack else "-",
            f"{n / t_set:,.0f}",
            f"{t_stack / t_tree:.1f}x" if t_stack else "-",
        ])
        records.append({
            "n": n, "tree_refs_per_s": n / t_tree,
            "stack_refs_per_s": (n / t_stack) if t_stack else None,
            "per_set_refs_per_s": n / t_set,
        })
    print(fmt_table(
        ["refs", "tree refs/s", "stack refs/s", "per-set refs/s",
         "tree speedup"], rows))
    grid = api_grid_benchmark(n=48 if quick else 96)
    print("\nprofile builds (batched-fused vs sequential host path):")
    profile = profile_build_benchmark(full=not quick)
    print("\nstreaming scans (peak RSS per subprocess):")
    streaming = streaming_benchmark(full=not quick)
    summary = {"records": records, "api_grid": grid,
               "profile_build": profile, "streaming": streaming}
    save_json("reuse_throughput" + ("_quick" if quick else ""), summary)
    return summary


if __name__ == "__main__":
    if "--streaming-smoke" in sys.argv:
        streaming_benchmark(full=False)
    elif "--streaming-full" in sys.argv:
        streaming_benchmark(full=True)
    elif "--sampling-smoke" in sys.argv:
        # CI gate: >= 1M-ref sampled profile at ~flat peak RSS
        sampling_benchmark(full=False)
    elif "--sampling-full" in sys.argv:
        sampling_benchmark(full=True)
    elif "--profile-gate" in sys.argv:
        # CI gate: identity + >= 3x at the 1M point (both shapes)
        profile_build_benchmark(full=True)
    elif "--profile-smoke" in sys.argv:
        profile_build_benchmark(full=False)
    else:
        run(quick="--full" not in sys.argv)
