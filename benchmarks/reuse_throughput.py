"""§3.3.1 table analog: reuse-profile computation throughput, plus the
`repro.api` grid amortization benchmark.

The paper's speed contribution is replacing the O(N·M) stack method
with an O(N·log M) tree; this benchmark measures both on the same
traces (refs/s), plus the per-set variant the exact simulator uses.

The second half times the SAME 3-target x {1,2,4,8}-core prediction
grid two ways — the legacy per-call predictor loop (profiles recomputed
per cell, seed-quickstart style) vs one cached `Session` request — and
writes the speedup to ``BENCH_api_grid.json`` at the repo root.
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from benchmarks.common import REPO_ROOT, fmt_table, make_session, save_json
from repro.core.reuse.distance import (
    per_set_reuse_distances, reuse_distances, reuse_distances_ref,
)


def synthetic_trace(n: int, working_set: int, seed: int = 0) -> np.ndarray:
    """Zipf-ish reuse: mixes hot lines with cold streaming."""
    rng = np.random.default_rng(seed)
    hot = rng.integers(0, working_set // 8, n // 2)
    cold = rng.integers(0, working_set, n - n // 2)
    mix = np.concatenate([hot, cold])
    rng.shuffle(mix)
    return (mix * 64 + 4096).astype(np.int64)


CANONICAL_CORES = (1, 2, 4, 8)  # the acceptance grid (3 targets x these)


def api_grid_benchmark(n: int = 64, core_counts=CANONICAL_CORES) -> dict:
    """Legacy per-call loop vs one cached Session request on an
    identical 3-CPU-target grid (the ISSUE-1 acceptance number).

    The repo-root ``BENCH_api_grid.json`` is only (re)written for the
    canonical 3-target x {1,2,4,8} grid — smoke runs with toy grids
    must not clobber the recorded baseline.  Every run also lands in
    experiments/results/ via save_json.
    """
    import json

    from repro.api import PredictionRequest
    from repro.core.predictor import PPTMulticorePredictor
    from repro.hw.targets import CPU_TARGETS
    from repro.workloads.polybench import make_atax

    workload = make_atax(n=n)
    trace = workload.trace()

    # legacy: one predictor per target, one predict() per cell — every
    # call re-derives mimicked traces + reuse profiles from scratch
    t0 = time.perf_counter()
    legacy_cells = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for target in CPU_TARGETS.values():
            predictor = PPTMulticorePredictor(target)
            for cores in core_counts:
                predictor.predict(trace, cores, workload.op_counts)
                legacy_cells += 1
    t_legacy = time.perf_counter() - t0

    # new API: one declarative request, artifacts computed once
    request = PredictionRequest(
        targets=tuple(CPU_TARGETS),
        core_counts=tuple(core_counts),
        counts=workload.op_counts,
    )
    # cold run on a throwaway session pays the one-time XLA compile of
    # the batched SDCM kernel; the timed run measures steady state
    # (the legacy numpy loop has no compile cost to exclude)
    t0 = time.perf_counter()
    make_session().predict(trace, request)
    t_cold = time.perf_counter() - t0
    session = make_session()
    t0 = time.perf_counter()
    result = session.predict(trace, request)
    t_session = time.perf_counter() - t0

    assert len(result) == legacy_cells, (len(result), legacy_cells)
    payload = {
        "grid": {
            "targets": list(CPU_TARGETS),
            "core_counts": list(core_counts),
            "cells": legacy_cells,
            "workload": workload.name,
            "trace_refs": len(trace),
        },
        "legacy_s": t_legacy,
        "session_s": t_session,
        "session_cold_s": t_cold,
        "speedup": t_legacy / max(t_session, 1e-12),
        "profile_builds": session.stats.profile_builds,
        "profile_cache_hits": session.stats.profile_hits,
    }
    if tuple(core_counts) == CANONICAL_CORES:
        (REPO_ROOT / "BENCH_api_grid.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("BENCH_api_grid", payload)
    print(f"\napi grid ({legacy_cells} cells): legacy loop {t_legacy:.2f}s, "
          f"Session {t_session:.2f}s -> {payload['speedup']:.1f}x "
          f"({session.stats.profile_builds} profile builds, "
          f"{session.stats.profile_hits} cache hits)")
    return payload


def run(quick: bool = True) -> dict:
    sizes = [20_000, 60_000] if quick else [20_000, 60_000, 200_000]
    rows, records = [], []
    for n in sizes:
        tr = synthetic_trace(n, working_set=n // 4)
        t0 = time.perf_counter()
        rd_tree = reuse_distances(tr, 64)
        t_tree = time.perf_counter() - t0

        t_stack = None
        if n <= 60_000:
            t0 = time.perf_counter()
            rd_stack = reuse_distances_ref((tr // 64))
            t_stack = time.perf_counter() - t0
            assert np.array_equal(rd_tree, rd_stack), "tree != stack oracle"

        t0 = time.perf_counter()
        per_set_reuse_distances(tr, line_size=64, num_sets=64)
        t_set = time.perf_counter() - t0

        rows.append([
            n,
            f"{n / t_tree:,.0f}",
            f"{n / t_stack:,.0f}" if t_stack else "-",
            f"{n / t_set:,.0f}",
            f"{t_stack / t_tree:.1f}x" if t_stack else "-",
        ])
        records.append({
            "n": n, "tree_refs_per_s": n / t_tree,
            "stack_refs_per_s": (n / t_stack) if t_stack else None,
            "per_set_refs_per_s": n / t_set,
        })
    print(fmt_table(
        ["refs", "tree refs/s", "stack refs/s", "per-set refs/s",
         "tree speedup"], rows))
    grid = api_grid_benchmark(n=48 if quick else 96)
    summary = {"records": records, "api_grid": grid}
    save_json("reuse_throughput" + ("_quick" if quick else ""), summary)
    return summary


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
