"""§Roofline: the 40-cell table from the dry-run records.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun),
derives the three roofline terms per (arch x shape x mesh) and prints
the table + per-cell bottleneck.  ``loop_aware_cost`` is the primary
source (XLA's cost_analysis counts while bodies once — probe-verified);
both are recorded.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import save_json
from repro.analysis.roofline import Roofline, format_table, model_flops
from repro.hw.targets import TPU_V5E

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def roofline_from_record(rec: dict) -> Roofline:
    from repro.configs import SHAPES, get_arch

    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "multipod" else 256
    cost = rec.get("loop_aware_cost") or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes", 0.0))
    ici = float(cost.get("ici_bytes", 0.0))
    factor = get_arch(rec["arch"]).flops_token_factor
    mf = factor * model_flops(rec["kind"], rec["active_param_count"],
                              shape.seq_len, shape.global_batch) / chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"],
        compute_s=flops / TPU_V5E.peak_flops_bf16,
        memory_s=bytes_acc / TPU_V5E.hbm_bandwidth,
        collective_s=ici / TPU_V5E.ici_bandwidth,
        model_flops_chip=mf,
        hlo_flops_chip=flops,
        chips=chips,
        useful_bytes_chip=float(rec["memory"]["argument_bytes"]),
    )


def run(mesh: str = "pod") -> dict:
    recs = load_records(mesh)
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    rows = [roofline_from_record(r) for r in ok]
    rows.sort(key=lambda r: (r.arch, r.shape))
    print(format_table(rows))
    print(f"\n{len(ok)} cells analysed, {len(skipped)} skipped "
          f"(sub-quadratic-attention rule) on mesh={mesh}")
    for r in skipped:
        print(f"  SKIP {r['arch']} x {r['shape']}: {r['reason'][:60]}...")
    payload = {
        "mesh": mesh,
        "cells": [r.row() for r in rows],
        "skipped": [
            {"arch": r["arch"], "shape": r["shape"], "reason": r["reason"]}
            for r in skipped
        ],
    }
    save_json(f"roofline_{mesh}", payload)
    return payload


if __name__ == "__main__":
    import sys
    run("multipod" if "--multipod" in sys.argv else "pod")
