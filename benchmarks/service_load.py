"""Service load generator: coalesced microbatching vs a naive
per-request loop (ISSUE-4 acceptance).

    PYTHONPATH=src python -m benchmarks.service_load [--smoke]

At 1/8/64 concurrent clients, the same stream of prediction requests
is driven through

* **naive** — the upstream-PPT serving shape: every request is its own
  ``Session.predict`` call against the per-level float64 SDCM oracle,
  serialized by a lock (one Session is not thread-safe — this is what
  "a batch script per query" costs);
* **service** — :class:`repro.service.PredictionService`: requests
  coalesce in the microbatcher and each batch is ONE call into the
  batched vmapped SDCM grid kernel via ``Session.predict_many``.

Both sides run with warm profile caches (the paper's "collect once"
premise — the service exists for the *query* phase), so the comparison
isolates serving overhead: per-request python/dispatch loops vs one
padded kernel call per batch.  Writes ``BENCH_service.json`` at the
repo root; the acceptance gate is service/naive throughput >= 3x at 64
clients.
"""
from __future__ import annotations

import json
import sys
import threading
import time

from benchmarks.common import REPO_ROOT, fmt_table, save_json
from repro.api import AnalyticalSDCM, PredictionRequest, Session
from repro.hw.targets import CPU_TARGETS
from repro.service import PredictionService, ServiceConfig
from repro.workloads.polybench import make_workload

CLIENT_COUNTS = (1, 8, 64)


def request_pool() -> list[tuple[object, PredictionRequest, object]]:
    """A mixed stream of (source, request, dedup-key) query shapes —
    several workloads, target subsets, and core grids, as a fleet of
    what-if clients would issue against one profile corpus."""
    cpus = tuple(CPU_TARGETS)
    shapes = [
        dict(targets=cpus, core_counts=(1, 2, 4, 8)),
        dict(targets=cpus[:1], core_counts=(1, 8)),
        dict(targets=cpus[1:], core_counts=(2, 4)),
        dict(targets=cpus + ("tpu-v5e",), core_counts=(1, 4)),
    ]
    pool = []
    for abbr in ("atx", "mvt", "bcg"):
        workload = make_workload(abbr, "smoke")
        for si, shape in enumerate(shapes):
            req = PredictionRequest(
                counts=workload.op_counts, respect_core_limit=False,
                **shape,
            )
            pool.append((workload, req, (abbr, si)))
    return pool


def _drive(n_clients: int, n_requests: int, pool, issue) -> float:
    """Fan ``n_requests`` (round-robin over the pool) across
    ``n_clients`` threads; returns elapsed seconds."""
    jobs = [pool[i % len(pool)] for i in range(n_requests)]
    chunks = [jobs[i::n_clients] for i in range(n_clients)]
    errors: list[BaseException] = []

    def client(chunk):
        try:
            for workload, req, key in chunk:
                issue(workload, req, key)
        except BaseException as exc:  # noqa: BLE001 — fail the bench
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(c,)) for c in chunks]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return elapsed


def run(quick: bool = True, *, write_root: bool | None = None) -> dict:
    pool = request_pool()
    per_client = 4 if quick else 16

    # --- naive: per-request Session.predict, f64 oracle, lock-serial
    naive_session = Session(cache_model=AnalyticalSDCM(backend="numpy"))
    lock = threading.Lock()

    def naive_issue(workload, req, _key):
        with lock:
            naive_session.predict(workload, req)

    # --- service: microbatched, one batched-kernel call per batch
    service = PredictionService(
        config=ServiceConfig(max_batch=128, max_wait_ms=4, queue_size=4096)
    )

    rows, results = [], {}
    with service:
        # warm both sides: profiles built once, kernels compiled
        for workload, req, key in pool:
            naive_session.predict(workload, req)
            service.predict(workload, req, key=key)

        service_issue = (
            lambda w, r, k: service.predict(w, r, key=k, timeout=600)
        )
        for n_clients in CLIENT_COUNTS:
            # low concurrency gets extra rounds so timings aren't noise
            n_requests = max(n_clients * per_client, 8 * per_client)
            # untimed round at this fan-in: compiles the batched-kernel
            # G-buckets this concurrency produces (steady-state serving
            # never recompiles; the gate measures steady state)
            _drive(n_clients, n_requests, pool, service_issue)
            t_naive = _drive(n_clients, n_requests, pool, naive_issue)
            t_service = _drive(n_clients, n_requests, pool, service_issue)
            naive_qps = n_requests / t_naive
            service_qps = n_requests / t_service
            results[n_clients] = {
                "requests": n_requests,
                "naive_s": t_naive,
                "service_s": t_service,
                "naive_qps": naive_qps,
                "service_qps": service_qps,
                "speedup": service_qps / naive_qps,
            }
            rows.append([
                n_clients, n_requests, f"{naive_qps:.1f}",
                f"{service_qps:.1f}",
                f"{service_qps / naive_qps:.2f}x",
            ])
        stats = service.snapshot()

    print(fmt_table(
        ["clients", "requests", "naive qps", "service qps", "speedup"],
        rows,
    ))
    print(f"mean batch size {stats['service']['mean_batch_size']:.1f}, "
          f"deduped {stats['service']['deduped']}, "
          f"kernel calls {stats['service']['kernel_calls']}")

    payload = {
        "description": (
            "coalesced PredictionService vs naive per-request "
            "Session.predict (f64 oracle, lock-serialized) at N "
            "concurrent clients; warm profile caches on both sides"
        ),
        "mode": "quick" if quick else "full",
        "per_client_requests": per_client,
        "concurrency": results,
        "service_stats": stats,
        "acceptance": {
            "criterion": "service >= 3x naive throughput at 64 clients",
            "speedup_at_64": results[64]["speedup"],
            "pass": results[64]["speedup"] >= 3.0,
        },
    }
    if write_root is None:
        write_root = not quick
    if write_root:
        (REPO_ROOT / "BENCH_service.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("BENCH_service", payload)
    return payload


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--full" not in argv
    if "--smoke" in argv:
        payload = run(quick=True)
        ok = payload["acceptance"]["speedup_at_64"] > 1.0
        print("SMOKE-OK" if ok else "SMOKE-FAIL (no speedup at 64 clients)")
        return 0 if ok else 1
    payload = run(quick=quick, write_root=True)
    if not payload["acceptance"]["pass"]:
        print("ACCEPTANCE FAIL: service < 3x naive at 64 clients",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
