"""Fused config-sweep benchmark: `sweep_grid` vs a per-config
`Session.predict` loop (ISSUE-10 acceptance).

    PYTHONPATH=src python -m benchmarks.explore_sweep [--smoke | --full]

The sweep path exists because the explore agents ask one question the
per-request grid was never shaped for: "score these THOUSANDS of
hardware configs against one fixed profile".  The naive shape is the
sequential oracle — every candidate becomes its own applied target and
its own ``Session.predict`` call (warm profile caches, batched SDCM
backend) — while the fused shape stages the whole candidate set as
traced device arrays and runs ONE jitted SDCM+ECM dispatch per row
shape.

Gates (written to ``BENCH_explore.json``):

* fused >= 20x the naive loop at 1k configs (both warm);
* the fused best config agrees with the sequential oracle's best
  (score tie-tolerance, since inert axes can tie exactly);
* a subsample of fused rows is BIT-identical to `batched_hit_rates`
  on the applied targets;
* the Pallas inner evaluator agrees with the vmap inner to 1e-6;
* ``--full`` additionally runs a ~10k-config sweep and asserts it
  issued exactly ONE fused-grid invocation per distinct row shape.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import REPO_ROOT, fmt_table, save_json
from repro.api import PredictionRequest, Session
from repro.api.batched import _sweep_akey, batched_hit_rates
from repro.explore import FusedSweepEvaluator, SearchSpace
from repro.workloads.polybench import make_workload

TIE_RTOL = 1e-6   # fused/oracle scores agree to f32-chain accuracy


def space_1k() -> SearchSpace:
    """8 sets x 4 ways x 4 latencies x 4 betas x 2 cores = 1024."""
    return SearchSpace(
        sets=(256, 512, 1024, 2048, 4096, 8192, 16384, 32768),
        ways=(2, 4, 8, 16),
        latency_cy=(12.0, 20.0, 36.0, 60.0),
        beta_cy=(1.0, 2.0, 3.0, 4.0),
        cores=(1, 2),
    )


def space_10k() -> SearchSpace:
    """16 sets x 4 ways x 5 latencies x 4 betas x 4 cores x 2 line
    sizes = 10240 configs across 8 profile groups."""
    sets = tuple(64 << i for i in range(16))
    return SearchSpace(
        sets=sets,
        ways=(2, 4, 8, 16),
        line_sizes=(64, 128),
        latency_cy=(12.0, 20.0, 36.0, 48.0, 60.0),
        beta_cy=(1.0, 2.0, 3.0, 4.0),
        cores=(1, 2, 4, 8),
    )


def naive_scores(session: Session, workload, evaluator,
                 configs) -> np.ndarray:
    """The sequential oracle: one applied target + one
    ``Session.predict`` call per candidate config."""
    base = evaluator.base
    li = evaluator.level_idx
    out = np.empty(len(configs))
    for ci, cfg in enumerate(configs):
        request = PredictionRequest(
            targets=(cfg.apply(base, li),),
            core_counts=(cfg.cores,),
            strategies=(cfg.strategy,),
            counts=workload.op_counts,
            runtime_model="ecm",
            respect_core_limit=False,
        )
        (cell,) = session.predict(workload, request)
        out[ci] = cell.t_pred_s
    return out


def check_bit_identity(session, workload, evaluator, configs,
                       rates: np.ndarray, sample: int = 32) -> int:
    """Fused rows vs `batched_hit_rates` on the applied targets."""
    rng = np.random.default_rng(0)
    idxs = rng.choice(len(configs), size=min(sample, len(configs)),
                      replace=False)
    base, li = evaluator.base, evaluator.level_idx
    items = []
    for ci in idxs:
        cfg = configs[ci]
        art = session.artifacts(
            workload, cfg.cores, strategy=cfg.strategy, seed=0,
            line_size=cfg.line_size,
        )
        items.append((cfg.apply(base, li), art))
    names = [lvl.name for lvl in base.levels]
    for ci, per_level in zip(idxs, batched_hit_rates(items)):
        want = [per_level[n] for n in names]
        assert rates[ci].tolist() == want, (
            f"fused rates for config {configs[ci]} are not bit-identical"
            f" to batched_hit_rates: {rates[ci].tolist()} != {want}"
        )
    return len(idxs)


def row_shapes(evaluator, configs) -> set:
    """Distinct (profile group, per-level bucket tuple) row shapes a
    sweep dispatches — the denominator of the one-invocation claim."""
    groups: dict[tuple, list[int]] = {}
    for ci, cfg in enumerate(configs):
        groups.setdefault(
            (cfg.line_size, cfg.cores, cfg.strategy), []
        ).append(ci)
    shapes = set()
    for (line, cores, strategy), idxs in groups.items():
        geom = evaluator._geometry([configs[i] for i in idxs], line, cores)
        for ri in range(len(idxs)):
            shapes.add((
                (line, cores, strategy),
                _sweep_akey(geom.assoc[ri], geom.blocks[ri]),
            ))
    return shapes


def run(quick: bool = True, write_root: bool | None = None) -> dict:
    workload = make_workload("atx", "smoke")
    session = Session(cache_model="batched")
    space = space_1k()
    configs = space.configs()
    evaluator = FusedSweepEvaluator(workload, space, session=session)
    assert evaluator.objective == "runtime"

    # warm both sides: profile caches + jit compile caches (the naive
    # loop reuses ONE compiled grid kernel across configs; the fused
    # side compiles once per row shape — both paid before timing)
    evaluator.evaluate(configs)
    naive_scores(session, workload, evaluator, configs[:2])

    warm_dispatches = evaluator.stats.fused_dispatches
    t0 = time.perf_counter()
    res = evaluator.evaluate(configs)
    fused_s = time.perf_counter() - t0
    timed_dispatches = evaluator.stats.fused_dispatches - warm_dispatches

    t0 = time.perf_counter()
    oracle = naive_scores(session, workload, evaluator, configs)
    naive_s = time.perf_counter() - t0
    speedup = naive_s / max(fused_s, 1e-12)

    # top-1 agreement with the sequential oracle (tie-tolerant)
    fused_best = int(np.argmin(res.scores))
    oracle_best = float(np.min(oracle))
    top1_ok = oracle[fused_best] <= oracle_best * (1 + TIE_RTOL)
    assert top1_ok, (
        f"fused best config {configs[fused_best]} scores "
        f"{oracle[fused_best]:.6e} on the oracle, best {oracle_best:.6e}"
    )
    np.testing.assert_allclose(res.scores, oracle, rtol=1e-5)

    bit_checked = check_bit_identity(
        session, workload, evaluator, configs, res.rates
    )

    # Pallas inner evaluator subsample
    pallas = FusedSweepEvaluator(workload, space, session=session,
                                 inner="pallas")
    sub = configs[:16]
    pallas_res = pallas.evaluate(sub)
    pallas_diff = float(np.max(np.abs(
        pallas_res.rates - res.rates[: len(sub)]
    )))
    assert pallas_diff <= 1e-6, f"pallas inner diff {pallas_diff}"

    shapes_1k = row_shapes(evaluator, configs)
    payload = {
        "description": (
            "fused device-resident config sweep (sweep_grid) vs a "
            "per-config Session.predict loop, warm caches, atx smoke"
        ),
        "mode": "quick" if quick else "full",
        "configs": len(configs),
        "fused_s": fused_s,
        "naive_s": naive_s,
        "speedup": speedup,
        "fused_dispatches_1k": timed_dispatches,
        "row_shapes_1k": len(shapes_1k),
        "bit_identity_sample": bit_checked,
        "pallas_max_abs_diff": pallas_diff,
        "best": {
            "config": configs[fused_best].to_json(),
            "t_pred_s": float(res.scores[fused_best]),
        },
        "acceptance": {
            "criterion": "fused >= 20x per-config predict loop at 1k "
                         "configs; oracle top-1 agreement; bit-identical "
                         "rates; pallas within 1e-6",
            "speedup_at_1k": speedup,
            "top1_agrees": bool(top1_ok),
            "pass": bool(speedup >= 20.0 and top1_ok),
        },
    }

    if not quick:
        big_space = space_10k()
        big = big_space.configs()
        big_eval = FusedSweepEvaluator(workload, big_space,
                                       session=session)
        t0 = time.perf_counter()
        big_res = big_eval.evaluate(big)
        big_s = time.perf_counter() - t0
        shapes = row_shapes(big_eval, big)
        assert big_eval.stats.fused_dispatches == len(shapes), (
            f"{big_eval.stats.fused_dispatches} dispatches for "
            f"{len(shapes)} row shapes — the sweep must issue exactly "
            "one fused-grid invocation per row shape"
        )
        payload["full_sweep"] = {
            "configs": len(big),
            "seconds": big_s,
            "configs_per_s": len(big) / max(big_s, 1e-12),
            "fused_dispatches": big_eval.stats.fused_dispatches,
            "row_shapes": len(shapes),
            "best": {
                "config": big[int(np.argmin(big_res.scores))].to_json(),
                "t_pred_s": float(np.min(big_res.scores)),
            },
        }

    print(fmt_table(
        ["configs", "fused s", "naive s", "speedup", "dispatches",
         "row shapes"],
        [[len(configs), f"{fused_s:.3f}", f"{naive_s:.3f}",
          f"{speedup:.1f}x", timed_dispatches, len(shapes_1k)]],
    ))
    if "full_sweep" in payload:
        fs = payload["full_sweep"]
        print(f"full sweep: {fs['configs']} configs in "
              f"{fs['seconds']:.2f}s ({fs['configs_per_s']:.0f}/s), "
              f"{fs['fused_dispatches']} dispatches for "
              f"{fs['row_shapes']} row shapes")

    if write_root is None:
        write_root = not quick
    if write_root:
        (REPO_ROOT / "BENCH_explore.json").write_text(
            json.dumps(payload, indent=2)
        )
    save_json("BENCH_explore", payload)
    return payload


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--full" not in argv
    payload = run(quick=quick, write_root="--full" in argv or None)
    if not payload["acceptance"]["pass"]:
        print("ACCEPTANCE FAIL: "
              f"speedup {payload['speedup']:.1f}x (need >= 20x) or "
              "oracle disagreement", file=sys.stderr)
        return 1
    print("SMOKE-OK" if quick else "OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
