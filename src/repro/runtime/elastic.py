"""Elastic scaling: re-plan shardings when the device pool changes.

A checkpoint stores *logical* axes, so scaling from 512 -> 256 chips
(pod loss) or down to a single debug host is a restore with a new
mesh.  ``plan_remesh`` reports exactly which leaves change shardings
and which logical mappings stop dividing (fall back to replication) —
the operator-facing diff before committing to a restart.
"""
from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from repro.dist.sharding import ShardingRules, pspec_for
from repro.runtime.checkpoint import load_manifest


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_mesh_axes: dict
    new_mesh_axes: dict
    shardings: dict              # leaf name -> PartitionSpec (new mesh)
    fallbacks: list              # (leaf, logical axis, dim) that replicate
    bytes_per_device: float

    def summary(self) -> str:
        lines = [
            f"remesh {self.old_mesh_axes} -> {self.new_mesh_axes}:"
            f" {len(self.shardings)} leaves,"
            f" {len(self.fallbacks)} replication fallbacks,"
            f" {self.bytes_per_device / 2**30:.2f} GiB/device"
        ]
        for leaf, axis, dim in self.fallbacks[:20]:
            lines.append(f"  fallback {leaf}: {axis!r} over dim {dim}")
        return "\n".join(lines)


_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int8": 1, "float64": 8, "int64": 8, "uint32": 4}


def plan_remesh(ckpt_dir, new_mesh: Mesh,
                rule_overrides: dict | None = None,
                old_mesh_axes: dict | None = None) -> RemeshPlan:
    manifest = load_manifest(ckpt_dir)
    rules = ShardingRules(new_mesh, rule_overrides or {})
    shardings, fallbacks = {}, []
    total_bytes = 0.0
    n_dev = new_mesh.size
    for entry in manifest["leaves"]:
        axes = entry["axes"]
        shape = tuple(entry["shape"])
        if axes is None:
            axes = (None,) * len(shape)
        fb: list = []
        spec = pspec_for(shape, tuple(axes), rules, fb)
        shardings[entry["name"]] = spec
        for axis, dim in fb:
            fallbacks.append((entry["name"], axis, dim))
        leaf_bytes = float(_DTYPE_BYTES.get(entry["dtype"], 4))
        for d in shape:
            leaf_bytes *= d
        shards = 1
        for p in spec:
            if p is None:
                continue
            for ax in (p if isinstance(p, tuple) else (p,)):
                shards *= new_mesh.shape[ax]
        total_bytes += leaf_bytes / shards
    return RemeshPlan(
        old_mesh_axes=old_mesh_axes or {},
        new_mesh_axes=dict(new_mesh.shape),
        shardings=shardings,
        fallbacks=fallbacks,
        bytes_per_device=total_bytes,
    )


def fits(plan: RemeshPlan, hbm_bytes: int, headroom: float = 0.7) -> bool:
    """Would the checkpointed state fit the per-device HBM budget?"""
    return plan.bytes_per_device <= hbm_bytes * headroom
