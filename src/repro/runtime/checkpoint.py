"""Sharded checkpoint/restart with elastic re-sharding.

Layout: one ``.npy`` per pytree leaf + ``manifest.json`` holding the
step, tree structure, and each leaf's *logical* sharding axes.  Restore
maps logical axes onto ANY mesh (elastic scaling: a 512-chip checkpoint
restores onto 256 chips or 1 host) — the mesh is a property of the
run, not the checkpoint.

Writes are atomic (tmp dir + rename) and optionally async (background
thread); ``keep`` bounds disk usage.  On a real cluster each host
writes only its addressable shards — the manifest format is unchanged,
only the writer loop differs (documented in DESIGN.md §8).
"""
from __future__ import annotations

import errno
import json
import re
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.dist.sharding import ShardingRules, pspec_for


def _sanitize(keystr: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", keystr).strip("_") or "leaf"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    seen: dict[str, int] = {}
    for path, leaf in flat:
        name = _sanitize(jax.tree_util.keystr(path))
        if name in seen:
            seen[name] += 1
            name = f"{name}__{seen[name]}"
        else:
            seen[name] = 0
        names.append(name)
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    axes_tree: Any = None) -> Path:
    """Write ``state`` under ``directory/step_<n>`` atomically.

    The staging directory name is unique per writer (a fixed name would
    let two concurrent savers of the same step interleave partial
    files); whichever writer renames into place first wins, the loser
    discards its staging copy."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(
        dir=directory, prefix=f".tmp_step_{step:08d}."
    ))

    names, leaves, treedef = _flatten_with_names(state)
    if axes_tree is not None:
        # flatten *up to* the state's structure: logical-axes leaves are
        # tuples of strings and must not be descended into
        axes_leaves = treedef.flatten_up_to(axes_tree)
    else:
        axes_leaves = [None] * len(leaves)

    manifest = {"step": int(step), "leaves": []}
    for name, leaf, axes in zip(names, leaves, axes_leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or not arr.dtype.isbuiltin:
            # ml_dtypes (bfloat16 etc.) don't round-trip through .npy;
            # store as f32 (bf16 c f32 exactly), manifest keeps truth
            arr = arr.astype(np.float32)
        np.save(tmp / f"{name}.npy", arr, allow_pickle=False)
        manifest["leaves"].append({
            "name": name,
            "dtype": dtype_str,
            "shape": list(arr.shape),
            "axes": list(axes) if axes is not None else None,
        })
    # tree structure is re-derived from the caller's abstract_state at
    # restore (named .npy leaves make the mapping explicit)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if not _publish(tmp, final):
        # contended away by concurrent same-step writers; whichever
        # won left a complete checkpoint in place — ours is redundant
        shutil.rmtree(tmp, ignore_errors=True)
    return final


def _publish(tmp: Path, final: Path, attempts: int = 8) -> bool:
    """Swap a fully-staged checkpoint into place.

    ``rename`` only succeeds onto a non-existent target; an occupied
    target (EEXIST/ENOTEMPTY — the previous checkpoint of this step,
    or a concurrent writer's) is cleared and the rename retried.  Any
    other rename error propagates untouched — it must never trigger
    the clear, or a persistent failure (EACCES, EXDEV, …) would
    destroy the existing good checkpoint and then publish nothing.
    Every rename moves a *complete* staging dir, so the final
    directory is always some writer's whole checkpoint, never a
    mixture."""
    for _ in range(attempts):
        try:
            tmp.rename(final)
            return True
        except OSError as exc:
            if exc.errno not in (errno.EEXIST, errno.ENOTEMPTY):
                raise
            shutil.rmtree(final, ignore_errors=True)
    # attempts exhausted under contention: acceptable only if some
    # concurrent writer left a complete checkpoint behind
    if (final / "manifest.json").exists():
        return False
    raise OSError(
        f"could not publish checkpoint to {final}: rename contended "
        f"{attempts} times and no complete checkpoint is in place"
    )


def load_manifest(ckpt_dir: str | Path) -> dict:
    return json.loads((Path(ckpt_dir) / "manifest.json").read_text())


def restore_checkpoint(ckpt_dir: str | Path, abstract_state: Any,
                       rules: ShardingRules | None = None) -> Any:
    """Restore onto the current process.  With ``rules``, every leaf is
    device_put with the sharding its *logical* axes imply on the new
    mesh (elastic re-shard); without, plain host arrays."""
    ckpt_dir = Path(ckpt_dir)
    manifest = load_manifest(ckpt_dir)
    names, abstract_leaves, treedef = _flatten_with_names(abstract_state)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves = []
    for name, ab in zip(names, abstract_leaves):
        entry = by_name[name]
        arr = np.load(ckpt_dir / f"{name}.npy", allow_pickle=False)
        if tuple(arr.shape) != tuple(ab.shape):
            raise ValueError(
                f"checkpoint leaf {name}: shape {arr.shape} != expected "
                f"{tuple(ab.shape)}"
            )
        if arr.dtype != ab.dtype:
            arr = arr.astype(ab.dtype)  # f32 -> bf16 etc. (registered)
        if rules is not None and entry["axes"] is not None:
            sharding = jax.sharding.NamedSharding(
                rules.mesh,
                pspec_for(arr.shape, tuple(entry["axes"]), rules),
            )
            leaves.append(jax.device_put(arr.astype(ab.dtype), sharding))
        else:
            leaves.append(jax.device_put(arr.astype(ab.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Rolling async checkpointer.

    save() snapshots to host then hands the write to a background
    thread; wait() joins.  Retains the ``keep`` newest steps."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.directory = Path(directory)
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self.directory.mkdir(parents=True, exist_ok=True)

    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.directory.glob("step_*")
            if p.is_dir()
        )

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, state: Any, axes_tree: Any = None) -> None:
        self.wait()
        # snapshot to host synchronously (state may be donated next step)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def write():
            save_checkpoint(self.directory, step, host_state, axes_tree)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, abstract_state: Any,
                       rules: ShardingRules | None = None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        state = restore_checkpoint(
            self.directory / f"step_{step:08d}", abstract_state, rules
        )
        return step, state

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
