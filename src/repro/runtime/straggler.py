"""Deadline-based straggler mitigation, with PPT-predicted deadlines.

The paper's headline property — predict runtime for any configuration
*before running it* — is exactly what a straggler detector needs: an
expected step time that doesn't come from warm-up statistics.  The
monitor accepts the roofline/PPT step-time bound as its prior deadline
and tightens it with observed medians as steps accumulate.

Pure logic + injectable clock: unit-testable, and the decision layer a
real cluster agent would call between steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass
class WorkerView:
    worker: int
    last_step: int
    last_heartbeat_s: float


@dataclasses.dataclass
class StragglerDecision:
    stragglers: list[int]
    failed: list[int]
    deadline_s: float


class StragglerMonitor:
    """Track per-worker step heartbeats against a deadline.

    deadline = max(predicted_step_s * slack, observed_median * slack)
    — the PPT prediction bootstraps detection from step 0 (no warm-up
    blindness); workers past ``fail_factor`` x deadline are failed.
    """

    def __init__(self, num_workers: int, predicted_step_s: float,
                 slack: float = 3.0, fail_factor: float = 5.0,
                 clock: Callable[[], float] | None = None):
        if predicted_step_s <= 0:
            raise ValueError("predicted_step_s must be positive")
        self.num_workers = num_workers
        self.predicted_step_s = predicted_step_s
        self.slack = slack
        self.fail_factor = fail_factor
        self.clock = clock or __import__("time").monotonic
        now = self.clock()
        self.views = {
            w: WorkerView(w, -1, now) for w in range(num_workers)
        }
        self.durations: list[float] = []

    def heartbeat(self, worker: int, step: int) -> None:
        now = self.clock()
        view = self.views[worker]
        if step > view.last_step and view.last_step >= 0:
            self.durations.append(now - view.last_heartbeat_s)
            if len(self.durations) > 512:
                del self.durations[: -512]
        view.last_step = step
        view.last_heartbeat_s = now

    def deadline_s(self) -> float:
        base = self.predicted_step_s
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            base = max(base, med)
        return base * self.slack

    def check(self) -> StragglerDecision:
        now = self.clock()
        deadline = self.deadline_s()
        stragglers, failed = [], []
        for view in self.views.values():
            idle = now - view.last_heartbeat_s
            if idle > deadline * self.fail_factor / self.slack:
                failed.append(view.worker)
            elif idle > deadline:
                stragglers.append(view.worker)
        return StragglerDecision(sorted(stragglers), sorted(failed), deadline)

    def remove(self, worker: int) -> None:
        self.views.pop(worker, None)
        self.num_workers = len(self.views)
