from repro.runtime.checkpoint import (
    CheckpointManager, save_checkpoint, restore_checkpoint,
)
from repro.runtime.elastic import plan_remesh
from repro.runtime.straggler import StragglerMonitor

__all__ = [
    "CheckpointManager", "save_checkpoint", "restore_checkpoint",
    "plan_remesh", "StragglerMonitor",
]
