"""Session: executes PredictionRequests with content-hash artifact
caching.

The paper's headline property — "predictions for various core counts
without having to rerun the application" — becomes an invariant here:
one trace is loaded once, and every derived artifact is cached under
content-hash keys

    reuse distances       (trace_id, line_size)
    mimicked privates     (trace_id, cores)
    interleaved shared    (trace_id, cores, strategy, seed)
    PRD/CRD profiles      (trace_id, line_size, cores, strategy, seed)

so a full (target x core-count x strategy) sweep computes each profile
exactly once across ALL targets (the three Table-5 CPUs share 64-byte
lines; the TPU's 512-byte VMEM granule adds one more profile set, not
a new pipeline).  ``Session.stats`` exposes build/hit counters — tests
assert the compute-once property instead of trusting it.

The in-memory caches are process-local; ``Session(artifact_dir=...)``
(or ``store=ArtifactStore(...)``) transparently layers a disk-backed
store *under* them: a profile missing from memory is loaded from disk
before being rebuilt, and every freshly built profile is written back
— so repeated sweeps are incremental across processes and runs
(``repro.validate.store``).  Lookup order per cell:

    in-memory dict  ->  ArtifactStore (npz on disk)  ->  build + put

``predict_many`` evaluates many independent requests through one
cache-model grid call — the coalescible surface the concurrent
prediction service (:mod:`repro.service`) microbatches through.
"""
from __future__ import annotations

import dataclasses

from repro.api.request import PredictionRequest
from repro.api.results import CellPrediction, PredictionSet
from repro.api.stages import (
    AnalyticalSDCM,
    ExactLRU,
    MimicProfileBuilder,
    ProfileArtifacts,
    as_trace_source,
    default_runtime_model,
    resolve_runtime_model,
    trace_content_id,
)
from repro.core.reuse.profile import profile_from_distances
from repro.core.trace.types import LabeledTrace
from repro.hw.targets import resolve_target


@dataclasses.dataclass
class SessionStats:
    """Observable cache behaviour (asserted by tests/benchmarks)."""

    trace_builds: int = 0
    rd_builds: int = 0
    mimic_builds: int = 0
    interleave_builds: int = 0
    profile_builds: int = 0
    profile_hits: int = 0
    streaming_builds: int = 0
    store_hits: int = 0     # profiles served from the disk store
    store_puts: int = 0     # freshly built profiles written back
    kernel_compiles: int = 0  # NEW jit compile-cache entries this session
    # triggered in `repro.api.batched` (grid + config-sweep kernels).
    # A warm session re-running an identical sweep must leave this
    # unchanged: every dispatch lands on an existing row-shape key.

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class Session:
    """Cached executor for :class:`PredictionRequest` grids.

    Stages are injectable: pass a different ``cache_model`` (e.g.
    :class:`repro.api.stages.ExactLRU`) or ``profile_builder`` and the
    same request produces ground-truth or alternative-model grids.
    ``cache=False`` disables artifact reuse (the legacy per-call cost
    model — used by the deprecated shim and the benchmark baseline).

    ``artifact_dir`` (or an explicit ``store``) layers a disk-backed
    :class:`repro.validate.store.ArtifactStore` under the in-memory
    caches: profiles survive the process, so a second run over the
    same traces performs zero reuse-profile recomputations
    (``stats.store_hits`` counts disk loads, ``stats.store_puts``
    write-backs).

    ``binned=True`` builds device-binned log2 profiles through the
    fused ``kernels/reuse_hist`` path instead of exact histograms —
    faster at scale, hit rates within ~1e-3 of the exact profiles, and
    stored under distinct (builder-fingerprinted) disk keys.

    ``sampled=R`` builds SHARDS-sampled profiles at rate R through
    :mod:`repro.core.reuse.sampled` — constant memory at any trace
    length, each profile carrying its declared ``error_bound`` — also
    under distinct disk keys (``+sampled{R}``), so exact, binned, and
    sampled cells of one workload never collide in a shared store.
    A per-request ``PredictionRequest.sampled_rate`` overrides the
    session rate cell by cell through a cached variant builder.
    """

    def __init__(
        self,
        *,
        profile_builder=None,
        cache_model=None,
        runtime_model=None,
        cache: bool = True,
        window_size: int | None = None,
        binned: bool = False,
        sampled: float | None = None,
        store=None,
        artifact_dir=None,
        verify_fingerprints: bool = False,
    ):
        if profile_builder is None:
            profile_builder = MimicProfileBuilder(
                window_size=window_size, binned=binned, sampled=sampled
            )
        elif binned and not getattr(profile_builder, "binned", False):
            raise ValueError(
                "binned=True only configures the default builder; pass a "
                "builder with binned profile support instead"
            )
        elif (sampled is not None
              and getattr(profile_builder, "sampled", None) != sampled):
            raise ValueError(
                "sampled=R only configures the default builder; pass a "
                "builder with sampled profile support instead"
            )
        self.builder = profile_builder
        self._sampled_builders: dict[float, object] = {}
        self.window_size = window_size
        if isinstance(cache_model, str):
            # shorthand for the analytical backends ("batched"/"numpy")
            cache_model = AnalyticalSDCM(backend=cache_model)
        self.cache_model = cache_model or AnalyticalSDCM()
        self.runtime_model = runtime_model  # None -> per-target default
        self.cache_enabled = cache
        if store is None and artifact_dir is not None:
            from repro.validate.store import ArtifactStore

            store = ArtifactStore(artifact_dir)
        self.store = store
        self.verify_fingerprints = verify_fingerprints
        self.stats = SessionStats()
        self._trace_ids: dict[int, str] = {}       # id(source) -> trace_id
        # pins every cached source: id() keys are only valid while the
        # object is alive, so a recycled address must never hit the map
        self._sources: dict[int, object] = {}
        self._traces: dict[str, LabeledTrace] = {}
        self._rd: dict = {}
        self._privates: dict = {}
        self._shared: dict = {}
        self._profiles: dict = {}

    # --- artifact construction (each key computed exactly once) -----------

    def identify(self, source) -> str:
        """Trace id of a source WITHOUT materializing its trace when a
        declared fingerprint is available.

        Registry-resolved workloads carry ``declared_fingerprint`` — a
        stable key over (name, generator version, resolved kwargs) —
        which becomes the trace id directly, so artifact cells can be
        answered from the store without ever building the trace.
        Undeclared sources fall back to :meth:`load` (materialize +
        content-hash), preserving the old behaviour.
        """
        sid = id(source)
        if self.cache_enabled and sid in self._trace_ids:
            return self._trace_ids[sid]
        fp = getattr(source, "declared_fingerprint", None)
        if fp:
            tid = str(fp)
            if self.cache_enabled:
                self._trace_ids[sid] = tid
                self._sources[sid] = source
            return tid
        tid, _trace = self.load(source)
        return tid

    def load(self, source) -> tuple[str, LabeledTrace]:
        """Coerce + trace + id a source (cached).

        Declared sources are keyed by their declared fingerprint;
        anything else is content-hashed after materialization.  With
        caching disabled both the id and the hash are skipped (nothing
        is keyed on them) — the deprecated shim must not pay O(N)
        hashing the legacy predictor never did.
        """
        sid = id(source)  # the caller's object, not the coercion wrapper
        if self.cache_enabled and sid in self._trace_ids:
            tid = self._trace_ids[sid]
            return tid, self._trace_of(tid, source)
        if not self.cache_enabled:
            trace = as_trace_source(source).trace()
            self.stats.trace_builds += 1
            return "", trace
        fp = getattr(source, "declared_fingerprint", None)
        if fp:
            tid = str(fp)
            self._trace_ids[sid] = tid
            self._sources[sid] = source
            return tid, self._trace_of(tid, source)
        trace = as_trace_source(source).trace()
        self.stats.trace_builds += 1
        tid = trace_content_id(trace)
        self._trace_ids[sid] = tid
        self._sources[sid] = source
        self._traces.setdefault(tid, trace)
        return tid, trace

    def _trace_of(self, tid: str, source) -> LabeledTrace:
        """Materialize (or fetch) the trace behind an already-known id.

        This is the ONLY place declared sources build their trace, so
        ``stats.trace_builds`` counts real materializations — the
        warm-store zero-build property is asserted on it.
        """
        if self.cache_enabled and tid in self._traces:
            return self._traces[tid]
        trace = as_trace_source(source).trace()
        self.stats.trace_builds += 1
        if self.cache_enabled:
            self._traces[tid] = trace
        if getattr(source, "declared_fingerprint", None):
            self._check_declared(tid, source, trace)
        return trace

    def _check_declared(self, tid: str, source, trace: LabeledTrace) -> None:
        """Record (and optionally verify) the content hash behind a
        declared fingerprint.

        First materialization writes ``trace_content_id`` into the
        store's workload meta; under ``verify_fingerprints=True`` a
        later materialization that hashes differently — a generator
        whose declared version lied — raises instead of silently
        serving stale artifacts.
        """
        if self.store is None:
            return
        meta = dict(self.store.get_json("workload", tid) or {})
        recorded = meta.get("trace_content_id")
        if recorded is None:
            meta.update(
                trace_content_id=trace_content_id(trace),
                refs=len(trace),
                workload=getattr(source, "workload_name", None)
                or meta.get("workload"),
            )
            self.store.put_json("workload", tid, meta)
        elif self.verify_fingerprints:
            cid = trace_content_id(trace)
            if cid != recorded:
                raise RuntimeError(
                    f"declared fingerprint {tid} of "
                    f"{getattr(source, 'workload_name', source)!r} is stale: "
                    f"trace content hash {cid} != recorded {recorded} — "
                    "bump the generator version"
                )

    def _reuse_distances(self, tid: str, trace: LabeledTrace, line: int):
        key = (tid, line)
        if self.cache_enabled and key in self._rd:
            return self._rd[key]
        from repro.core.reuse.distance import reuse_distances

        self.stats.rd_builds += 1
        rd = reuse_distances(trace.addresses, line)
        if self.cache_enabled:
            self._rd[key] = rd
        return rd

    def _private_traces(self, tid: str, trace: LabeledTrace, cores: int):
        if cores == 1:
            return [trace]
        key = (tid, cores)
        if self.cache_enabled and key in self._privates:
            return self._privates[key]
        self.stats.mimic_builds += 1
        privs = self.builder.private_traces(trace, cores)
        if self.cache_enabled:
            self._privates[key] = privs
        return privs

    def _shared_trace(self, tid: str, privs, cores: int, strategy: str,
                      seed: int):
        key = (tid, cores, strategy, seed)
        if self.cache_enabled and key in self._shared:
            return self._shared[key]
        self.stats.interleave_builds += 1
        shared = self.builder.interleave(privs, strategy, seed)
        if self.cache_enabled:
            self._shared[key] = shared
        return shared

    def _resolve_window(self, window_size: int | None) -> int | None:
        """Explicit override > session default > builder default."""
        if window_size is not None:
            return window_size or None  # 0 forces the in-memory path
        if self.window_size is not None:
            return self.window_size or None  # normalized: one cache key
        return getattr(self.builder, "window_size", None)

    def _builder_for(self, sampled: float | None):
        """The Session builder, or a cached sampled-rate variant when a
        per-request rate overrides it (``PredictionRequest.sampled_rate``).
        Variants share nothing but the store — their fingerprints embed
        the rate, so store keys never collide across rates."""
        if sampled is None:
            return self.builder
        rate = float(sampled)
        if getattr(self.builder, "sampled", None) == rate:
            return self.builder
        if not hasattr(self.builder, "with_sampled"):
            raise ValueError(
                "per-request sampled_rate needs a profile builder with "
                "with_sampled support (the default MimicProfileBuilder)"
            )
        variant = self._sampled_builders.get(rate)
        if variant is None:
            variant = self.builder.with_sampled(rate)
            self._sampled_builders[rate] = variant
        return variant

    def artifacts(self, source, cores: int, *, strategy: str = "round_robin",
                  seed: int = 0, line_size: int = 64,
                  window_size: int | None = None,
                  sampled: float | None = None,
                  need_traces: bool = False) -> ProfileArtifacts:
        """PRD/CRD profiles (+ underlying traces) for one grid cell.

        ``window_size`` (or the Session/builder default) routes the
        reuse-distance passes through the streaming layer: bit-identical
        profiles, peak scan memory bounded by the window + working set,
        and the interleaved shared trace never materialized (for the
        deterministic strategies) — ``artifacts.shared`` is ``None``.

        ``sampled`` overrides the builder's sampling rate for this cell
        (``None`` keeps the builder mode — exact unless the Session was
        built with ``sampled=R``); the cell caches and store keys embed
        the effective rate, so exact and sampled artifacts coexist.

        ``need_traces`` guarantees the returned artifact carries the
        mimicked private/shared traces: profile cells served from the
        disk store arrive trace-less (only the histograms persist) and
        are rematerialized through the stage caches for trace-consuming
        models (ExactLRU ground truth).
        """
        ws = self._resolve_window(window_size)
        builder = self._builder_for(sampled)
        rate = getattr(builder, "sampled", None)
        if self.cache_enabled:
            # id only — the trace is materialized lazily, so cells
            # served from memory/disk never build it (store hits cost
            # zero trace builds)
            tid = self.identify(source)
            trace = None
        else:
            tid, trace = self.load(source)
        key = (tid, line_size, cores, strategy, seed, ws, rate)
        if self.cache_enabled and key in self._profiles:
            self.stats.profile_hits += 1
            art = self._profiles[key]
            if need_traces and not art.privates:
                art = self._materialize_traces(
                    art, self._trace_of(tid, source)
                )
                self._profiles[key] = art
            return art
        if self.cache_enabled and self.store is not None:
            from repro.validate.store import (
                builder_fingerprint,
                load_profile_artifacts,
            )

            art = load_profile_artifacts(
                self.store, tid, line_size, cores, strategy, seed, ws,
                builder_fingerprint(builder),
            )
            if art is not None:
                self.stats.store_hits += 1
                if need_traces:
                    art = self._materialize_traces(
                        art, self._trace_of(tid, source)
                    )
                self._profiles[key] = art
                return art
        if trace is None:
            trace = self._trace_of(tid, source)
        binned = bool(getattr(builder, "binned", False))
        if ws:
            art = self._streaming_artifacts(
                tid, trace, cores, strategy, seed, line_size, ws, builder
            )
        elif cores == 1:
            if rate is not None:
                # sampled cells bypass the exact-rd cache entirely: the
                # builder hash-filters the trace itself
                prof = builder.profile(trace, line_size)
            else:
                rds = self._reuse_distances(tid, trace, line_size)
                if hasattr(builder, "profile_of_distances"):
                    prof = builder.profile_of_distances(rds)
                else:
                    prof = profile_from_distances(rds)
            art = ProfileArtifacts(
                trace_id=tid, cores=1, strategy=strategy, seed=seed,
                line_size=line_size, privates=[trace], shared=trace,
                prd=prof, crd=prof, binned=binned, sampled=rate,
            )
        else:
            privs = self._private_traces(tid, trace, cores)
            shared = self._shared_trace(tid, privs, cores, strategy, seed)
            # PRD of the master core (cores are symmetric by construction)
            prd = builder.profile(privs[0], line_size)
            crd = builder.profile(shared, line_size)
            art = ProfileArtifacts(
                trace_id=tid, cores=cores, strategy=strategy, seed=seed,
                line_size=line_size, privates=privs, shared=shared,
                prd=prd, crd=crd, binned=binned, sampled=rate,
            )
        self.stats.profile_builds += 1
        if self.cache_enabled:
            self._profiles[key] = art
            if self.store is not None:
                from repro.validate.store import (
                    builder_fingerprint,
                    save_profile_artifacts,
                )

                save_profile_artifacts(
                    self.store, art, builder_fingerprint(builder)
                )
                self.stats.store_puts += 1
        return art

    def _materialize_traces(self, art: ProfileArtifacts,
                            trace: LabeledTrace) -> ProfileArtifacts:
        """Re-attach mimicked traces to a store-loaded (trace-less)
        profile cell.  Mimicry/interleaving are cheap O(N) rebuilds and
        go through the stage caches; the expensive profile passes are
        NOT rerun.  Streaming cells keep ``shared=None`` (the
        interleaved trace is never materialized on that path)."""
        if art.cores == 1:
            return dataclasses.replace(art, privates=[trace], shared=trace)
        privs = self._private_traces(art.trace_id, trace, art.cores)
        shared = art.shared
        if shared is None and not art.window_size:
            shared = self._shared_trace(
                art.trace_id, privs, art.cores, art.strategy, art.seed
            )
        return dataclasses.replace(art, privates=privs, shared=shared)

    def _streaming_artifacts(self, tid, trace, cores, strategy, seed,
                             line_size, ws, builder=None) -> ProfileArtifacts:
        """Window-bounded cell build (ISSUE-2 tentpole).

        Uses the builder's streaming hooks when present (the default
        ``MimicProfileBuilder`` provides them); a custom builder without
        them falls back to its own in-memory stages.
        """
        self.stats.streaming_builds += 1
        builder = builder if builder is not None else self.builder
        binned = bool(getattr(builder, "binned", False))
        rate = getattr(builder, "sampled", None)
        if hasattr(builder, "profile_windows"):
            def stream_profile(t, line):
                return builder.profile_windows(t, line, ws)
        else:  # custom builder without streaming hooks: its own stages
            def stream_profile(t, line):
                return builder.profile(t, line)
        if cores == 1:
            prof = stream_profile(trace, line_size)
            return ProfileArtifacts(
                trace_id=tid, cores=1, strategy=strategy, seed=seed,
                line_size=line_size, privates=[trace], shared=trace,
                prd=prof, crd=prof, window_size=ws, binned=binned,
                sampled=rate,
            )
        privs = self._private_traces(tid, trace, cores)
        prd = stream_profile(privs[0], line_size)
        if (
            strategy in ("round_robin", "chunked")
            and hasattr(builder, "shared_profile")
        ):
            crd, shared = builder.shared_profile(
                privs, strategy, seed, line_size, ws
            )
        else:
            # uniform (or a builder without streaming hooks) needs the
            # materialized interleave: go through the Session cache so
            # it is built once across line sizes/targets
            shared = self._shared_trace(tid, privs, cores, strategy, seed)
            crd = stream_profile(shared, line_size)
        return ProfileArtifacts(
            trace_id=tid, cores=cores, strategy=strategy, seed=seed,
            line_size=line_size, privates=privs, shared=shared,
            prd=prd, crd=crd, window_size=ws, binned=binned,
            sampled=rate,
        )

    # --- execution --------------------------------------------------------

    def predict(self, source, request: PredictionRequest) -> PredictionSet:
        """Execute the full grid; hit rates evaluated in one batched
        call when the cache model supports grids."""
        return self.predict_many([(source, request)])[0]

    def predict_many(
        self, items: list[tuple[object, PredictionRequest]]
    ) -> list[PredictionSet]:
        """Execute many independent (source, request) pairs with ONE
        cache-model grid evaluation across all of them.

        This is the coalescible surface the prediction service batches
        through (:mod:`repro.service`): every grid cell of every request
        is gathered (profiles served from the Session caches / disk
        store as usual) and the whole union goes to
        ``cache_model.hit_rates_grid`` — with the batched SDCM backend
        that is a single vmapped, jitted kernel call for N requests
        instead of N per-request loops.  Results are fanned back out in
        input order, bit-identical to ``[predict(s, r) for s, r in
        items]``.
        """
        need_traces = bool(getattr(self.cache_model, "needs_traces", False))
        plans = []
        flat: list[tuple[object, ProfileArtifacts]] = []
        for source, request in items:
            tid = self.identify(source)
            cells = list(request.cells())
            if not cells:
                raise ValueError(
                    f"request matched no grid cells: {request.describe()}"
                )
            arts = [
                self.artifacts(
                    source, cell.cores, strategy=cell.strategy,
                    seed=request.seed,
                    line_size=cell.target.levels[0].line_size,
                    window_size=request.window_size,
                    sampled=request.sampled_rate,
                    need_traces=need_traces,
                )
                for cell in cells
            ]
            plans.append((tid, request, cells, arts))
            flat.extend((cell.target, art) for cell, art in zip(cells, arts))

        from repro.api import batched

        compiled_before = batched.compile_count()
        if hasattr(self.cache_model, "hit_rates_grid"):
            rate_dicts = self.cache_model.hit_rates_grid(flat)
        else:
            rate_dicts = [
                self.cache_model.hit_rates(t, a) for t, a in flat
            ]
        self.stats.kernel_compiles += (
            batched.compile_count() - compiled_before
        )

        out: list[PredictionSet] = []
        offset = 0
        for tid, request, cells, arts in plans:
            rates_slice = rate_dicts[offset:offset + len(cells)]
            offset += len(cells)
            out.append(
                self._assemble(tid, request, cells, arts, rates_slice)
            )
        return out

    def _assemble(self, tid, request, cells, arts, rate_dicts
                  ) -> PredictionSet:
        predictions = []
        for cell, art, rates in zip(cells, arts, rate_dicts):
            timing = {}
            rt = None
            if request.counts is not None:
                # precedence: per-request named model > the Session's
                # injected stage > the target's default
                if request.runtime_model is not None:
                    rt = resolve_runtime_model(
                        request.runtime_model, cell.target
                    )
                else:
                    rt = self.runtime_model or default_runtime_model(
                        cell.target
                    )
                timing = rt.runtime(
                    cell.target, rates, request.counts, cell.cores,
                    mode=cell.mode, gap_bytes=request.gap_bytes,
                )
            predictions.append(
                CellPrediction(
                    target=cell.target.name,
                    cores=cell.cores,
                    strategy=cell.strategy,
                    mode=cell.mode,
                    hit_rates=rates,
                    t_pred_s=timing.get("t_pred_s"),
                    t_mem_s=timing.get("t_mem_s"),
                    t_cpu_s=timing.get("t_cpu_s"),
                    runtime_model=getattr(rt, "name", None) if rt else None,
                    private_profile=art.prd if request.keep_profiles else None,
                    shared_profile=art.crd if request.keep_profiles else None,
                )
            )
        return PredictionSet(
            predictions,
            cache_model=getattr(self.cache_model, "name", "custom"),
            trace_id=tid,
        )

    # --- single-cell conveniences ----------------------------------------

    def hit_rates(self, source, target, cores: int, *,
                  strategy: str = "round_robin", seed: int = 0
                  ) -> dict[str, float]:
        target = resolve_target(target)
        art = self.artifacts(
            source, cores, strategy=strategy, seed=seed,
            line_size=target.levels[0].line_size,
        )
        return self.cache_model.hit_rates(target, art)

    def ground_truth_hit_rates(self, source, target, cores: int, *,
                               strategy: str = "round_robin", seed: int = 0
                               ) -> dict[str, float]:
        """Exact-LRU simulation through the same stage interface.

        ExactLRU simulates the materialized traces, so this always
        builds in-memory artifacts (``window_size=0``) — it works on a
        streaming Session too, cached under the in-memory key.
        """
        target = resolve_target(target)
        art = self.artifacts(
            source, cores, strategy=strategy, seed=seed,
            line_size=target.levels[0].line_size, window_size=0,
            need_traces=True,
        )
        return ExactLRU().hit_rates(target, art)
