"""Declarative grid requests: the whole paper-style sweep in one object.

A :class:`PredictionRequest` names targets x core counts x interleave
strategies x runtime modes; :meth:`cells` enumerates the concrete grid
(dropping core counts a target doesn't have).  The Session executes it
with every intermediate artifact computed exactly once — the paper's
"one trace, every configuration" claim as an API invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

from repro.core.runtime_model import OpCounts
from repro.hw.targets import resolve_target


@dataclasses.dataclass(frozen=True)
class GridCell:
    """One concrete point of the request grid."""

    target: object
    cores: int
    strategy: str
    mode: str

    @property
    def key(self) -> tuple:
        return (self.target.name, self.cores, self.strategy, self.mode)


@dataclasses.dataclass(frozen=True)
class PredictionRequest:
    """Declarative spec for a prediction sweep.

    ``targets`` accepts registry names (``"i7-5960X"``, ``"tpu-v5e"``)
    or target objects.  ``counts`` enables the stage-4 runtime model;
    without it the request predicts hit rates only.
    """

    targets: tuple = ()
    core_counts: tuple[int, ...] = (1,)
    strategies: tuple[str, ...] = ("round_robin",)
    modes: tuple[str, ...] = ("throughput",)
    counts: OpCounts | None = None
    # stage-4 model by registry name ("eq" / "ecm" / "roofline");
    # None/"auto" keeps each target's default (repro.api.stages)
    runtime_model: str | None = None
    seed: int = 0
    gap_bytes: float = 0.0
    keep_profiles: bool = False
    # drop grid cells asking for more cores than the target has
    respect_core_limit: bool = True
    # route reuse-distance passes through the streaming layer with this
    # window (bit-identical profiles, O(window + working set) memory);
    # None defers to the Session/builder default, 0 forces in-memory
    window_size: int | None = None
    # build SHARDS-sampled profiles at this rate (0 < R <= 1) instead
    # of exact histograms — constant memory, declared error_bound on
    # each profile; None defers to the Session/builder mode
    sampled_rate: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "targets", tuple(self.targets))
        object.__setattr__(
            self, "core_counts", tuple(int(c) for c in self.core_counts)
        )
        object.__setattr__(self, "strategies", tuple(self.strategies))
        object.__setattr__(self, "modes", tuple(self.modes))
        if not self.targets:
            raise ValueError("PredictionRequest needs at least one target")
        if any(c < 1 for c in self.core_counts):
            raise ValueError("core counts must be >= 1")
        if self.window_size is not None and self.window_size < 0:
            raise ValueError("window_size must be >= 0 (0 = in-memory)")
        if self.sampled_rate is not None:
            rate = float(self.sampled_rate)
            if not (0.0 < rate <= 1.0):
                raise ValueError(
                    f"sampled_rate must be in (0, 1], got {self.sampled_rate!r}"
                )
            object.__setattr__(self, "sampled_rate", rate)
        if self.runtime_model is not None:
            # validate both the name and every target pairing up front —
            # a bad request fails at build time, not mid-grid
            from repro.api.stages import resolve_runtime_model

            for target in self.targets:
                resolve_runtime_model(self.runtime_model, target)

    def resolved_targets(self) -> list:
        return [resolve_target(t) for t in self.targets]

    def cells(self) -> Iterator[GridCell]:
        for target in self.resolved_targets():
            limit = getattr(target, "cores", None)
            for cores in self.core_counts:
                if (
                    self.respect_core_limit
                    and limit is not None
                    and cores > limit
                ):
                    continue
                for strategy in self.strategies:
                    for mode in self.modes:
                        yield GridCell(target, cores, strategy, mode)

    def describe(self) -> str:
        names = [resolve_target(t).name for t in self.targets]
        return (
            f"{len(names)} target(s) {names} x cores {list(self.core_counts)}"
            f" x strategies {list(self.strategies)}"
            f" x modes {list(self.modes)}"
        )
