"""repro.api — the unified prediction pipeline.

    from repro.api import PredictionRequest, Session

    session = Session()
    request = PredictionRequest(
        targets=("i7-5960X", "Xeon E5-2699 v4", "EPYC 7702P"),
        core_counts=(1, 2, 4, 8),
        counts=workload.op_counts,
    )
    result = session.predict(workload, request)
    print(result.to_table())

One trace in; the whole (target x cores x strategy x mode) grid out,
with every reuse profile computed exactly once (``session.stats``).

Orthogonal knobs layered on the same Session:

* ``Session(artifact_dir=...)`` puts a disk-backed, content-hash-keyed
  :class:`repro.validate.store.ArtifactStore` under the in-memory
  caches, so profiles persist across processes and runs
  (``stats.store_hits`` / ``stats.store_puts``).
* ``Session(window_size=...)`` routes the reuse-distance passes
  through the streaming layer — bit-identical profiles with peak scan
  memory bounded by O(window + working set) instead of O(trace)
  (docs/streaming.md).
* ``Session.predict_many`` evaluates many independent requests with
  one cache-model grid call — the coalescible surface the concurrent
  prediction service (:mod:`repro.service`, docs/service.md)
  microbatches through.

The legacy ``repro.core.predictor.PPTMulticorePredictor`` is a
deprecated shim over this package (docs/api_migration.md).
"""
from repro.api.request import GridCell, PredictionRequest
from repro.api.results import CellPrediction, PredictionSet
from repro.api.session import Session, SessionStats
from repro.core.trace.types import ChunkedTraceSource
from repro.api.stages import (
    AnalyticalSDCM,
    ArrayTraceSource,
    CacheModel,
    ECMRuntimeModel,
    EqRuntimeModel,
    ExactLRU,
    MimicProfileBuilder,
    ProfileArtifacts,
    ProfileBuilder,
    RUNTIME_MODELS,
    RooflineRuntimeModel,
    RuntimeModel,
    Target,
    TraceSource,
    resolve_runtime_model,
    supported_runtime_models,
    trace_content_id,
)

__all__ = [
    "AnalyticalSDCM",
    "ArrayTraceSource",
    "CacheModel",
    "ChunkedTraceSource",
    "CellPrediction",
    "ECMRuntimeModel",
    "EqRuntimeModel",
    "ExactLRU",
    "GridCell",
    "MimicProfileBuilder",
    "PredictionRequest",
    "PredictionSet",
    "ProfileArtifacts",
    "ProfileBuilder",
    "RUNTIME_MODELS",
    "RooflineRuntimeModel",
    "RuntimeModel",
    "Session",
    "SessionStats",
    "Target",
    "TraceSource",
    "resolve_runtime_model",
    "supported_runtime_models",
    "trace_content_id",
]
