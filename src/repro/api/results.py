"""Structured sweep results: filterable, tabulable, serializable."""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator

from repro.core.reuse.profile import ReuseProfile


@dataclasses.dataclass
class CellPrediction:
    """Prediction for one grid cell (hit rates always; runtime when the
    request carried op counts)."""

    target: str
    cores: int
    strategy: str
    mode: str
    hit_rates: dict[str, float]
    t_pred_s: float | None = None
    t_mem_s: float | None = None
    t_cpu_s: float | None = None
    # which stage-4 model produced the runtime fields ("eq"/"ecm"/...)
    runtime_model: str | None = None
    private_profile: ReuseProfile | None = None
    shared_profile: ReuseProfile | None = None

    def to_record(self) -> dict:
        rec = {
            "target": self.target,
            "cores": self.cores,
            "strategy": self.strategy,
            "mode": self.mode,
            "hit_rates": dict(self.hit_rates),
        }
        if self.t_pred_s is not None:
            rec.update(
                t_pred_s=self.t_pred_s,
                t_mem_s=self.t_mem_s,
                t_cpu_s=self.t_cpu_s,
                runtime_model=self.runtime_model,
            )
        return rec


@dataclasses.dataclass
class PredictionSet:
    """The executed grid: an ordered collection of cell predictions."""

    predictions: list[CellPrediction]
    cache_model: str = "sdcm"
    trace_id: str = ""

    def __iter__(self) -> Iterator[CellPrediction]:
        return iter(self.predictions)

    def __len__(self) -> int:
        return len(self.predictions)

    def select(self, *, target: str | None = None, cores: int | None = None,
               strategy: str | None = None, mode: str | None = None
               ) -> "PredictionSet":
        """Filter by any subset of grid coordinates."""
        keep = [
            p for p in self.predictions
            if (target is None or p.target == target)
            and (cores is None or p.cores == cores)
            and (strategy is None or p.strategy == strategy)
            and (mode is None or p.mode == mode)
        ]
        return PredictionSet(keep, self.cache_model, self.trace_id)

    def one(self, **kw) -> CellPrediction:
        sel = self.select(**kw).predictions
        if len(sel) != 1:
            raise LookupError(f"expected exactly one cell for {kw}, "
                              f"got {len(sel)}")
        return sel[0]

    def to_records(self) -> list[dict]:
        return [p.to_record() for p in self.predictions]

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(
            {
                "cache_model": self.cache_model,
                "trace_id": self.trace_id,
                "predictions": self.to_records(),
            },
            indent=indent,
            default=float,
        )

    def to_table(self) -> str:
        """Fixed-width benchmark table, one row per grid cell."""
        level_names: list[str] = []
        for p in self.predictions:
            for name in p.hit_rates:
                if name not in level_names:
                    level_names.append(name)
        has_runtime = any(p.t_pred_s is not None for p in self.predictions)
        headers = ["target", "cores", "strategy"]
        if len({p.mode for p in self.predictions}) > 1:
            headers.append("mode")
        headers += [f"P(h) {n}" for n in level_names]
        if has_runtime:
            headers += ["T_pred", "T_mem", "T_cpu"]
        rows = []
        for p in self.predictions:
            row = [p.target, p.cores, p.strategy]
            if "mode" in headers:
                row.append(p.mode)
            row += [
                f"{p.hit_rates[n]:.4f}" if n in p.hit_rates else "-"
                for n in level_names
            ]
            if has_runtime:
                row += [
                    f"{v:.3e}" if v is not None else "-"
                    for v in (p.t_pred_s, p.t_mem_s, p.t_cpu_s)
                ]
            rows.append(row)
        widths = [
            max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
            for i, h in enumerate(headers)
        ]

        def line(cells):
            return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))

        out = [line(headers), line(["-" * w for w in widths])]
        out.extend(line(r) for r in rows)
        return "\n".join(out)
