"""Pluggable pipeline stages (paper Fig. 1, made first-class).

The prediction pipeline is four swappable stages behind protocols:

    TraceSource   -> one labeled sequential trace (+ stable content id)
    ProfileBuilder-> PRD/CRD reuse profiles for (cores, strategy, seed)
    CacheModel    -> per-level hit rates from the profile artifacts
    RuntimeModel  -> T_pred from hit rates + op counts (Eq. 4-7 or
                     a roofline for accelerator targets)

Both the analytical SDCM and the exact-LRU simulator implement
``CacheModel``, so a benchmark comparing prediction against ground
truth is two models run through one :class:`repro.api.Session` — and
the TPU's VMEM level goes through the SAME SDCM path as the CPU
hierarchies (``TPUTarget.levels`` is one fully-associative level).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import ClassVar, Protocol, runtime_checkable

import numpy as np

from repro.core import sdcm
from repro.core.cachesim import simulate_hierarchy
from repro.core.levels import CacheLevelConfig
from repro.core.reuse.distance import (
    reuse_distance_windows,
    reuse_distances,
)
from repro.core.reuse.profile import (
    ReuseProfile,
    profile_from_distances,
    profile_from_distances_incremental,
)
from repro.core.incore import ECMRuntimeModel, miss_fractions
from repro.core.runtime_model import OpCounts, predict_runtime_s
from repro.hw.targets import resolve_target
from repro.core.trace.interleave import interleave_traces, interleave_windows
from repro.core.trace.mimic import gen_private_traces
from repro.core.trace.types import LabeledTrace


# --- targets -----------------------------------------------------------------


@runtime_checkable
class Target(Protocol):
    """Anything with a cache hierarchy: CPUTarget and TPUTarget both
    satisfy this structurally — there is no accelerator-specific fork
    in the pipeline."""

    name: str

    @property
    def levels(self) -> tuple[CacheLevelConfig, ...]: ...


def shared_level_index(target) -> int:
    return getattr(target, "shared_level", -1) % len(target.levels)


# --- trace sources -----------------------------------------------------------


@runtime_checkable
class TraceSource(Protocol):
    """Stage 1: produce the labeled sequential trace once."""

    def trace(self) -> LabeledTrace: ...


def trace_content_id(trace: LabeledTrace) -> str:
    """Stable content hash of a materialized trace.

    Roots the artifact-cache keys for plain sources; registry-resolved
    sources carry a *declared fingerprint* instead (computable without
    the trace — see ``repro.workloads.registry``), and this hash then
    serves only as the ``verify_fingerprints`` cross-check.
    """
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(trace.addresses).tobytes())
    h.update(np.ascontiguousarray(trace.bb_ids).tobytes())
    h.update(np.ascontiguousarray(trace.shared_mask).tobytes())
    return h.hexdigest()[:16]


@dataclass
class ArrayTraceSource:
    """Wrap an in-memory trace as a TraceSource."""

    _trace: LabeledTrace
    name: str = "trace"

    def trace(self) -> LabeledTrace:
        return self._trace


def as_trace_source(obj) -> TraceSource:
    """Coerce a LabeledTrace / Workload / TraceSource uniformly."""
    if isinstance(obj, LabeledTrace):
        return ArrayTraceSource(obj)
    if hasattr(obj, "trace") and callable(obj.trace):
        return obj  # Workload and any TraceSource qualify
    raise TypeError(f"cannot interpret {type(obj).__name__} as a TraceSource")


# --- profile artifacts -------------------------------------------------------


@dataclass
class ProfileArtifacts:
    """Everything derived from one (trace, cores, strategy, seed, line)
    cell — cached by Session so it is computed exactly once.

    ``shared`` is ``None`` when the cell was built through the streaming
    path (``window_size`` set): the interleaved trace is scanned window
    by window and never materialized.  Profile consumers (SDCM, batched
    SDCM) only read ``prd``/``crd``; trace consumers (ExactLRU) require
    the in-memory path.

    Cells loaded from a disk :class:`repro.validate.store.ArtifactStore`
    carry only the profiles (``privates == []``, ``shared is None``) —
    the Session rematerializes the traces on demand
    (``Session.artifacts(..., need_traces=True)``).
    """

    trace_id: str
    cores: int
    strategy: str
    seed: int
    line_size: int
    privates: list[LabeledTrace]
    shared: LabeledTrace | None
    prd: ReuseProfile
    crd: ReuseProfile
    window_size: int | None = None
    # True when prd/crd are device-binned log2 profiles (the fused
    # kernels/reuse_hist path) rather than exact histograms
    binned: bool = False
    # sampling rate when prd/crd are SHARDS-sampled estimates
    # (core.reuse.sampled); the profiles then carry ``error_bound``
    sampled: float | None = None

    @property
    def has_traces(self) -> bool:
        """Whether the mimicked traces are attached (False for cells
        deserialized from the disk store)."""
        return bool(self.privates)


class ProfileBuilder(Protocol):
    """Stage 2: trace -> mimicked traces -> PRD/CRD profiles."""

    def private_traces(
        self, trace: LabeledTrace, cores: int
    ) -> list[LabeledTrace]: ...

    def interleave(
        self, privates: list[LabeledTrace], strategy: str, seed: int
    ) -> LabeledTrace: ...

    def profile(self, trace: LabeledTrace, line_size: int) -> ReuseProfile: ...


class MimicProfileBuilder:
    """Default builder: Algorithm 1 + Algorithm 2 + the Fenwick-tree
    reuse-distance pass, exactly the paper's pipeline.

    ``window_size`` routes profile construction through the streaming
    layer (chunked Fenwick scan + incremental histogram accumulation):
    bit-identical profiles, peak memory bounded by the window and the
    working set instead of the trace length.  ``None`` (the default)
    keeps the monolithic in-memory pass — the oracle the streaming path
    is tested against.

    ``binned=True`` switches profile construction to the fused
    device-binned path (:mod:`repro.core.reuse.fused`): the distance
    stream feeds the ``kernels/reuse_hist`` Pallas histogram on device
    and the profile is log2-binned (the kernel's bin layout, with
    weighted-mean bin representatives).  SDCM hit rates from binned
    profiles track the exact profiles to well under 1e-3 absolute
    (asserted by the validation runner); the exact host path stays the
    default oracle.

    ``sampled=R`` (0 < R <= 1) switches to SHARDS-style spatially-
    hashed sampled profiles (:mod:`repro.core.reuse.sampled`): constant
    memory at any trace length, with the declared DKW error bound
    attached as ``profile.error_bound`` — ``repro.validate`` gates
    SDCM deviation against it.  ``sampled`` and ``binned`` are
    mutually exclusive profile modes; ``R == 1.0`` reproduces the
    exact histograms bit for bit.
    """

    window_size: int | None = None  # class defaults: subclasses with
    binned: bool = False            # bare __init__ (test
    sampled: float | None = None    # instrumentation) still resolve them
    sample_seed: int = 0            # spatial-hash key (fixed by default
    # so sampled cells are deterministic and store keys stay stable)

    def __init__(self, window_size: int | None = None,
                 binned: bool = False, sampled: float | None = None):
        if sampled is not None:
            if binned:
                raise ValueError(
                    "binned and sampled are mutually exclusive profile "
                    "modes — pick one approximate representation"
                )
            if not (0.0 < float(sampled) <= 1.0):
                raise ValueError(
                    f"sampled rate must be in (0, 1], got {sampled!r}"
                )
            sampled = float(sampled)
        self.window_size = window_size
        self.binned = binned
        self.sampled = sampled

    @property
    def store_fingerprint(self) -> str:
        """Disk-store identity: binned/sampled cells must never be
        confused with exact cells (or with each other, or with a
        different rate), so approximate builders stamp their keys."""
        base = f"{type(self).__module__}.{type(self).__qualname__}"
        if self.binned:
            base += "+binned"
        if self.sampled is not None:
            base += f"+sampled{self.sampled:g}"
            if self.sample_seed:
                base += f"@{self.sample_seed}"
        return base

    def with_sampled(self, rate: float | None) -> "MimicProfileBuilder":
        """Variant builder at a different sampling rate (Session uses
        this for per-request ``sampled_rate`` overrides)."""
        if rate == self.sampled:
            return self
        return MimicProfileBuilder(
            window_size=self.window_size, sampled=rate
        )

    def private_traces(self, trace, cores):
        return gen_private_traces(trace, cores)

    def interleave(self, privates, strategy, seed):
        return interleave_traces(privates, strategy, seed=seed)

    def profile(self, trace, line_size):
        if self.window_size:
            return self.profile_windows(trace, line_size)
        if self.sampled is not None:
            from repro.core.reuse.sampled import sampled_reuse_profile

            return sampled_reuse_profile(
                trace.addresses, line_size,
                rate=self.sampled, seed=self.sample_seed,
            )
        return self.profile_of_distances(
            reuse_distances(trace.addresses, line_size)
        )

    def profile_of_distances(self, rds) -> ReuseProfile:
        """Distances -> profile under the builder's histogram mode."""
        if self.binned:
            from repro.core.reuse.fused import binned_profile_from_distances

            return binned_profile_from_distances(rds)
        return profile_from_distances(rds)

    def profile_windows(
        self, source, line_size, window_size: int | None = None
    ) -> ReuseProfile:
        """Streaming profile of any window source (``LabeledTrace``,
        ``ChunkedTraceSource``, or an iterator of windows).
        ``window_size`` overrides the builder default for this call."""
        ws = window_size if window_size is not None else (self.window_size or 0)
        if ws < 1:
            raise ValueError("profile_windows needs window_size >= 1")
        if self.sampled is not None:
            from repro.core.reuse.sampled import sampled_profile_windows

            return sampled_profile_windows(
                source, line_size, rate=self.sampled,
                seed=self.sample_seed, window_size=ws,
            )
        if self.binned:
            from repro.core.reuse.fused import binned_profile_windows

            return binned_profile_windows(source, line_size, window_size=ws)
        return profile_from_distances_incremental(
            reuse_distance_windows(source, line_size, window_size=ws)
        )

    def shared_profile(
        self, privates, strategy: str, seed: int, line_size: int,
        window_size: int | None = None,
    ) -> tuple[ReuseProfile, LabeledTrace | None]:
        """CRD profile of the interleaved trace.

        Streaming mode merges per-core windows and scans them directly
        — the shared trace is never concatenated (returned trace is
        ``None``).  The ``uniform`` strategy needs the global random
        choice sequence, so it interleaves in memory first and streams
        only the reuse-distance pass.
        """
        ws = window_size if window_size is not None else self.window_size
        if ws and strategy in ("round_robin", "chunked"):
            wins = interleave_windows(
                privates, strategy, window_size=ws, seed=seed
            )
            return self.profile_windows(wins, line_size, ws), None
        shared = self.interleave(privates, strategy, seed)
        if ws:
            return self.profile_windows(shared, line_size, ws), shared
        return self.profile(shared, line_size), shared


# --- cache models ------------------------------------------------------------


class CacheModel(Protocol):
    """Stage 3: per-level cumulative hit rates for one target."""

    name: str

    def hit_rates(self, target, artifacts: ProfileArtifacts) -> dict[str, float]: ...


@dataclass
class AnalyticalSDCM:
    """Brehob–Enbody SDCM (paper Eq. 1–3) over the PRD/CRD profiles.

    ``backend="numpy"`` evaluates each level with the float64 oracle
    (bit-identical to the legacy predictor); ``backend="batched"``
    routes grids through the padded, vmapped JAX kernel in
    :mod:`repro.api.batched` — one jitted call for the whole
    (target x level x cores) grid.
    """

    backend: str = "numpy"
    name: str = field(default="sdcm", init=False)

    def __post_init__(self):
        if self.backend not in ("numpy", "batched"):
            raise ValueError(f"unknown SDCM backend: {self.backend}")

    def hit_rates(self, target, artifacts: ProfileArtifacts) -> dict[str, float]:
        (out,) = self.hit_rates_grid([(target, artifacts)])
        return out

    def hit_rates_grid(
        self, items: list[tuple[object, ProfileArtifacts]]
    ) -> list[dict[str, float]]:
        """Evaluate many (target, artifacts) cells; the batched backend
        folds every level of every cell into one jitted SDCM call."""
        if self.backend == "batched":
            from repro.api.batched import batched_hit_rates

            return batched_hit_rates(items)
        out = []
        for target, art in items:
            shared_idx = shared_level_index(target)
            rates = {}
            for i, lvl in enumerate(target.levels):
                prof = art.crd if i >= shared_idx else art.prd
                rates[lvl.name] = sdcm.hit_rate(
                    prof, lvl.effective_assoc, lvl.num_lines
                )
            out.append(rates)
        return out


@dataclass
class ExactLRU:
    """Ground-truth stage-3 model: exact set-associative LRU simulation
    of the same mimicked traces (the container's PAPI stand-in).  Same
    interface as the analytical model, so benchmarks swap it in.

    Private levels aggregate per-core simulations (every core runs its
    own hierarchy).  Shared levels follow the paper's Table-6
    convention — the interleaved trace through one inclusive hierarchy,
    mirroring the CRD profile the SDCM path consumes — which models the
    upstream filter as a single cache; a per-core-filtered miss-stream
    merge is a different (finer) model than the paper validates
    against.
    """

    name: str = field(default="exact-lru", init=False)
    # tells Session.predict to materialize the mimicked traces even for
    # profile cells served from the disk store
    needs_traces: ClassVar[bool] = True

    def hit_rates(self, target, artifacts: ProfileArtifacts) -> dict[str, float]:
        shared_idx = shared_level_index(target)
        levels = list(target.levels)
        if not artifacts.has_traces:
            raise ValueError(
                "ExactLRU simulates the materialized traces, but this "
                "artifact carries only profiles (loaded from the disk "
                "store) — request it with need_traces=True"
            )
        if artifacts.cores == 1:
            res = simulate_hierarchy(artifacts.privates[0].addresses, levels)
            return {r.name: r.cumulative_hit_rate for r in res}
        if artifacts.shared is None:
            raise ValueError(
                "ExactLRU simulates the materialized traces; streaming "
                "artifacts (window_size set) keep no shared trace — use "
                "an in-memory Session for ground truth"
            )
        out: dict[str, float] = {}
        # private levels: every core runs its own hierarchy; the Table-6
        # cumulative metric aggregates misses over ALL cores' accesses
        # (core 0 alone is only correct for symmetric traces)
        priv_levels = levels[:shared_idx]
        if priv_levels:
            total = sum(len(p) for p in artifacts.privates)
            misses = np.zeros(len(priv_levels), dtype=np.int64)
            for priv in artifacts.privates:
                for i, r in enumerate(
                    simulate_hierarchy(priv.addresses, priv_levels)
                ):
                    misses[i] += r.accesses - r.hits
            for i, lvl in enumerate(priv_levels):
                out[lvl.name] = 1.0 - misses[i] / max(total, 1)
        res_shared = simulate_hierarchy(artifacts.shared.addresses, levels)
        for r, lvl in zip(res_shared, levels):
            out.setdefault(lvl.name, r.cumulative_hit_rate)
        return out


# --- runtime models ----------------------------------------------------------


class RuntimeModel(Protocol):
    """Stage 4: hit rates + op counts -> seconds."""

    def runtime(
        self,
        target,
        hit_rates: dict[str, float],
        counts: OpCounts,
        cores: int,
        *,
        mode: str = "throughput",
        gap_bytes: float = 0.0,
    ) -> dict[str, float]: ...


class EqRuntimeModel:
    """Paper Eq. 4–7 (T_mem latency/throughput chain + two-mode T_CPU)."""

    name = "eq"

    def runtime(self, target, hit_rates, counts, cores, *,
                mode="throughput", gap_bytes=0.0):
        ordered = [hit_rates[l.name] for l in target.levels]
        return predict_runtime_s(
            target, ordered, counts, cores, mode=mode, gap_bytes=gap_bytes
        )


def roofline_peak_flops(target) -> float:
    """Peak FLOP rate: the accelerator's declared peak, else the CPU's
    fully-issued FP pipes (freq / aggregate β_fp)."""
    peak = getattr(target, "peak_flops_bf16", None)
    if peak is not None:
        return peak
    return target.freq_hz / target.instr.beta_fp


def roofline_mem_bandwidth(target) -> float:
    """Sustained memory bandwidth (bytes/s): the accelerator's HBM
    figure, else the word-per-β_RAM stream of the Eq. 7 chain."""
    bw = getattr(target, "hbm_bandwidth", None)
    if bw is not None:
        return bw
    return target.word_bytes / (target.ram_beta_cy * target.cycle_s)


def roofline_miss_latency_s(target) -> float:
    """One un-hidden round trip to backing memory: the accelerator's
    declared on-chip latency, else the RAM latency of the Eq. 6 chain."""
    lat = getattr(target, "vmem_latency_s", None)
    if lat is not None:
        return lat
    return target.ram_latency_cy * target.cycle_s


class RooflineRuntimeModel:
    """Bandwidth/peak-FLOPs stage 4: on-chip hits are ~free, the
    traffic missing every cache level streams from backing memory at
    the target's sustained bandwidth; compute at the peak FLOP rate.
    ``mode`` picks the combiner: throughput-bound overlap (max) vs a
    serialized latency chain (sum).

    The accelerator reading (VMEM + HBM on the TPU) is unchanged; CPU
    and GPU targets reuse the same two-term model with peaks derived
    from their Eq. 4–7 parameters, which is what makes it the crude
    baseline the ECM model is gated against (``--runtime-gate``).
    """

    name = "roofline"

    def runtime(self, target, hit_rates, counts, cores, *,
                mode="throughput", gap_bytes=0.0):
        share = counts.scaled(1.0 / max(cores, 1))
        # levels are read by name, never dict order; a missing key is a
        # model-wiring bug — fail loudly like the Eq. 4-7 model does,
        # don't degrade to an all-miss estimate
        ordered = [hit_rates[lvl.name] for lvl in target.levels]
        miss_bytes = miss_fractions(ordered)[-1] * share.total_bytes
        t_mem = miss_bytes / roofline_mem_bandwidth(target)
        if miss_bytes > 0.0:  # no misses -> no memory round-trip to hide
            t_mem += roofline_miss_latency_s(target)
        t_cpu = share.fp_ops / roofline_peak_flops(target)
        t_pred = max(t_mem, t_cpu) if mode == "throughput" else t_mem + t_cpu
        return {"t_pred_s": t_pred, "t_mem_s": t_mem, "t_cpu_s": t_cpu}


def default_runtime_model(target) -> RuntimeModel:
    """CPU targets carry Eq. 4–7 instruction timings; targets exposing
    bandwidth/FLOP peaks instead get the roofline combiner."""
    if hasattr(target, "instr"):
        return EqRuntimeModel()
    return RooflineRuntimeModel()


#: Stage-4 registry: every runtime model addressable by name through
#: ``PredictionRequest(runtime_model=...)`` and the service's
#: ``/predict`` payload.  "auto" keeps the per-target default.
RUNTIME_MODELS: dict[str, type] = {
    "eq": EqRuntimeModel,
    "roofline": RooflineRuntimeModel,
    "ecm": ECMRuntimeModel,
}

RUNTIME_MODEL_NAMES = ("auto",) + tuple(RUNTIME_MODELS)


def supported_runtime_models(target) -> tuple[str, ...]:
    """Which named stage-4 models can run on ``target``.

    * ``eq`` needs the aggregate Eq. 4–7 ``instr`` timings;
    * ``ecm`` needs per-class ``incore`` tables (or ``instr`` to derive
      a 1-port fallback table) plus the per-level β chain;
    * ``roofline`` runs everywhere — peaks are declared (TPU) or
      derived from the Eq. 4–7 parameters (CPUs/GPU).
    """
    target = resolve_target(target)
    names = []
    if hasattr(target, "instr"):
        names.append("eq")
    if getattr(target, "incore", None) is not None or hasattr(target, "instr"):
        names.append("ecm")
    names.append("roofline")
    return tuple(names)


def resolve_runtime_model(name, target=None) -> RuntimeModel:
    """Instantiate a stage-4 model by registry name.

    ``None``/``"auto"`` defer to :func:`default_runtime_model` (which
    needs ``target``).  A named model is validated against the target's
    capabilities so an unsupported pairing fails at request-build time,
    not deep inside a grid evaluation.
    """
    if name is None or name == "auto":
        if target is None:
            raise ValueError("runtime model 'auto' needs a target")
        return default_runtime_model(resolve_target(target))
    try:
        cls = RUNTIME_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown runtime model {name!r}; known: "
            f"{sorted(RUNTIME_MODEL_NAMES)}"
        ) from None
    if target is not None:
        target = resolve_target(target)
        if name not in supported_runtime_models(target):
            raise ValueError(
                f"target {target.name!r} does not support runtime model "
                f"{name!r} (supported: {supported_runtime_models(target)})"
            )
    return cls()
