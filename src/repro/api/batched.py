"""Batched SDCM: the whole (target x level x cores) grid in ONE jitted
JAX call.

The per-level oracle (``sdcm.phit_given_d_np``) walks every distinct
reuse distance in a Python loop; a paper-style sweep calls it
levels x targets x core-counts times.  Here every level profile of
every grid cell is padded into one ``[G, M]`` array and a single
``vmap``-ed, jitted kernel evaluates Eq. 1–3 for all rows at once.

Per-row associativity is a *traced* scalar: the log-space binomial term
sum runs over a static ``A_MAX`` lane axis and masks ``k >= assoc``,
which keeps one compilation per (A_MAX bucket, M bucket, G bucket)
rather than one per geometry.  Fully-associative rows (the TPU VMEM
level) take the exact stack-rule branch ``P(h|D) = [D < B]``.

Evaluation is **composition-invariant**: every row's (A_MAX, M) shape
is derived from that row alone and row counts are padded to powers of
two, so the bits a profile evaluates to are identical whether it runs
in a lone single-request grid or coalesced with arbitrary other
requests (``Session.predict_many``, the ``repro.service``
microbatcher) — the property behind the service's "bit-identical to
sequential ``Session.predict``" guarantee.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.core.reuse.distance import INF_RD

# log-space term sums stay ~1e-7-accurate in f32 up to this many ways;
# larger set-associative geometries don't occur in Table 5 (max 20).
A_MAX_LIMIT = 64
_A_BUCKETS = (8, 16, 32, 64)


def _phit_row(d: jnp.ndarray, assoc: jnp.ndarray, blocks: jnp.ndarray,
              a_max: int) -> jnp.ndarray:
    """P(h | D) for one padded profile row; assoc/blocks are traced."""
    inf_mask = d == float(INF_RD)
    df = jnp.maximum(d, 0.0)
    p = assoc / blocks
    p = jnp.clip(p, 1e-30, 1.0 - 1e-7)

    d_col = df[:, None]                                   # [M, 1]
    j = jnp.arange(1, a_max, dtype=jnp.float32)           # [A-1]
    ratios = jnp.log(jnp.maximum(d_col - j + 1.0, 1e-30)) - jnp.log(j)
    log_comb = jnp.concatenate(
        [jnp.zeros_like(d_col), jnp.cumsum(ratios, axis=-1)], axis=-1
    )                                                     # [M, A]
    k = jnp.arange(a_max, dtype=jnp.float32)
    log_terms = log_comb + k * jnp.log(p) + (d_col - k) * jnp.log1p(-p)
    valid = (k < assoc) & (k <= d_col)
    log_terms = jnp.where(valid, log_terms, -jnp.inf)
    s = jnp.minimum(jnp.exp(logsumexp(log_terms, axis=-1)), 1.0)

    out = jnp.where(df <= assoc - 1.0, 1.0, s)
    fully = jnp.where(df < blocks, 1.0, 0.0)
    out = jnp.where(assoc >= blocks, fully, out)
    return jnp.where(inf_mask, 0.0, out)


@functools.lru_cache(maxsize=None)
def _grid_fn(a_max: int):
    @jax.jit
    def run(d, probs, assoc, blocks):
        phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
            d, assoc, blocks, a_max
        )
        return jnp.sum(probs * phit, axis=-1)

    return run


def _bucket(n: int, buckets=_A_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"set-associativity {n} exceeds the batched kernel's "
        f"A_MAX={A_MAX_LIMIT} (fully-associative levels are fine)"
    )


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def pack_profiles(profiles, m: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of ReuseProfiles into (distances [G, M], probs [G, M]).

    Padding rows with distance 0 / probability 0 — padded entries
    contribute nothing to the Eq. 3 dot product.  ``m`` overrides the
    padded width (callers grouping rows for composition-invariant
    evaluation pass each row's own pow2 width).
    """
    if m is None:
        # round M up so repeated sweeps reuse one compiled kernel
        m = _pow2(max((len(p.distances) for p in profiles), default=1))
    d = np.zeros((len(profiles), m), dtype=np.float32)
    pr = np.zeros((len(profiles), m), dtype=np.float32)
    for g, p in enumerate(profiles):
        n = len(p.distances)
        d[g, :n] = p.distances.astype(np.float32)
        pr[g, :n] = p.probabilities.astype(np.float32)
    return d, pr


def batched_phit(d: np.ndarray, assoc: np.ndarray, blocks: np.ndarray):
    """Vectorized P(h|D): rows of distances with per-row geometry."""
    finite = [int(a) for a, b in zip(assoc, blocks) if a < b]
    a_max = _bucket(max(finite, default=1))
    phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
        jnp.asarray(d, jnp.float32),
        jnp.asarray(assoc, jnp.float32),
        jnp.asarray(blocks, jnp.float32),
        a_max,
    )
    return np.asarray(phit)


def _row_shape_key(prof, assoc: int, blocks: int) -> tuple[int, int]:
    """The (a_max bucket, padded M) this row is evaluated under.

    Derived from the ROW alone — never from what else is in the call —
    so a profile's evaluated bits are identical whether it runs in a
    single-request grid or coalesced into a service batch
    (``Session.predict_many`` / ``repro.service``).  Fully-associative
    rows take the exact stack-rule branch; their lane axis is
    irrelevant, so they share the smallest bucket.
    """
    a_max = _bucket(int(assoc)) if assoc < blocks else _A_BUCKETS[0]
    return a_max, _pow2(max(len(prof.distances), 1))


def batched_hit_rates(items) -> list[dict[str, float]]:
    """Evaluate SDCM for every level of every (target, artifacts) cell
    in one vmapped+jitted call per row shape.  Returns one
    {level: hit_rate} dict per cell.

    Rows are grouped by :func:`_row_shape_key` and the row count of
    each group is padded to a power of two, so both the compiled-kernel
    set AND each row's numerics are independent of batch composition:
    coalesced results are bit-identical to per-request evaluation.
    """
    from repro.api.stages import shared_level_index

    rows = []           # (cell index, level name, profile, assoc, blocks)
    for ci, (target, art) in enumerate(items):
        shared_idx = shared_level_index(target)
        for li, lvl in enumerate(target.levels):
            prof = art.crd if li >= shared_idx else art.prd
            rows.append(
                (ci, lvl.name, prof, lvl.effective_assoc, lvl.num_lines)
            )
    if not rows:
        return [{} for _ in items]

    groups: dict[tuple[int, int], list[int]] = {}
    for ri, (_ci, _name, prof, assoc, blocks) in enumerate(rows):
        groups.setdefault(_row_shape_key(prof, assoc, blocks), []).append(ri)

    rates = np.zeros(len(rows), dtype=np.float64)
    for (a_max, m), idxs in groups.items():
        d, pr = pack_profiles([rows[i][2] for i in idxs], m)
        assoc = np.array([rows[i][3] for i in idxs], dtype=np.float32)
        blocks = np.array([rows[i][4] for i in idxs], dtype=np.float32)
        # pad G to pow2 with inert rows (probs 0) so the number of
        # compiled kernels stays bounded as batch sizes vary
        g = _pow2(len(idxs))
        if g > len(idxs):
            pad = g - len(idxs)
            d = np.pad(d, ((0, pad), (0, 0)))
            pr = np.pad(pr, ((0, pad), (0, 0)))
            assoc = np.pad(assoc, (0, pad), constant_values=1.0)
            blocks = np.pad(blocks, (0, pad), constant_values=2.0)
        out = np.asarray(
            _grid_fn(a_max)(
                jnp.asarray(d), jnp.asarray(pr),
                jnp.asarray(assoc), jnp.asarray(blocks),
            )
        )
        rates[idxs] = out[:len(idxs)]
    # empty-profile rows (total == 0) follow the oracle: hit rate 0
    empty = np.array([r[2].total == 0 for r in rows])
    rates = np.where(empty, 0.0, rates)

    out: list[dict[str, float]] = [{} for _ in items]
    for (ci, name, _prof, _a, _b), rate in zip(rows, rates):
        out[ci][name] = float(rate)
    return out
