"""Batched SDCM: the whole (target x level x cores) grid in ONE jitted
JAX call.

The per-level oracle (``sdcm.phit_given_d_np``) walks every distinct
reuse distance in a Python loop; a paper-style sweep calls it
levels x targets x core-counts times.  Here every level profile of
every grid cell is padded into one ``[G, M]`` array and a single
``vmap``-ed, jitted kernel evaluates Eq. 1–3 for all rows at once.

Per-row associativity is a *traced* scalar: the log-space binomial term
sum runs over a static ``A_MAX`` lane axis and masks ``k >= assoc``,
which keeps one compilation per (A_MAX bucket, M bucket, G bucket)
rather than one per geometry.  Fully-associative rows (the TPU VMEM
level) take the exact stack-rule branch ``P(h|D) = [D < B]``.

Evaluation is **composition-invariant**: every row's (A_MAX, M) shape
is derived from that row alone and row counts are padded to powers of
two, so the bits a profile evaluates to are identical whether it runs
in a lone single-request grid or coalesced with arbitrary other
requests (``Session.predict_many``, the ``repro.service``
microbatcher) — the property behind the service's "bit-identical to
sequential ``Session.predict``" guarantee.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.special import logsumexp

from repro.core.reuse.distance import INF_RD

# log-space term sums stay ~1e-7-accurate in f32 up to this many ways;
# larger set-associative geometries don't occur in Table 5 (max 20).
A_MAX_LIMIT = 64
_A_BUCKETS = (8, 16, 32, 64)


def _phit_row(d: jnp.ndarray, assoc: jnp.ndarray, blocks: jnp.ndarray,
              a_max: int) -> jnp.ndarray:
    """P(h | D) for one padded profile row; assoc/blocks are traced."""
    inf_mask = d == float(INF_RD)
    df = jnp.maximum(d, 0.0)
    p = assoc / blocks
    p = jnp.clip(p, 1e-30, 1.0 - 1e-7)

    d_col = df[:, None]                                   # [M, 1]
    j = jnp.arange(1, a_max, dtype=jnp.float32)           # [A-1]
    ratios = jnp.log(jnp.maximum(d_col - j + 1.0, 1e-30)) - jnp.log(j)
    log_comb = jnp.concatenate(
        [jnp.zeros_like(d_col), jnp.cumsum(ratios, axis=-1)], axis=-1
    )                                                     # [M, A]
    k = jnp.arange(a_max, dtype=jnp.float32)
    log_terms = log_comb + k * jnp.log(p) + (d_col - k) * jnp.log1p(-p)
    valid = (k < assoc) & (k <= d_col)
    log_terms = jnp.where(valid, log_terms, -jnp.inf)
    s = jnp.minimum(jnp.exp(logsumexp(log_terms, axis=-1)), 1.0)

    out = jnp.where(df <= assoc - 1.0, 1.0, s)
    fully = jnp.where(df < blocks, 1.0, 0.0)
    out = jnp.where(assoc >= blocks, fully, out)
    return jnp.where(inf_mask, 0.0, out)


@functools.lru_cache(maxsize=None)
def _grid_fn(a_max: int):
    @jax.jit
    def run(d, probs, assoc, blocks):
        phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
            d, assoc, blocks, a_max
        )
        return jnp.sum(probs * phit, axis=-1)

    return run


def _bucket(n: int, buckets=_A_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"set-associativity {n} exceeds the batched kernel's "
        f"A_MAX={A_MAX_LIMIT} (fully-associative levels are fine)"
    )


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def pack_profiles(profiles, m: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of ReuseProfiles into (distances [G, M], probs [G, M]).

    Padding rows with distance 0 / probability 0 — padded entries
    contribute nothing to the Eq. 3 dot product.  ``m`` overrides the
    padded width (callers grouping rows for composition-invariant
    evaluation pass each row's own pow2 width).
    """
    if m is None:
        # round M up so repeated sweeps reuse one compiled kernel
        m = _pow2(max((len(p.distances) for p in profiles), default=1))
    d = np.zeros((len(profiles), m), dtype=np.float32)
    pr = np.zeros((len(profiles), m), dtype=np.float32)
    for g, p in enumerate(profiles):
        n = len(p.distances)
        d[g, :n] = p.distances.astype(np.float32)
        pr[g, :n] = p.probabilities.astype(np.float32)
    return d, pr


def batched_phit(d: np.ndarray, assoc: np.ndarray, blocks: np.ndarray):
    """Vectorized P(h|D): rows of distances with per-row geometry."""
    finite = [int(a) for a, b in zip(assoc, blocks) if a < b]
    a_max = _bucket(max(finite, default=1))
    phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
        jnp.asarray(d, jnp.float32),
        jnp.asarray(assoc, jnp.float32),
        jnp.asarray(blocks, jnp.float32),
        a_max,
    )
    return np.asarray(phit)


def _row_shape_key(prof, assoc: int, blocks: int) -> tuple[int, int]:
    """The (a_max bucket, padded M) this row is evaluated under.

    Derived from the ROW alone — never from what else is in the call —
    so a profile's evaluated bits are identical whether it runs in a
    single-request grid or coalesced into a service batch
    (``Session.predict_many`` / ``repro.service``).  Fully-associative
    rows take the exact stack-rule branch; their lane axis is
    irrelevant, so they share the smallest bucket.
    """
    a_max = _bucket(int(assoc)) if assoc < blocks else _A_BUCKETS[0]
    return a_max, _pow2(max(len(prof.distances), 1))


def batched_hit_rates(items) -> list[dict[str, float]]:
    """Evaluate SDCM for every level of every (target, artifacts) cell
    in one vmapped+jitted call per row shape.  Returns one
    {level: hit_rate} dict per cell.

    Rows are grouped by :func:`_row_shape_key` and the row count of
    each group is padded to a power of two, so both the compiled-kernel
    set AND each row's numerics are independent of batch composition:
    coalesced results are bit-identical to per-request evaluation.
    """
    from repro.api.stages import shared_level_index

    rows = []           # (cell index, level name, profile, assoc, blocks)
    for ci, (target, art) in enumerate(items):
        shared_idx = shared_level_index(target)
        for li, lvl in enumerate(target.levels):
            prof = art.crd if li >= shared_idx else art.prd
            rows.append(
                (ci, lvl.name, prof, lvl.effective_assoc, lvl.num_lines)
            )
    if not rows:
        return [{} for _ in items]

    groups: dict[tuple[int, int], list[int]] = {}
    for ri, (_ci, _name, prof, assoc, blocks) in enumerate(rows):
        groups.setdefault(_row_shape_key(prof, assoc, blocks), []).append(ri)

    rates = np.zeros(len(rows), dtype=np.float64)
    for (a_max, m), idxs in groups.items():
        d, pr = pack_profiles([rows[i][2] for i in idxs], m)
        assoc = np.array([rows[i][3] for i in idxs], dtype=np.float32)
        blocks = np.array([rows[i][4] for i in idxs], dtype=np.float32)
        # pad G to pow2 with inert rows (probs 0) so the number of
        # compiled kernels stays bounded as batch sizes vary
        g = _pow2(len(idxs))
        if g > len(idxs):
            pad = g - len(idxs)
            d = np.pad(d, ((0, pad), (0, 0)))
            pr = np.pad(pr, ((0, pad), (0, 0)))
            assoc = np.pad(assoc, (0, pad), constant_values=1.0)
            blocks = np.pad(blocks, (0, pad), constant_values=2.0)
        _record_signature(("grid", a_max, g, m))
        out = np.asarray(
            _grid_fn(a_max)(
                jnp.asarray(d), jnp.asarray(pr),
                jnp.asarray(assoc), jnp.asarray(blocks),
            )
        )
        rates[idxs] = out[:len(idxs)]
    # empty-profile rows (total == 0) follow the oracle: hit rate 0
    empty = np.array([r[2].total == 0 for r in rows])
    rates = np.where(empty, 0.0, rates)

    out: list[dict[str, float]] = [{} for _ in items]
    for (ci, name, _prof, _a, _b), rate in zip(rows, rates):
        out[ci][name] = float(rate)
    return out


# --- compile accounting ------------------------------------------------------
#
# Every jit dispatch in this module lands on a cache key derived ONLY
# from static structure (A_MAX bucket, padded shapes, level count,
# chain mode) — never from batch composition or config values.  The
# signature set below mirrors those keys so sessions can assert "a warm
# sweep compiles nothing": `compile_count()` deltas feed
# `SessionStats.kernel_compiles`.

_COMPILED: set[tuple] = set()


def _record_signature(sig: tuple) -> int:
    """Record the compile-cache key a dispatch lands on; 1 if new."""
    if sig in _COMPILED:
        return 0
    _COMPILED.add(sig)
    return 1


def compile_count() -> int:
    """Number of distinct kernel compilations triggered so far."""
    return len(_COMPILED)


def compiled_signatures() -> frozenset:
    return frozenset(_COMPILED)


# --- fused config sweeps -----------------------------------------------------
#
# The batched grid above amortizes one kernel over many (workload,
# target) cells; a config *sweep* flips the axes: ONE fixed packed
# profile against C candidate hardware configs.  Geometry (assoc,
# blocks), transfer betas, level latencies and core counts are traced
# [C, L] / [C] device arrays, so the whole sweep — SDCM hit rates AND
# the ECM runtime chain from `core/incore.py` — is one jitted call per
# row shape with no per-config host round-trips.  C is padded to a
# power of two and rows are grouped by their per-level A_MAX-bucket
# tuple, keeping the compiled-kernel set bounded and each config's
# numerics bit-identical to `batched_hit_rates` on the same row.

# cap C*M elements per dispatch (f32 phit buffer <= 32 MiB); larger
# sweeps split into pow2-sized chunks, still one dispatch per chunk.
SWEEP_MAX_ELEMS = 1 << 23
_SWEEP_MIN_CHUNK = 64


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A reuse profile packed once and held device-resident.

    ``d``/``p`` are pow2-padded [M] f32 device arrays with exactly the
    bytes `pack_profiles` would produce for this profile, so sweep
    rates match `batched_hit_rates` bit for bit.
    """
    d: jnp.ndarray
    p: jnp.ndarray
    m: int
    total: int


def pack_profile_device(prof) -> DeviceProfile:
    d, p = pack_profiles([prof])
    return DeviceProfile(
        d=jnp.asarray(d[0]), p=jnp.asarray(p[0]),
        m=d.shape[1], total=int(prof.total),
    )


@dataclasses.dataclass(frozen=True)
class SweepGeometry:
    """Host-staged config axes for one sweep row group.

    All arrays are f32; [C, L] for per-level axes, [C] for cores.
    ``trans_beta[:, i]`` is the transfer beta of the boundary INTO
    level i+1 (RAM for the last column) — the `core/incore.py`
    convention.  ``delta`` is the per-level access latency used by the
    latency-mode chain.
    """
    assoc: np.ndarray
    blocks: np.ndarray
    trans_beta: np.ndarray
    delta: np.ndarray
    cores: np.ndarray

    def __post_init__(self):
        c, n = self.assoc.shape
        for name in ("blocks", "trans_beta", "delta"):
            if getattr(self, name).shape != (c, n):
                raise ValueError(f"geometry field {name} shape mismatch")
        if self.cores.shape != (c,):
            raise ValueError("geometry cores shape mismatch")


@dataclasses.dataclass(frozen=True)
class SweepResult:
    rates: np.ndarray            # [C, L] float64
    t_pred_s: np.ndarray | None  # [C] float64 (None without counts)
    dispatches: int              # fused-grid invocations issued
    compiles: int                # NEW kernel compilations triggered


def _rates_body(prd_d, prd_p, crd_d, crd_p, assoc, blocks,
                a_key: tuple, shared_idx: int):
    """[C, L] hit rates; level l uses the PRD below the shared level
    and the CRD at/above it, matching `AnalyticalSDCM`."""
    c = assoc.shape[0]
    cols = []
    for lv in range(len(a_key)):
        d, p = (prd_d, prd_p) if lv < shared_idx else (crd_d, crd_p)
        d2 = jnp.broadcast_to(d, (c, d.shape[0]))
        phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
            d2, assoc[:, lv], blocks[:, lv], a_key[lv]
        )
        cols.append(
            jnp.sum(jnp.broadcast_to(p, d2.shape) * phit, axis=-1)
        )
    return jnp.stack(cols, axis=-1)


def _chain_body(rates, trans_beta, delta, cores,
                comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s,
                shared_idx: int, mode: str):
    """ECM runtime chain on device — the `core/incore.py` math
    vectorized over the config axis.

    Per-core counts are the 1/cores share; the chip-wide saturation
    term runs on UNDIVIDED counts over the boundaries at/above the
    shared level, exactly as `ecm_cycles` does on host.
    """
    n_levels = rates.shape[1]
    reach = lax.cummin(jnp.clip(1.0 - rates, 0.0, 1.0), axis=1)
    share = 1.0 / jnp.maximum(cores, 1.0)
    full_transfers = mem_ops * reach * trans_beta        # [C, L] undivided
    if mode == "latency":
        acc = jnp.broadcast_to(ram_delta, rates.shape[:1])
        for lv in reversed(range(n_levels)):
            pl = rates[:, lv]
            acc = pl * delta[:, lv] + (1.0 - pl) * acc
        core_cy = comp_cy * share + mem_ops * share * acc
    else:
        data = lsu_cy * share + share * jnp.sum(full_transfers, axis=-1)
        core_cy = jnp.maximum(comp_cy * share, data)
    start = max(shared_idx - 1, 0)
    sat = jnp.sum(full_transfers[:, start:], axis=-1)
    return jnp.maximum(core_cy, sat) * cycle_s


@functools.lru_cache(maxsize=None)
def _sweep_fn(a_key: tuple, shared_idx: int, mode: str,
              with_runtime: bool):
    @jax.jit
    def run(prd_d, prd_p, crd_d, crd_p, assoc, blocks, trans_beta,
            delta, cores, comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s):
        rates = _rates_body(
            prd_d, prd_p, crd_d, crd_p, assoc, blocks, a_key, shared_idx
        )
        if not with_runtime:
            return rates
        t = _chain_body(
            rates, trans_beta, delta, cores,
            comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s,
            shared_idx, mode,
        )
        return rates, t

    return run


@functools.lru_cache(maxsize=None)
def _chain_fn(n_levels: int, shared_idx: int, mode: str):
    """Runtime chain alone — consumes externally computed hit rates
    (the Pallas inner evaluator path)."""
    del n_levels  # part of the cache key; shapes carry it at trace time

    @jax.jit
    def run(rates, trans_beta, delta, cores,
            comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s):
        return _chain_body(
            rates, trans_beta, delta, cores,
            comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s,
            shared_idx, mode,
        )

    return run


def _sweep_akey(assoc_row: np.ndarray, blocks_row: np.ndarray) -> tuple:
    """Per-level A_MAX bucket tuple for one config — `_row_shape_key`
    applied level-wise, so each (config, level) row compiles and
    evaluates exactly as it would in `batched_hit_rates`."""
    return tuple(
        _bucket(int(a)) if a < b else _A_BUCKETS[0]
        for a, b in zip(assoc_row, blocks_row)
    )


def _pad_rows(arr: np.ndarray, pad: int, value: float) -> np.ndarray:
    if pad == 0:
        return arr
    width = ((0, pad),) + ((0, 0),) * (arr.ndim - 1)
    return np.pad(arr, width, constant_values=value)


def _pallas_rates(prd: DeviceProfile, crd: DeviceProfile,
                  geom: SweepGeometry, shared_idx: int,
                  interpret: bool) -> tuple[np.ndarray, int, int]:
    """Inner evaluator on the `repro.kernels.sdcm` Pallas kernel.

    Geometry is static per Pallas compile, so configs are grouped by
    distinct (assoc, blocks) per level — one kernel call per geometry.
    A TPU-oriented path (interpret mode off-TPU); the vmap path remains
    the default.  Returns (rates, dispatches, new compiles).
    """
    from repro.kernels.sdcm import sdcm_hit_rate

    c, n_levels = geom.assoc.shape
    rates = np.zeros((c, n_levels), dtype=np.float64)
    dispatches = 0
    compiles = 0
    for lv in range(n_levels):
        prof = prd if lv < shared_idx else crd
        pairs: dict[tuple[int, int], list[int]] = {}
        for ci in range(c):
            key = (int(geom.assoc[ci, lv]), int(geom.blocks[ci, lv]))
            pairs.setdefault(key, []).append(ci)
        for (a, b), idxs in pairs.items():
            compiles += _record_signature(
                ("pallas-sdcm", a, b, prof.m, interpret)
            )
            r = float(
                sdcm_hit_rate(
                    prof.d, prof.p, assoc=a, blocks=b, interpret=interpret
                )
            )
            dispatches += 1
            rates[np.asarray(idxs), lv] = r
    return rates, dispatches, compiles


def sweep_grid(prd: DeviceProfile, crd: DeviceProfile,
               geom: SweepGeometry, *, shared_idx: int,
               counts=None, timings=None, cycle_s: float = 1.0,
               ram_delta: float = 0.0, mode: str = "throughput",
               inner: str = "vmap",
               interpret: bool | None = None) -> SweepResult:
    """Evaluate C hardware configs against one packed profile pair.

    Returns per-config [C, L] hit rates, plus per-config predicted
    runtime seconds when ``counts`` (an `OpCounts`) and ``timings``
    (an `InCoreTimings`) are given — the full SDCM + ECM chain fused
    into one jitted dispatch per row shape.  Configs are grouped by
    their per-level A_MAX-bucket tuple and each group's C is padded to
    a power of two (chunked at `SWEEP_MAX_ELEMS`), so the compiled set
    stays bounded and every config's hit-rate bits are independent of
    which other configs share the sweep.
    """
    if inner not in ("vmap", "pallas"):
        raise ValueError(f"unknown sweep inner evaluator: {inner!r}")
    c, n_levels = geom.assoc.shape
    with_runtime = counts is not None
    if with_runtime and timings is None:
        raise ValueError("sweep_grid needs timings when counts are given")

    if with_runtime:
        from repro.core.incore import t_comp_cy, t_lsu_cy

        comp_cy = float(t_comp_cy(timings, counts, mode))
        lsu_cy = float(t_lsu_cy(timings, counts))
        mem_ops = float(counts.mem_ops)
    else:
        comp_cy = lsu_cy = mem_ops = 0.0

    rates = np.zeros((c, n_levels), dtype=np.float64)
    t_pred = np.zeros(c, dtype=np.float64) if with_runtime else None
    dispatches = 0
    compiles = 0

    if inner == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        rates, dispatches, compiles = _pallas_rates(
            prd, crd, geom, shared_idx, interpret
        )
        if with_runtime:
            sig = ("sweep-chain", n_levels, shared_idx, mode, _pow2(c))
            compiles += _record_signature(sig)
            pad = _pow2(c) - c
            t = _chain_fn(n_levels, shared_idx, mode)(
                jnp.asarray(
                    _pad_rows(rates.astype(np.float32), pad, 1.0)
                ),
                jnp.asarray(_pad_rows(geom.trans_beta, pad, 0.0)),
                jnp.asarray(_pad_rows(geom.delta, pad, 0.0)),
                jnp.asarray(_pad_rows(geom.cores, pad, 1.0)),
                comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s,
            )
            t_pred[:] = np.asarray(t, dtype=np.float64)[:c]
            dispatches += 1
        return SweepResult(rates, t_pred, dispatches, compiles)

    # group configs by their per-level bucket tuple (static per compile)
    groups: dict[tuple, list[int]] = {}
    for ci in range(c):
        groups.setdefault(
            _sweep_akey(geom.assoc[ci], geom.blocks[ci]), []
        ).append(ci)

    max_m = max(prd.m, crd.m)
    chunk_cap = max(_SWEEP_MIN_CHUNK, _pow2(SWEEP_MAX_ELEMS // max_m) // 2)
    fn_args = (prd.d, prd.p, crd.d, crd.p)
    for a_key, idx_list in groups.items():
        fn = _sweep_fn(a_key, shared_idx, mode, with_runtime)
        for lo in range(0, len(idx_list), chunk_cap):
            idxs = np.asarray(idx_list[lo:lo + chunk_cap])
            g = _pow2(len(idxs))
            pad = g - len(idxs)
            sig = ("sweep", a_key, shared_idx, mode, with_runtime,
                   g, prd.m, crd.m)
            compiles += _record_signature(sig)
            out = fn(
                *fn_args,
                jnp.asarray(_pad_rows(geom.assoc[idxs], pad, 1.0)),
                jnp.asarray(_pad_rows(geom.blocks[idxs], pad, 2.0)),
                jnp.asarray(_pad_rows(geom.trans_beta[idxs], pad, 0.0)),
                jnp.asarray(_pad_rows(geom.delta[idxs], pad, 0.0)),
                jnp.asarray(_pad_rows(geom.cores[idxs], pad, 1.0)),
                comp_cy, lsu_cy, mem_ops, ram_delta, cycle_s,
            )
            dispatches += 1
            if with_runtime:
                r, t = out
                t_pred[idxs] = np.asarray(t, dtype=np.float64)[:len(idxs)]
            else:
                r = out
            rates[idxs] = np.asarray(r, dtype=np.float64)[:len(idxs)]

    if prd.total == 0:
        rates[:, :shared_idx] = 0.0
    if crd.total == 0:
        rates[:, shared_idx:] = 0.0
    return SweepResult(rates, t_pred, dispatches, compiles)
