"""Batched SDCM: the whole (target x level x cores) grid in ONE jitted
JAX call.

The per-level oracle (``sdcm.phit_given_d_np``) walks every distinct
reuse distance in a Python loop; a paper-style sweep calls it
levels x targets x core-counts times.  Here every level profile of
every grid cell is padded into one ``[G, M]`` array and a single
``vmap``-ed, jitted kernel evaluates Eq. 1–3 for all rows at once.

Per-row associativity is a *traced* scalar: the log-space binomial term
sum runs over a static ``A_MAX`` lane axis and masks ``k >= assoc``,
which keeps one compilation per (A_MAX bucket, M bucket) rather than
one per geometry.  Fully-associative rows (the TPU VMEM level) take
the exact stack-rule branch ``P(h|D) = [D < B]``.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.core.reuse.distance import INF_RD

# log-space term sums stay ~1e-7-accurate in f32 up to this many ways;
# larger set-associative geometries don't occur in Table 5 (max 20).
A_MAX_LIMIT = 64
_A_BUCKETS = (8, 16, 32, 64)


def _phit_row(d: jnp.ndarray, assoc: jnp.ndarray, blocks: jnp.ndarray,
              a_max: int) -> jnp.ndarray:
    """P(h | D) for one padded profile row; assoc/blocks are traced."""
    inf_mask = d == float(INF_RD)
    df = jnp.maximum(d, 0.0)
    p = assoc / blocks
    p = jnp.clip(p, 1e-30, 1.0 - 1e-7)

    d_col = df[:, None]                                   # [M, 1]
    j = jnp.arange(1, a_max, dtype=jnp.float32)           # [A-1]
    ratios = jnp.log(jnp.maximum(d_col - j + 1.0, 1e-30)) - jnp.log(j)
    log_comb = jnp.concatenate(
        [jnp.zeros_like(d_col), jnp.cumsum(ratios, axis=-1)], axis=-1
    )                                                     # [M, A]
    k = jnp.arange(a_max, dtype=jnp.float32)
    log_terms = log_comb + k * jnp.log(p) + (d_col - k) * jnp.log1p(-p)
    valid = (k < assoc) & (k <= d_col)
    log_terms = jnp.where(valid, log_terms, -jnp.inf)
    s = jnp.minimum(jnp.exp(logsumexp(log_terms, axis=-1)), 1.0)

    out = jnp.where(df <= assoc - 1.0, 1.0, s)
    fully = jnp.where(df < blocks, 1.0, 0.0)
    out = jnp.where(assoc >= blocks, fully, out)
    return jnp.where(inf_mask, 0.0, out)


@functools.lru_cache(maxsize=None)
def _grid_fn(a_max: int):
    @jax.jit
    def run(d, probs, assoc, blocks):
        phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
            d, assoc, blocks, a_max
        )
        return jnp.sum(probs * phit, axis=-1)

    return run


def _bucket(n: int, buckets=_A_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"set-associativity {n} exceeds the batched kernel's "
        f"A_MAX={A_MAX_LIMIT} (fully-associative levels are fine)"
    )


def pack_profiles(profiles) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of ReuseProfiles into (distances [G, M], probs [G, M]).

    Padding rows with distance 0 / probability 0 — padded entries
    contribute nothing to the Eq. 3 dot product.
    """
    m = max((len(p.distances) for p in profiles), default=1)
    # round M up so repeated sweeps reuse one compiled kernel
    m = 1 << max(m - 1, 1).bit_length()
    d = np.zeros((len(profiles), m), dtype=np.float32)
    pr = np.zeros((len(profiles), m), dtype=np.float32)
    for g, p in enumerate(profiles):
        n = len(p.distances)
        d[g, :n] = p.distances.astype(np.float32)
        pr[g, :n] = p.probabilities.astype(np.float32)
    return d, pr


def batched_phit(d: np.ndarray, assoc: np.ndarray, blocks: np.ndarray):
    """Vectorized P(h|D): rows of distances with per-row geometry."""
    finite = [int(a) for a, b in zip(assoc, blocks) if a < b]
    a_max = _bucket(max(finite, default=1))
    phit = jax.vmap(_phit_row, in_axes=(0, 0, 0, None))(
        jnp.asarray(d, jnp.float32),
        jnp.asarray(assoc, jnp.float32),
        jnp.asarray(blocks, jnp.float32),
        a_max,
    )
    return np.asarray(phit)


def batched_hit_rates(items) -> list[dict[str, float]]:
    """Evaluate SDCM for every level of every (target, artifacts) cell
    in one jitted call.  Returns one {level: hit_rate} dict per cell."""
    from repro.api.stages import shared_level_index

    rows = []           # (cell index, level name, profile, assoc, blocks)
    for ci, (target, art) in enumerate(items):
        shared_idx = shared_level_index(target)
        for li, lvl in enumerate(target.levels):
            prof = art.crd if li >= shared_idx else art.prd
            rows.append(
                (ci, lvl.name, prof, lvl.effective_assoc, lvl.num_lines)
            )
    if not rows:
        return [{} for _ in items]

    d, pr = pack_profiles([r[2] for r in rows])
    assoc = np.array([r[3] for r in rows], dtype=np.float32)
    blocks = np.array([r[4] for r in rows], dtype=np.float32)
    finite = [int(a) for a, b in zip(assoc, blocks) if a < b]
    a_max = _bucket(max(finite, default=1))
    rates = np.asarray(
        _grid_fn(a_max)(
            jnp.asarray(d), jnp.asarray(pr),
            jnp.asarray(assoc), jnp.asarray(blocks),
        )
    )
    # empty-profile rows (total == 0) follow the oracle: hit rate 0
    empty = np.array([r[2].total == 0 for r in rows])
    rates = np.where(empty, 0.0, rates)

    out: list[dict[str, float]] = [{} for _ in items]
    for (ci, name, _prof, _a, _b), rate in zip(rows, rates):
        out[ci][name] = float(rate)
    return out
