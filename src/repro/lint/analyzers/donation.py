"""DN family: buffer donation at jitted call sites.

The repo's hot loops all follow the donated-carry pattern from
``core/reuse/batched.py``::

    run = _multi_scan_fn(cap, block)        # jit factory, donate_argnums=(0, 1)
    tree, last_slot, rds = run(tree, last_slot, starts)

DN201 flags the shape of that pattern *without* the donation: a call
to a known-jitted callable whose result rebinds one of its own
positional arguments (a carry), where that argument position is not in
``donate_argnums`` — XLA then keeps both the old and new buffer alive
per step.

DN202 flags the inverse hazard: an argument that *is* donated being
read again after the call without first being rebound (donated buffers
are invalidated).  The scan is linear within the enclosing statement
block; reads on loop back-edges are out of scope (documented in
docs/lint.md).
"""
from __future__ import annotations

import ast

from repro.lint.analyzers._ast_utils import (
    collect_jit_callables,
    dotted,
    scan_imports,
)
from repro.lint.engine import Finding, ModuleContext


def _blocks(tree: ast.Module):
    """Yield every statement list in the module (function bodies, loop
    bodies, branches...)."""
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            stmts = getattr(node, field, None)
            if isinstance(stmts, list) and stmts \
                    and isinstance(stmts[0], ast.stmt):
                yield stmts


def _names_read(node: ast.AST) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            d = dotted(sub)
            if d:
                out.add(d)
    return out


def _names_bound(stmt: ast.stmt) -> set[str]:
    out = set()
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    for t in targets:
        for sub in ast.walk(t):
            d = dotted(sub)
            if d:
                out.add(d)
    return out


def analyze(ctx: ModuleContext) -> list[Finding]:
    imp = scan_imports(ctx.tree)
    if not imp.has_jax:
        return []
    callables = collect_jit_callables(ctx.tree, imp)
    findings: list[Finding] = []

    for stmts in _blocks(ctx.tree):
        for idx, stmt in enumerate(stmts):
            if not (isinstance(stmt, ast.Assign)
                    and isinstance(stmt.value, ast.Call)):
                continue
            call = stmt.value
            d = dotted(call.func)
            info = callables.get(d) if d else None
            if info is None or info.unknown or info.is_factory:
                # a factory call builds the jitted callable; its own
                # arguments (cap, block) are static config, not buffers
                continue
            rebound = set()
            for t in stmt.targets:
                for sub in ast.walk(t):
                    td = dotted(sub)
                    if td:
                        rebound.add(td)
            donated_args: list[tuple[int, str]] = []
            for i, arg in enumerate(call.args):
                ad = dotted(arg)
                if ad is None:
                    continue
                if i in info.donate_argnums:
                    donated_args.append((i, ad))
                elif ad in rebound:
                    findings.append(ctx.finding(
                        "DN201", call,
                        f"`{ad}` is a carry of jitted `{d}` (argument "
                        f"{i} rebound from the result) but the jit "
                        f"wrapper does not donate it — add "
                        f"donate_argnums=({i},)"))
            # DN202: donated buffer read after the call before rebinding
            for i, ad in donated_args:
                if ad in rebound:
                    continue
                for later in stmts[idx + 1:]:
                    if ad in _names_read(later) \
                            and ad not in _names_bound(later):
                        findings.append(ctx.finding(
                            "DN202", later,
                            f"`{ad}` was donated to jitted `{d}` "
                            f"(argument {i}) and is read again here — "
                            f"donated buffers are invalidated by XLA"))
                        break
                    if ad in _names_bound(later):
                        break
    return findings
