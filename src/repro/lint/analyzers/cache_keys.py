"""CK family: cache-key and fingerprint invariants.

The artifact pipeline trusts its keys completely — ``Session`` and
``ArtifactStore`` never re-validate a hit (the paper's premise is
"extract the trace only once"), so a key that omits a
behavior-changing field silently serves wrong results.

CK401 — any function that *is* a key builder (name contains
``fingerprint``, ends in ``_key``, or is ``key``) must route every
parameter and every ``self.<attr>`` it reads into its return value.
The check runs a backward slice from the return expressions through
local assignments (and ``.append``/``.update`` mutations), so
``parts = [...]; parts.append(f(seed)); return "/".join(parts)``
counts ``seed`` as used.

CK402 — a module that defines ``STORE_VERSION`` must actually
interpolate a version component into its on-disk path (the
``f"v{self.version}"`` namespace in ``validate/store.py``); otherwise
bumping the constant would *misread* old entries instead of orphaning
them.

CK403 — ``save_*``/``load_*`` pairs must agree on the persisted meta
fields: every key written into the save-side meta dict should be read
back (``meta["k"]`` / ``meta.get("k")``) by the paired loader, and
vice versa.  Write-only provenance fields need a justified
suppression.
"""
from __future__ import annotations

import ast

from repro.lint.analyzers._ast_utils import dotted
from repro.lint.engine import Finding, ModuleContext


def _is_key_builder(name: str) -> bool:
    return "fingerprint" in name or name.endswith("_key") or name == "key"


def _expr_deps(node: ast.AST) -> set[str]:
    """Names and ``self.X`` attrs read by an expression."""
    deps: set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            deps.add(sub.id)
        elif (isinstance(sub, ast.Attribute)
              and isinstance(sub.value, ast.Name)
              and sub.value.id == "self"):
            deps.add(f"self.{sub.attr}")
    return deps


def _check_key_builder(ctx: ModuleContext, fn: ast.FunctionDef,
                       findings: list[Finding]) -> None:
    args = fn.args
    if args.vararg or args.kwarg:
        return  # *args/**kwargs builders hash dynamically; out of scope
    params = [a.arg for a in (*args.posonlyargs, *args.args,
                              *args.kwonlyargs) if a.arg not in ("self",
                                                                 "cls")]

    returns = [n.value for n in ast.walk(fn)
               if isinstance(n, ast.Return) and n.value is not None]
    if not returns:
        return

    # local assignment graph: name -> deps of its value(s)
    assigns: dict[str, set[str]] = {}

    def _add(name: str, deps: set[str]) -> None:
        assigns.setdefault(name, set()).update(deps)

    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign):
            deps = _expr_deps(sub.value)
            for t in sub.targets:
                for tn in ast.walk(t):
                    if isinstance(tn, ast.Name):
                        _add(tn.id, deps)
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
            if getattr(sub, "value", None) is None:
                continue
            if isinstance(sub.target, ast.Name):
                _add(sub.target.id, _expr_deps(sub.value))
        elif isinstance(sub, ast.NamedExpr):
            if isinstance(sub.target, ast.Name):
                _add(sub.target.id, _expr_deps(sub.value))
        elif isinstance(sub, ast.Call):
            # mutation flows: parts.append(x), d.update(...), d.add(...)
            f = sub.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.attr in ("append", "extend", "update", "add",
                                   "insert", "setdefault", "write")):
                deps = set()
                for a in sub.args:
                    deps |= _expr_deps(a)
                for kw in sub.keywords:
                    deps |= _expr_deps(kw.value)
                _add(f.value.id, deps)
        elif isinstance(sub, ast.For):
            deps = _expr_deps(sub.iter)
            for tn in ast.walk(sub.target):
                if isinstance(tn, ast.Name):
                    _add(tn.id, deps)
        elif isinstance(sub, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                              ast.DictComp)):
            for gen in sub.generators:
                deps = _expr_deps(gen.iter)
                for tn in ast.walk(gen.target):
                    if isinstance(tn, ast.Name):
                        _add(tn.id, deps)

    used: set[str] = set()
    for r in returns:
        used |= _expr_deps(r)
    # control dependence: a field read in a branch condition steers
    # which key is returned (e.g. `if self.done: return inf`) — that
    # counts as flowing into the key
    for sub in ast.walk(fn):
        if isinstance(sub, (ast.If, ast.While)):
            used |= _expr_deps(sub.test)
        elif isinstance(sub, ast.IfExp):
            used |= _expr_deps(sub.test)
    for _ in range(len(assigns) + 1):
        grown = set(used)
        for name in list(used):
            grown |= assigns.get(name, set())
        if grown == used:
            break
        used = grown

    self_reads = {d for d in _all_self_reads(fn)}
    for p in params:
        if p not in used:
            findings.append(ctx.finding(
                "CK401", fn,
                f"key builder `{fn.name}` reads parameter `{p}` but it "
                f"never flows into the returned key — two inputs "
                f"differing only in `{p}` would collide"))
    for attr in sorted(self_reads):
        if attr not in used:
            findings.append(ctx.finding(
                "CK401", fn,
                f"key builder `{fn.name}` reads `{attr}` but it never "
                f"flows into the returned key"))


def _all_self_reads(fn: ast.FunctionDef) -> set[str]:
    out = set()
    for sub in ast.walk(fn):
        if (isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "self"
                and isinstance(sub.ctx, ast.Load)):
            out.add(f"self.{sub.attr}")
    return out


# -- CK402 --------------------------------------------------------------------

def _check_store_version(ctx: ModuleContext,
                         findings: list[Finding]) -> None:
    assign = None
    for node in ctx.tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "STORE_VERSION"
                for t in node.targets):
            assign = node
            break
    if assign is None:
        return
    referenced = any(
        isinstance(n, ast.Name) and n.id == "STORE_VERSION"
        and isinstance(n.ctx, ast.Load)
        for n in ast.walk(ctx.tree))
    versioned_path = False
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.JoinedStr):
            continue
        has_v_literal = any(
            isinstance(v, ast.Constant) and isinstance(v.value, str)
            and v.value.rstrip().endswith("v")
            for v in node.values)
        has_version_field = any(
            isinstance(v, ast.FormattedValue)
            and any("version" in (dotted(s) or "").lower()
                    for s in ast.walk(v.value)
                    if isinstance(s, (ast.Name, ast.Attribute)))
            for v in node.values)
        if has_v_literal and has_version_field:
            versioned_path = True
            break
    if not (referenced and versioned_path):
        findings.append(ctx.finding(
            "CK402", assign,
            "STORE_VERSION is defined but the on-disk key path never "
            "interpolates a version component (expected an "
            "f\"v{...version...}\" namespace) — a format bump would "
            "misread old entries"))


# -- CK403 --------------------------------------------------------------------

def _meta_written_keys(fn: ast.FunctionDef) -> tuple[set[str],
                                                     ast.AST | None]:
    """String keys of the meta dict a ``save_*`` persists: a dict
    literal assigned to ``meta``/``*_meta``, passed as a ``meta=``
    kwarg, or handed positionally to a ``put_*`` call."""
    dicts: list[ast.Dict] = []
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign):
            if (isinstance(sub.value, ast.Dict)
                    and any(isinstance(t, ast.Name)
                            and t.id.endswith("meta")
                            for t in sub.targets)):
                dicts.append(sub.value)
        elif isinstance(sub, ast.Call):
            fname = dotted(sub.func) or ""
            for kw in sub.keywords:
                if kw.arg == "meta" and isinstance(kw.value, ast.Dict):
                    dicts.append(kw.value)
            if "put" in fname.rsplit(".", 1)[-1]:
                # put_arrays(kind, key, arrays, meta): the payload dict
                # precedes the meta dict — only the last literal dict
                # is the persisted meta
                pos_dicts = [a for a in sub.args if isinstance(a, ast.Dict)]
                if pos_dicts:
                    dicts.append(pos_dicts[-1])
    keys: set[str] = set()
    site = dicts[0] if dicts else None
    for d in dicts:
        for k in d.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
    return keys, site


def _meta_read_keys(fn: ast.FunctionDef) -> set[str]:
    """String keys a ``load_*`` reads off any ``*meta*`` variable via
    ``meta["k"]`` or ``meta.get("k")``."""
    keys: set[str] = set()
    for sub in ast.walk(fn):
        if (isinstance(sub, ast.Subscript)
                and isinstance(sub.ctx, ast.Load)
                and "meta" in (dotted(sub.value) or "")
                and isinstance(sub.slice, ast.Constant)
                and isinstance(sub.slice.value, str)):
            keys.add(sub.slice.value)
        elif (isinstance(sub, ast.Call)
              and isinstance(sub.func, ast.Attribute)
              and sub.func.attr == "get"
              and "meta" in (dotted(sub.func.value) or "")
              and sub.args
              and isinstance(sub.args[0], ast.Constant)
              and isinstance(sub.args[0].value, str)):
            keys.add(sub.args[0].value)
    return keys


def _check_save_load_pairs(ctx: ModuleContext,
                           findings: list[Finding]) -> None:
    fns: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.FunctionDef):
            fns[node.name] = node
    for name, save_fn in fns.items():
        if not name.startswith("save_"):
            continue
        load_fn = fns.get("load_" + name[len("save_"):])
        if load_fn is None:
            continue
        written, site = _meta_written_keys(save_fn)
        read = _meta_read_keys(load_fn)
        if not written or not read:
            continue  # pair doesn't persist structured meta — no claim
        for k in sorted(written - read):
            findings.append(ctx.finding(
                "CK403", site or save_fn,
                f"meta field \"{k}\" is written by `{save_fn.name}` but "
                f"never read back by `{load_fn.name}` — drop it or "
                f"restore it on load"))
        for k in sorted(read - written):
            findings.append(ctx.finding(
                "CK403", load_fn,
                f"meta field \"{k}\" is read by `{load_fn.name}` but "
                f"never written by `{save_fn.name}` — it will always "
                f"be missing"))


def analyze(ctx: ModuleContext) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.FunctionDef) and _is_key_builder(node.name):
            _check_key_builder(ctx, node, findings)
    _check_store_version(ctx, findings)
    _check_save_load_pairs(ctx, findings)
    return findings
