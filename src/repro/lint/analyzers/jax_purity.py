"""JP family: purity of jit-reachable code.

The analyzer discovers every *jit root* in a module (jit-decorated
defs, ``jax.jit(f)`` wraps, ``jax.jit(lambda ...)``, and jitted defs
returned by factories), then runs a taint fixpoint: a root's
parameters are traced values (minus ``static_argnums`` /
``static_argnames``), local helper functions called from reachable
code inherit taint through their call-site arguments, and helpers
passed *by reference* (``jax.vmap(f)``, ``lax.scan(step, ...)``)
get all parameters tainted because jax calls them with tracers.

The call-site propagation is what keeps helpers like::

    def _fenwick_levels(n):
        return max(1, int(n).bit_length())

clean when every caller passes a static shape — a naive
"every param of a jit-reachable function is traced" scheme would
flag that ``int(n)`` as a host sync.

Untainted by construction: constants, ``.shape/.dtype/.ndim/.size``,
``len()``, and ``x is None`` comparisons (the standard optional-arg
idiom inside jitted wrappers).

Rules emitted: JP101 (print), JP102 (host sync), JP103 (numpy on
traced), JP110 (Python control flow on traced), JP120 (jit built in a
loop), JP121 (data-length static argument at a jitted call site).
"""
from __future__ import annotations

import ast

from repro.lint.analyzers._ast_utils import (
    Imports,
    collect_jit_callables,
    decorator_jit_info,
    dotted,
    is_jit_ref,
    is_partial_ref,
    jit_call_target,
    param_names,
    positional_params,
    scan_imports,
)
from repro.lint.engine import Finding, ModuleContext

_SHAPE_ATTRS = {"shape", "dtype", "ndim", "size", "weak_type", "sharding"}
_HOST_CASTS = {"float", "int", "bool", "complex"}
_HOST_METHODS = {"item", "tolist", "to_py"}
_UNTAINTED_BUILTINS = {"len", "isinstance", "hasattr", "getattr", "type",
                       "repr", "str", "id", "callable"}
_MAX_FIXPOINT_PASSES = 12


class _FnNode:
    """Per-function taint state across fixpoint passes."""

    def __init__(self, node: ast.AST):
        self.node = node
        self.params = param_names(node)
        self.taint: dict[str, bool] = {p: False for p in self.params}
        self.reachable = False
        self.is_root = False

    def taint_param(self, name: str) -> bool:
        if name in self.taint and not self.taint[name]:
            self.taint[name] = True
            return True
        return False

    def taint_all(self) -> bool:
        changed = False
        for p in self.params:
            changed |= self.taint_param(p)
        return changed


class _Analyzer:
    def __init__(self, ctx: ModuleContext, imp: Imports):
        self.ctx = ctx
        self.imp = imp
        self.fns: dict[ast.AST, _FnNode] = {}
        self.by_name: dict[str, list[_FnNode]] = {}
        self.findings: list[Finding] = []
        self.seen: set[tuple[str, int, int]] = set()
        self.changed = False
        self.emitting = False

        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                fn = _FnNode(node)
                self.fns[node] = fn
                if not isinstance(node, ast.Lambda):
                    self.by_name.setdefault(node.name, []).append(fn)

    # -- root discovery ------------------------------------------------------

    def find_roots(self) -> None:
        for node, fn in self.fns.items():
            if isinstance(node, ast.Lambda):
                continue
            info = decorator_jit_info(node, self.imp)
            if info is not None:
                self._make_root(fn, info)
        for node in ast.walk(self.ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            hit = jit_call_target(node, self.imp)
            if hit is None:
                continue
            wrapped, info = hit
            if isinstance(wrapped, ast.Lambda):
                self._make_root(self.fns[wrapped], info)
            elif isinstance(wrapped, ast.Name):
                for fn in self.by_name.get(wrapped.id, []):
                    self._make_root(fn, info)

    def _make_root(self, fn: _FnNode, info) -> None:
        fn.is_root = True
        fn.reachable = True
        pos = positional_params(fn.node)
        static = {pos[i] for i in info.static_argnums if i < len(pos)}
        static |= set(info.static_argnames)
        if info.unknown:
            static = set(fn.params)  # can't tell — assume static, no FPs
        for p in fn.params:
            if p not in static:
                fn.taint[p] = True

    # -- fixpoint ------------------------------------------------------------

    def run(self) -> list[Finding]:
        self.find_roots()
        if not any(fn.is_root for fn in self.fns.values()):
            self._scan_jit_in_loop()
            return self.findings
        for _ in range(_MAX_FIXPOINT_PASSES):
            self.changed = False
            for fn in list(self.fns.values()):
                if fn.reachable:
                    _BodyWalker(self, fn).walk()
            if not self.changed:
                break
        self.emitting = True
        for fn in self.fns.values():
            if fn.reachable:
                _BodyWalker(self, fn).walk()
        self._scan_jit_in_loop()
        self._scan_static_len_args()
        return self.findings

    # -- helpers used by the walker -----------------------------------------

    def mark_called(self, name: str, arg_taints: list[bool],
                    kw_taints: dict[str, bool]) -> None:
        """Direct call of a local function: taint its params from the
        call site and make it reachable."""
        for fn in self.by_name.get(name, []):
            if not fn.reachable:
                fn.reachable = True
                self.changed = True
            pos = positional_params(fn.node)
            for i, t in enumerate(arg_taints):
                if t and i < len(pos):
                    self.changed |= fn.taint_param(pos[i])
            for k, t in kw_taints.items():
                if t:
                    self.changed |= fn.taint_param(k)

    def mark_referenced(self, fn: _FnNode) -> None:
        """Function passed by reference (vmap/scan/fori_loop callback):
        jax will call it with tracers — every param is traced."""
        if not fn.reachable:
            fn.reachable = True
            self.changed = True
        self.changed |= fn.taint_all()

    def emit(self, rule_id: str, node: ast.AST, message: str) -> None:
        if not self.emitting:
            return
        key = (rule_id, getattr(node, "lineno", 1),
               getattr(node, "col_offset", 0))
        if key in self.seen:
            return
        self.seen.add(key)
        self.findings.append(self.ctx.finding(rule_id, node, message))

    # -- module-wide scans (taint-independent) -------------------------------

    def _scan_jit_in_loop(self) -> None:
        self.emitting = True
        for loop in ast.walk(self.ctx.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            for sub in ast.walk(loop):
                if isinstance(sub, ast.Call) and (
                        is_jit_ref(sub.func, self.imp)
                        or jit_call_target(sub, self.imp) is not None):
                    self.emit("JP120", sub,
                              "jax.jit(...) constructed inside a loop "
                              "body recompiles every iteration; hoist "
                              "or cache the jitted callable")

    def _scan_static_len_args(self) -> None:
        callables = collect_jit_callables(self.ctx.tree, self.imp)
        for node in ast.walk(self.ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            info = callables.get(d) if d else None
            if info is None or info.is_factory or info.unknown:
                continue
            for i, arg in enumerate(node.args):
                if i in info.static_argnums and _derives_from_length(arg):
                    self.emit("JP121", arg,
                              f"static argument {i} of `{d}` is derived "
                              "from a data length at the call site — one "
                              "XLA compilation per distinct length")
            for kw in node.keywords:
                if (kw.arg in info.static_argnames
                        and _derives_from_length(kw.value)):
                    self.emit("JP121", kw.value,
                              f"static argument `{kw.arg}` of `{d}` is "
                              "derived from a data length at the call "
                              "site — one XLA compilation per distinct "
                              "length")


def _derives_from_length(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "len"):
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in ("shape", "size"):
            return True
    return False


def _is_none_compare(expr: ast.AST) -> bool:
    return (isinstance(expr, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops)
            and (any(isinstance(c, ast.Constant) and c.value is None
                     for c in expr.comparators)
                 or (isinstance(expr.left, ast.Constant)
                     and expr.left.value is None)))


class _BodyWalker:
    """Single forward pass over one function body, computing local
    taint and (on the emission pass) JP findings."""

    def __init__(self, an: _Analyzer, fn: _FnNode):
        self.an = an
        self.fn = fn
        self.env: dict[str, bool] = dict(fn.taint)

    def walk(self) -> None:
        body = self.fn.node.body
        if isinstance(self.fn.node, ast.Lambda):
            self.taint(body)
        else:
            self.block(body)

    # -- statements ----------------------------------------------------------

    def block(self, stmts: list[ast.stmt]) -> None:
        for stmt in stmts:
            self.stmt(stmt)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, ast.Expr):
            self.taint(node.value)
        elif isinstance(node, ast.Assign):
            t = self.taint(node.value)
            for target in node.targets:
                self.bind(target, t)
        elif isinstance(node, ast.AugAssign):
            t = self.taint(node.value) or self.taint(node.target)
            self.bind(node.target, t)
        elif isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self.bind(node.target, self.taint(node.value))
        elif isinstance(node, (ast.Return, ast.Raise)):
            for child in ast.iter_child_nodes(node):
                self.taint(child)
        elif isinstance(node, ast.If):
            self.check_condition(node.test, "if")
            self.block(node.body)
            self.block(node.orelse)
        elif isinstance(node, ast.While):
            self.check_condition(node.test, "while")
            self.block(node.body)
            self.block(node.body)  # loop-carried taint
            self.block(node.orelse)
        elif isinstance(node, ast.For):
            t = self.taint(node.iter)
            if t:
                self.an.emit("JP110", node.iter,
                             "for-loop over a traced value inside "
                             "jit-reachable code (unrolls per element and "
                             "recompiles per length)")
            self.bind(node.target, t)
            self.block(node.body)
            self.block(node.body)  # loop-carried taint
            self.block(node.orelse)
        elif isinstance(node, ast.Assert):
            self.check_condition(node.test, "assert")
            if node.msg is not None:
                self.taint(node.msg)
        elif isinstance(node, ast.With):
            for item in node.items:
                self.taint(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, False)
            self.block(node.body)
        elif isinstance(node, ast.Try):
            self.block(node.body)
            for h in node.handlers:
                self.block(h.body)
            self.block(node.orelse)
            self.block(node.finalbody)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            pass  # nested defs get their own _FnNode via references
        elif isinstance(node, ast.ClassDef):
            pass
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.env.pop(t.id, None)
        else:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self.taint(child)
                elif isinstance(child, ast.stmt):
                    self.stmt(child)

    def bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = tainted
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.bind(elt, tainted)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, tainted)
        # attribute/subscript stores: nothing to track locally

    def check_condition(self, test: ast.expr, kind: str) -> None:
        t = self.taint(test)
        if t and not _is_none_compare(test):
            self.an.emit("JP110", test,
                         f"Python `{kind}` conditioned on a traced value "
                         "inside jit-reachable code — use jnp.where / "
                         "jax.lax.cond")

    # -- expressions ---------------------------------------------------------

    def taint(self, node: ast.AST | None) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            if node.id in self.an.by_name and node.id not in self.env:
                # bare reference to a local function (callback position)
                for fn in self.an.by_name[node.id]:
                    self.an.mark_referenced(fn)
                return False
            return self.env.get(node.id, False)
        if isinstance(node, ast.Lambda):
            self.an.mark_referenced(self.an.fns[node])
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                self.taint(node.value)
                return False
            return self.taint(node.value)
        if isinstance(node, ast.Subscript):
            return self.taint(node.value) | self.taint(node.slice)
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, ast.BinOp):
            return self.taint(node.left) | self.taint(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.taint(node.operand)
        if isinstance(node, ast.BoolOp):
            return any([self.taint(v) for v in node.values])
        if isinstance(node, ast.Compare):
            t = self.taint(node.left)
            for c in node.comparators:
                t |= self.taint(c)
            return False if _is_none_compare(node) else t
        if isinstance(node, ast.IfExp):
            self.check_condition(node.test, "if-expression")
            return self.taint(node.body) | self.taint(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any([self.taint(e) for e in node.elts])
        if isinstance(node, ast.Dict):
            t = any([self.taint(k) for k in node.keys if k is not None])
            return any([self.taint(v) for v in node.values]) or t
        if isinstance(node, ast.Starred):
            return self.taint(node.value)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            for child in ast.iter_child_nodes(node):
                self.taint(child)
            return False
        if isinstance(node, ast.NamedExpr):
            t = self.taint(node.value)
            self.bind(node.target, t)
            return t
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return self.comprehension(node)
        if isinstance(node, ast.Slice):
            return (self.taint(node.lower) | self.taint(node.upper)
                    | self.taint(node.step))
        if isinstance(node, (ast.Await, ast.Yield, ast.YieldFrom)):
            return self.taint(node.value) if node.value else False
        return any(self.taint(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    def comprehension(self, node: ast.AST) -> bool:
        t = False
        for gen in node.generators:
            it = self.taint(gen.iter)
            if it:
                self.an.emit("JP110", gen.iter,
                             "comprehension over a traced value inside "
                             "jit-reachable code (unrolls per element)")
            self.bind(gen.target, it)
            for cond in gen.ifs:
                self.check_condition(cond, "comprehension-if")
            t |= it
        if isinstance(node, ast.DictComp):
            t |= self.taint(node.key) | self.taint(node.value)
        else:
            t |= self.taint(node.elt)
        return t

    def call(self, node: ast.Call) -> bool:
        imp = self.an.imp
        d = dotted(node.func)

        # evaluate arguments first; a Name-of-local-function in argument
        # position is a by-reference callback (vmap/scan) and is marked
        # all-tainted inside taint()
        skip_arg_refs = (is_partial_ref(node.func, imp)
                         and node.args
                         and isinstance(node.args[0], ast.Name)
                         and node.args[0].id in self.an.by_name)
        arg_taints = []
        for i, a in enumerate(node.args):
            if skip_arg_refs and i == 0:
                arg_taints.append(False)
                continue
            arg_taints.append(self.taint(a))
        kw_taints = {kw.arg: self.taint(kw.value)
                     for kw in node.keywords if kw.arg is not None}
        any_taint = any(arg_taints) or any(kw_taints.values())

        if d == "print":
            self.an.emit("JP101", node,
                         "print() inside jit-reachable code runs at "
                         "trace time only — use jax.debug.print()")
            return False
        if d in _HOST_CASTS and arg_taints and arg_taints[0]:
            self.an.emit("JP102", node,
                         f"{d}() on a traced value inside jit-reachable "
                         "code forces a host sync / fails under tracing")
            return False
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _HOST_METHODS
                and self.taint(node.func.value)):
            self.an.emit("JP102", node,
                         f".{node.func.attr}() on a traced value inside "
                         "jit-reachable code forces a host sync")
            return False
        root = d.split(".")[0] if d else None
        if d and any_taint and (root in imp.numpy_aliases
                                or d in imp.numpy_fn_names):
            self.an.emit("JP103", node,
                         f"`{d}` (host numpy) applied to a traced value "
                         "inside jit-reachable code — use the jnp "
                         "equivalent")
            return False
        if d and (root in imp.jaxlike or d in imp.jit_names
                  or d in imp.jax_fn_names):
            return True
        if (isinstance(node.func, ast.Name)
                and node.func.id in self.an.by_name
                and node.func.id not in self.env):
            self.an.mark_called(node.func.id, arg_taints, kw_taints)
            return any_taint
        if skip_arg_refs:
            # partial(local_fn, kw=...): map keyword taints through,
            # remaining params will be filled with tracers by the caller
            for fn in self.an.by_name[node.args[0].id]:
                if not fn.reachable:
                    fn.reachable = True
                    self.an.changed = True
                named = set()
                for k, t in kw_taints.items():
                    named.add(k)
                    if t:
                        self.an.changed |= fn.taint_param(k)
                for p in fn.params:
                    if p not in named:
                        self.an.changed |= fn.taint_param(p)
            return False
        if d in _UNTAINTED_BUILTINS:
            return False
        if isinstance(node.func, (ast.Attribute, ast.Subscript, ast.Call,
                                  ast.Lambda)):
            self.taint(node.func)
        return any_taint


def analyze(ctx: ModuleContext) -> list[Finding]:
    imp = scan_imports(ctx.tree)
    if not imp.has_jax:
        return []
    return _Analyzer(ctx, imp).run()
