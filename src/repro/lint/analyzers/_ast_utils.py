"""Shared AST helpers for the analyzer families.

Centralizes the fiddly parts every analyzer needs: resolving dotted
names, mapping import aliases (``jax`` vs ``jax.numpy`` vs real
``numpy``), and recovering :class:`JitInfo` (static/donate argument
sets) from the three jit idioms the codebase uses::

    @jax.jit / @functools.partial(jax.jit, static_argnames=...)
    g = jax.jit(f, donate_argnums=(0,))
    def factory(cap):                 # lru_cached jit factory
        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def run(...): ...
        return run
"""
from __future__ import annotations

import ast
import dataclasses


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


@dataclasses.dataclass
class Imports:
    """How this module spells jax / numpy / functools."""

    jaxlike: set[str]          # aliases for jax or jax.* modules (jax, jnp, lax)
    jit_names: set[str]        # bare names bound to jax.jit
    jax_fn_names: set[str]     # names imported from jax.* (traced calls)
    numpy_aliases: set[str]    # aliases for real numpy
    numpy_fn_names: set[str]   # names imported from numpy
    partial_names: set[str]    # bare names bound to functools.partial
    functools_aliases: set[str]
    threading_aliases: set[str]
    future_names: set[str]     # names bound to concurrent.futures.Future
    futures_aliases: set[str]  # aliases for the concurrent.futures module

    @property
    def has_jax(self) -> bool:
        return bool(self.jaxlike or self.jit_names or self.jax_fn_names)

    @property
    def has_threads(self) -> bool:
        return bool(self.threading_aliases or self.future_names
                    or self.futures_aliases)


def scan_imports(tree: ast.Module) -> Imports:
    imp = Imports(set(), set(), set(), set(), set(), set(), set(), set(),
                  set(), set())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                if a.name == "jax" or a.name.startswith("jax."):
                    # `import jax.numpy as jnp` binds jnp; plain
                    # `import jax.numpy` binds only `jax`
                    imp.jaxlike.add(a.asname or "jax")
                elif a.name == "numpy" or a.name.startswith("numpy."):
                    imp.numpy_aliases.add(name)
                elif a.name == "functools":
                    imp.functools_aliases.add(name)
                elif a.name == "threading":
                    imp.threading_aliases.add(name)
                elif a.name in ("concurrent.futures", "concurrent"):
                    imp.futures_aliases.add(a.asname or "concurrent")
        elif isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            for a in node.names:
                name = a.asname or a.name
                if mod == "jax" and a.name == "jit":
                    imp.jit_names.add(name)
                elif mod == "jax" and a.name in ("numpy", "lax", "nn",
                                                 "random", "scipy"):
                    imp.jaxlike.add(name)
                elif mod == "jax" or mod.startswith("jax."):
                    imp.jax_fn_names.add(name)
                elif mod == "numpy" or mod.startswith("numpy."):
                    imp.numpy_fn_names.add(name)
                elif mod == "functools" and a.name == "partial":
                    imp.partial_names.add(name)
                elif mod == "threading":
                    imp.threading_aliases.add(name)  # e.g. `from threading import Lock` — treated as module-ish marker
                elif mod == "concurrent.futures":
                    if a.name == "Future":
                        imp.future_names.add(name)
                    else:
                        imp.futures_aliases.add(name)
                elif mod == "concurrent" and a.name == "futures":
                    imp.futures_aliases.add(name)
    return imp


def is_jit_ref(node: ast.AST, imp: Imports) -> bool:
    d = dotted(node)
    if d is None:
        return False
    if d in imp.jit_names:
        return True
    return any(d == f"{alias}.jit" for alias in imp.jaxlike)


def is_partial_ref(node: ast.AST, imp: Imports) -> bool:
    d = dotted(node)
    if d is None:
        return False
    if d in imp.partial_names:
        return True
    return any(d == f"{alias}.partial" for alias in imp.functools_aliases)


def _const_set(node: ast.AST, typ: type) -> frozenset | None:
    """Literal ``3`` / ``"x"`` / tuple-or-list of them → frozenset;
    anything non-literal → None (caller marks the info unknown)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, typ):
        return frozenset({node.value})
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, typ):
                vals.add(e.value)
            else:
                return None
        return frozenset(vals)
    return None


@dataclasses.dataclass
class JitInfo:
    """Parsed jit options for one jitted callable."""

    node: ast.AST
    static_argnums: frozenset[int] = frozenset()
    static_argnames: frozenset[str] = frozenset()
    donate_argnums: frozenset[int] = frozenset()
    donate_argnames: frozenset[str] = frozenset()
    unknown: bool = False       # some option was not a parseable literal
    is_factory: bool = False    # name maps to a jit *factory*, not the
                                # jitted callable itself


def jit_info_from_keywords(node: ast.AST,
                           keywords: list[ast.keyword]) -> JitInfo:
    info = JitInfo(node)
    for kw in keywords:
        if kw.arg == "static_argnums":
            vals = _const_set(kw.value, int)
            info.static_argnums = vals or frozenset()
            info.unknown |= vals is None
        elif kw.arg == "static_argnames":
            vals = _const_set(kw.value, str)
            info.static_argnames = vals or frozenset()
            info.unknown |= vals is None
        elif kw.arg == "donate_argnums":
            vals = _const_set(kw.value, int)
            info.donate_argnums = vals or frozenset()
            info.unknown |= vals is None
        elif kw.arg == "donate_argnames":
            vals = _const_set(kw.value, str)
            info.donate_argnames = vals or frozenset()
            info.unknown |= vals is None
    return info


def jit_call_target(call: ast.Call,
                    imp: Imports) -> tuple[ast.AST | None, JitInfo] | None:
    """If ``call`` is ``jax.jit(f, ...)`` or ``partial(jax.jit, ...)``,
    return (wrapped expr or None, parsed JitInfo)."""
    if is_jit_ref(call.func, imp):
        target = call.args[0] if call.args else None
        return target, jit_info_from_keywords(call, call.keywords)
    if (is_partial_ref(call.func, imp) and call.args
            and is_jit_ref(call.args[0], imp)):
        target = call.args[1] if len(call.args) > 1 else None
        return target, jit_info_from_keywords(call, call.keywords)
    return None


def decorator_jit_info(func: ast.FunctionDef | ast.AsyncFunctionDef,
                       imp: Imports) -> JitInfo | None:
    for dec in func.decorator_list:
        if is_jit_ref(dec, imp):
            return JitInfo(dec)
        if isinstance(dec, ast.Call):
            hit = jit_call_target(dec, imp)
            if hit is not None:
                return hit[1]
    return None


def param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
                ) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def positional_params(fn) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def collect_jit_callables(tree: ast.Module,
                          imp: Imports) -> dict[str, JitInfo]:
    """Map local names to the jit options of the callable they hold.

    Covers jit-decorated defs, ``g = jax.jit(f, ...)`` wraps (both
    ``g`` and ``f``), jit-factory functions (a def whose return value
    is a nested jitted def — mapped with ``is_factory=True``), and
    locals assigned from a factory call (``run = _scan_fn(cap)``).
    """
    out: dict[str, JitInfo] = {}
    factories: dict[str, JitInfo] = {}

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = decorator_jit_info(node, imp)
            if info is not None:
                out[node.name] = info
                continue
            # factory? nested jitted def returned by name
            nested = {
                n.name: decorator_jit_info(n, imp)
                for n in node.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Return)
                        and isinstance(sub.value, ast.Name)
                        and nested.get(sub.value.id) is not None):
                    info = nested[sub.value.id]
                    factories[node.name] = info
                    out[node.name] = dataclasses.replace(
                        info, is_factory=True)
                    break

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.value, ast.Call)):
            continue
        target = dotted(node.targets[0])
        if target is None:
            continue
        hit = jit_call_target(node.value, imp)
        if hit is not None:
            wrapped, info = hit
            out[target] = info
            if isinstance(wrapped, ast.Name):
                out.setdefault(wrapped.id, info)
            continue
        callee = dotted(node.value.func)
        if callee in factories:
            out[target] = factories[callee]
    return out
