"""CC family: lock discipline and Future hygiene.

Written for the patterns in ``src/repro/service/`` (MicroBatcher,
PredictionService, WorkloadResolver) and ``src/repro/validate/store.py``.

CC301 — per class, an attribute becomes *lock-guarded* the moment any
method writes it inside ``with self.<lock>:``; every later access of
that attribute outside a lock block in a non-``__init__`` method is a
torn read / lost update.  ``__init__`` writes are exempt (publication
happens-before), and methods whose name contains ``locked`` are
treated as called-with-lock-held helpers.

CC302 — nested ``with self.A: ... with self.B:`` acquisitions define a
per-class order; two methods disagreeing on the order of the same pair
is a classic deadlock.

CC303 — a locally constructed ``Future`` must be resolved
(``set_result``/``set_exception``/``cancel``) or handed off (returned,
stored, passed to a call) on every path; a path that strands it hangs
the waiter forever.
"""
from __future__ import annotations

import ast

from repro.lint.analyzers._ast_utils import dotted, scan_imports
from repro.lint.engine import Finding, ModuleContext

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_RESOLVE_METHODS = {"set_result", "set_exception", "cancel"}


def _is_lock_ctor(call: ast.AST, imp) -> bool:
    if not isinstance(call, ast.Call):
        return False
    d = dotted(call.func)
    if d is None:
        return False
    parts = d.split(".")
    return parts[-1] in _LOCK_CTORS and (
        len(parts) == 1 or parts[0] in imp.threading_aliases)


def _self_attr(node: ast.AST) -> str | None:
    """``self.X`` (or the base attr of ``self.X.y``) → ``X``."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        node = node.value
    return None


def _with_lock_attrs(stmt: ast.With) -> list[str]:
    out = []
    for item in stmt.items:
        ctx_expr = item.context_expr
        attr = _self_attr(ctx_expr)
        if attr is not None:
            out.append(attr)
    return out


class _ClassInfo:
    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.locks: set[str] = set()
        self.guarded: set[str] = set()
        # attr -> node of the first guarded write (for the message)
        self.guard_site: dict[str, str] = {}


def _methods(cls: ast.ClassDef):
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield stmt


def _scan_class(cls: ast.ClassDef, imp) -> _ClassInfo:
    info = _ClassInfo(cls)
    for meth in _methods(cls):
        for sub in ast.walk(meth):
            if isinstance(sub, ast.Assign):
                for t in sub.targets:
                    attr = _self_attr(t)
                    if attr and _is_lock_ctor(sub.value, imp):
                        info.locks.add(attr)
            elif isinstance(sub, ast.With):
                for attr in _with_lock_attrs(sub):
                    info.locks.add(attr)
    for meth in _methods(cls):
        _collect_guarded(meth, meth.body, info, in_lock=False,
                         method=meth.name)
    return info


def _stores_in(node: ast.AST) -> list[str]:
    """self-attrs written by this statement (assign / augassign /
    write-through like ``self.stats.shed += 1`` counts for ``stats``)."""
    out = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
    else:
        return out
    for t in targets:
        attr = _self_attr(t)
        if attr:
            out.append(attr)
    return out


def _collect_guarded(meth, stmts, info: _ClassInfo, in_lock: bool,
                     method: str) -> None:
    for stmt in stmts:
        is_lock_with = isinstance(stmt, ast.With) and any(
            a in info.locks for a in _with_lock_attrs(stmt))
        if in_lock or is_lock_with:
            for sub in ast.walk(stmt):
                for attr in _stores_in(sub):
                    if attr not in info.locks:
                        info.guarded.add(attr)
                        info.guard_site.setdefault(attr, method)
        for field in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field, None)
            if isinstance(inner, list) and inner \
                    and isinstance(inner[0], ast.stmt):
                _collect_guarded(meth, inner, info,
                                 in_lock or is_lock_with, method)
        for h in getattr(stmt, "handlers", []) or []:
            _collect_guarded(meth, h.body, info, in_lock or is_lock_with,
                             method)


def _flag_unlocked(ctx: ModuleContext, info: _ClassInfo,
                   findings: list[Finding]) -> None:
    for meth in _methods(info.node):
        if meth.name == "__init__" or "locked" in meth.name:
            continue
        _walk_accesses(ctx, meth, meth.body, info, in_lock=False,
                       findings=findings, seen=set())


def _walk_accesses(ctx, meth, stmts, info: _ClassInfo, in_lock: bool,
                   findings: list[Finding], seen: set) -> None:
    for stmt in stmts:
        is_lock_with = isinstance(stmt, ast.With) and any(
            a in info.locks for a in _with_lock_attrs(stmt))
        inner_blocks = []
        for field in ("body", "orelse", "finalbody"):
            blk = getattr(stmt, field, None)
            if isinstance(blk, list) and blk \
                    and isinstance(blk[0], ast.stmt):
                inner_blocks.append(blk)
        for h in getattr(stmt, "handlers", []) or []:
            inner_blocks.append(h.body)
        if not (in_lock or is_lock_with):
            # examine only this statement's own expressions, not the
            # nested blocks (they are walked recursively below)
            for sub in _shallow_walk(stmt):
                attr = _self_attr(sub) if isinstance(
                    sub, (ast.Attribute, ast.Subscript)) else None
                if attr in info.guarded:
                    key = (meth.name, stmt.lineno, attr)
                    if key not in seen:
                        seen.add(key)
                        findings.append(ctx.finding(
                            "CC301", sub,
                            f"`self.{attr}` is lock-guarded (written "
                            f"under a lock in "
                            f"{info.guard_site.get(attr, 'another method')}"
                            f"()) but accessed here outside the lock"))
        for blk in inner_blocks:
            _walk_accesses(ctx, meth, blk, info,
                           in_lock or is_lock_with, findings, seen)


def _shallow_walk(stmt: ast.stmt):
    """Walk a statement's expressions without descending into nested
    statement blocks (those carry their own lock context)."""
    stack: list[ast.AST] = []
    for field, value in ast.iter_fields(stmt):
        if field in ("body", "orelse", "finalbody", "handlers"):
            continue
        if isinstance(value, ast.AST):
            stack.append(value)
        elif isinstance(value, list):
            stack.extend(v for v in value if isinstance(v, ast.AST))
    while stack:
        node = stack.pop()
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _lock_order_pairs(meth, stmts, info: _ClassInfo,
                      held: tuple[str, ...]) -> list[tuple[str, str, ast.With]]:
    pairs = []
    for stmt in stmts:
        new_held = held
        if isinstance(stmt, ast.With):
            acquired = [a for a in _with_lock_attrs(stmt)
                        if a in info.locks]
            for a in acquired:
                for h in new_held:
                    pairs.append((h, a, stmt))
                new_held = new_held + (a,)
        for field in ("body", "orelse", "finalbody"):
            blk = getattr(stmt, field, None)
            if isinstance(blk, list) and blk \
                    and isinstance(blk[0], ast.stmt):
                pairs.extend(_lock_order_pairs(meth, blk, info, new_held))
        for h in getattr(stmt, "handlers", []) or []:
            pairs.extend(_lock_order_pairs(meth, h.body, info, new_held))
    return pairs


def _flag_lock_order(ctx, info: _ClassInfo,
                     findings: list[Finding]) -> None:
    seen_order: dict[tuple[str, str], str] = {}
    for meth in _methods(info.node):
        for a, b, site in _lock_order_pairs(meth, meth.body, info, ()):
            if (b, a) in seen_order:
                findings.append(ctx.finding(
                    "CC302", site,
                    f"locks `{a}` then `{b}` acquired here, but "
                    f"{seen_order[(b, a)]}() acquires `{b}` then `{a}` "
                    f"— inconsistent order risks deadlock"))
            else:
                seen_order.setdefault((a, b), meth.name)


# -- CC303: stranded futures --------------------------------------------------

def _is_future_ctor(call: ast.AST, imp) -> bool:
    if not isinstance(call, ast.Call):
        return False
    d = dotted(call.func)
    if d is None:
        return False
    if d in imp.future_names:
        return True
    parts = d.split(".")
    return parts[-1] == "Future" and (
        parts[0] in imp.futures_aliases or parts[0] == "concurrent")


def _discharges(stmt: ast.stmt, name: str) -> bool:
    """Does this statement (ignoring nested blocks) resolve or hand off
    the future bound to ``name``?"""
    for sub in _shallow_walk(stmt):
        if isinstance(sub, ast.Call):
            if (isinstance(sub.func, ast.Attribute)
                    and isinstance(sub.func.value, ast.Name)
                    and sub.func.value.id == name
                    and sub.func.attr in _RESOLVE_METHODS):
                return True
            for a in sub.args:
                if any(isinstance(s, ast.Name) and s.id == name
                       for s in ast.walk(a)):
                    return True
            for kw in sub.keywords:
                if any(isinstance(s, ast.Name) and s.id == name
                       for s in ast.walk(kw.value)):
                    return True
    if isinstance(stmt, (ast.Return, ast.Yield)) and stmt.value is not None:
        if any(isinstance(s, ast.Name) and s.id == name
               for s in ast.walk(stmt.value)):
            return True
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            if not (isinstance(t, ast.Name) and t.id == name):
                # stored somewhere (self.x = f, d[k] = f, other = f)
                if any(isinstance(s, ast.Name) and s.id == name
                       and isinstance(s.ctx, ast.Load)
                       for s in ast.walk(stmt.value)):
                    return True
    return False


def _covers(stmts: list[ast.stmt], name: str) -> bool:
    """True if every path through ``stmts`` discharges the future."""
    for stmt in stmts:
        if _discharges(stmt, name):
            return True
        if isinstance(stmt, ast.If):
            if stmt.orelse and _covers(stmt.body, name) \
                    and _covers(stmt.orelse, name):
                return True
        elif isinstance(stmt, ast.Try):
            handlers_ok = all(_covers(h.body, name)
                              for h in stmt.handlers) if stmt.handlers \
                else True
            if _covers(stmt.body + stmt.orelse, name) and handlers_ok:
                return True
        elif isinstance(stmt, (ast.For, ast.While)):
            # lenient: a discharge inside a loop is accepted (zero-trip
            # hazards are below this tool's precision)
            if _covers(stmt.body, name):
                return True
        elif isinstance(stmt, ast.With):
            if _covers(stmt.body, name):
                return True
        if isinstance(stmt, (ast.Return, ast.Raise, ast.Break,
                             ast.Continue)):
            return False  # path ends with the future stranded
    return False


def _flag_futures(ctx: ModuleContext, imp,
                  findings: list[Finding]) -> None:
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for idx, stmt in enumerate(fn.body):
            if not (isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and _is_future_ctor(stmt.value, imp)):
                continue
            name = stmt.targets[0].id
            if not _covers(fn.body[idx + 1:], name):
                findings.append(ctx.finding(
                    "CC303", stmt,
                    f"Future `{name}` has a code path that neither "
                    f"resolves (set_result/set_exception/cancel) nor "
                    f"hands it off — its waiter would hang forever"))


def analyze(ctx: ModuleContext) -> list[Finding]:
    imp = scan_imports(ctx.tree)
    if not imp.has_threads:
        return []
    findings: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ClassDef):
            info = _scan_class(node, imp)
            if info.locks:
                _flag_unlocked(ctx, info, findings)
                _flag_lock_order(ctx, info, findings)
    _flag_futures(ctx, imp, findings)
    return findings
