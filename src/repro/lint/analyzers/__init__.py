"""Analyzer registry: each family exposes ``analyze(ctx) -> [Finding]``."""
from __future__ import annotations

from repro.lint.analyzers import cache_keys, concurrency, donation, jax_purity

ALL_ANALYZERS = (
    jax_purity.analyze,
    donation.analyze,
    concurrency.analyze,
    cache_keys.analyze,
)

__all__ = ["ALL_ANALYZERS", "jax_purity", "donation", "concurrency",
           "cache_keys"]
