"""Project-specific static analysis for the repro codebase.

Four analyzer families guard the invariants the test suite cannot see:

* **JP** (jax-purity) — no host syncs, traced control flow, or
  recompile hazards inside jit-reachable code.
* **DN** (donation) — carry buffers rebound through jitted calls must
  be donated; donated buffers must not be read after the call.
* **CC** (concurrency) — lock-guarded attributes stay under their
  lock, lock order is consistent, Futures always resolve.
* **CK** (cache-keys) — fingerprint inputs reach the key,
  ``STORE_VERSION`` namespaces the key path, save/load meta agree.

Entry points: ``python -m repro.lint`` / the ``repro-lint`` console
script; programmatic use via :func:`lint_paths`.
"""
from repro.lint.engine import Finding, LintResult, ModuleContext, lint_paths
from repro.lint.rules import RULES, Rule, rules_by_family

__all__ = [
    "Finding",
    "LintResult",
    "ModuleContext",
    "RULES",
    "Rule",
    "lint_paths",
    "rules_by_family",
]
