"""Rule catalogue for ``repro.lint``.

Every rule has a stable ID (family prefix + number), a severity, a
one-line summary, and a fix hint.  The catalogue is the single source
of truth: analyzers import their rules from here, ``docs/lint.md``
documents exactly this set (cross-checked by ``tools/docs_check.py``),
and suppression comments / baseline entries reference rules by ID.

Families:

* ``JP`` — jax-purity: host syncs, Python control flow on traced
  values, and recompile hazards inside jit-reachable code.
* ``DN`` — donation: rebound jit carries without ``donate_argnums``
  and use-after-donation at call sites.
* ``CC`` — concurrency: lock-guarded attribute discipline, lock
  acquisition order, and Future resolution paths.
* ``CK`` — cache-key invariants: fingerprint/key field coverage,
  ``STORE_VERSION`` in the key path, and save/load meta symmetry.
"""
from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One checkable invariant: stable ID, severity, summary, fix hint."""

    id: str
    name: str
    severity: str
    summary: str
    fix_hint: str


RULES: dict[str, Rule] = {}


def _rule(rule_id: str, name: str, severity: str, summary: str,
          fix_hint: str) -> Rule:
    if severity not in SEVERITIES:
        raise ValueError(f"unknown severity {severity!r} for {rule_id}")
    if rule_id in RULES:
        raise ValueError(f"duplicate rule id {rule_id}")
    r = Rule(rule_id, name, severity, summary, fix_hint)
    RULES[rule_id] = r
    return r


# --- JP: jax purity ----------------------------------------------------------

JP101 = _rule(
    "JP101", "jit-print", "error",
    "print() inside jit-reachable code (runs at trace time only, or "
    "not at all on later calls)",
    "use jax.debug.print(...) for traced values, or move the print "
    "outside the jitted function",
)
JP102 = _rule(
    "JP102", "jit-host-sync", "error",
    "host synchronization of a traced value inside jit-reachable code "
    "(float()/int()/bool()/.item()/.tolist() force a device round-trip "
    "or fail under tracing)",
    "keep the computation in jnp (jnp.where / lax.cond), or hoist the "
    "conversion out of the jitted function",
)
JP103 = _rule(
    "JP103", "jit-numpy-on-traced", "error",
    "numpy call applied to a traced value inside jit-reachable code "
    "(np.* materializes the tracer on host)",
    "use the jnp equivalent, or move the numpy post-processing outside "
    "the jitted function",
)
JP110 = _rule(
    "JP110", "jit-traced-control-flow", "error",
    "Python if/while/for/assert conditioned on a traced value inside "
    "jit-reachable code (TracerBoolConversionError at trace time)",
    "use jnp.where / jax.lax.cond / jax.lax.while_loop; comparisons "
    "against Python config values and `x is None` checks are fine",
)
JP120 = _rule(
    "JP120", "jit-in-loop", "error",
    "jax.jit(...) constructed inside a loop body (a fresh jitted "
    "callable recompiles on every iteration)",
    "hoist the jit() call out of the loop, or cache the jitted "
    "callable (module level / functools.lru_cache factory)",
)
JP121 = _rule(
    "JP121", "jit-data-length-static", "warning",
    "static jit argument derived from a data length (len()/.shape/"
    ".size) at the call site — one XLA compilation per distinct length",
    "pad or bucket the length to powers of two before passing it "
    "static (see repro.api.batched._row_shape_key)",
)

# --- DN: donation ------------------------------------------------------------

DN201 = _rule(
    "DN201", "undonated-carry", "warning",
    "jitted call rebinds an argument from its own result (a carry) but "
    "the jit wrapper does not donate that argument's buffer",
    "add donate_argnums=(<pos>,) to the jax.jit wrapper so XLA reuses "
    "the carry buffer in place (see core/reuse/batched.py)",
)
DN202 = _rule(
    "DN202", "use-after-donation", "error",
    "a donated argument is read again after the jitted call (donated "
    "buffers are invalidated by XLA)",
    "rebind the variable from the call result, or stop donating the "
    "argument",
)

# --- CC: concurrency ---------------------------------------------------------

CC301 = _rule(
    "CC301", "unlocked-guarded-attr", "error",
    "attribute is written under a lock elsewhere in this class but "
    "accessed outside it here (torn reads / lost updates)",
    "wrap the access in the same `with self.<lock>:` block (writes in "
    "__init__ happen-before publication and are exempt)",
)
CC302 = _rule(
    "CC302", "lock-order", "error",
    "locks are acquired in different orders by different methods of "
    "one class (deadlock risk)",
    "pick one global acquisition order for the class and restructure "
    "the method that violates it",
)
CC303 = _rule(
    "CC303", "unresolved-future", "warning",
    "a locally created Future has a code path that neither resolves "
    "(set_result/set_exception/cancel) nor hands it off (return / "
    "store / pass to a call)",
    "resolve or cancel the future on every path — a stranded future "
    "hangs its waiter forever",
)

# --- CK: cache-key invariants ------------------------------------------------

CK401 = _rule(
    "CK401", "key-field-unused", "error",
    "a fingerprint/key function reads a parameter or attribute that "
    "never flows into the returned key (two distinct inputs would "
    "collide on one cache entry)",
    "interpolate the field into the key, or add it to the analyzer's "
    "exclusion table with a justification",
)
CK402 = _rule(
    "CK402", "store-version-not-in-key-path", "error",
    "the module defines STORE_VERSION but the on-disk key path does "
    "not interpolate a version component (a format bump would misread "
    "old entries instead of orphaning them)",
    "namespace every key under f\"v{version}\" and default the store "
    "version to STORE_VERSION",
)
CK403 = _rule(
    "CK403", "meta-field-asymmetry", "error",
    "a save_*/load_* pair disagrees on the persisted meta fields "
    "(a field written but never restored, or read but never written)",
    "read the field in load_* (or drop it from save_*); genuinely "
    "write-only provenance fields need a justified suppression",
)


def rules_by_family() -> dict[str, list[Rule]]:
    fams: dict[str, list[Rule]] = {}
    for r in RULES.values():
        fams.setdefault(r.id[:2], []).append(r)
    return {k: sorted(v, key=lambda r: r.id) for k, v in sorted(fams.items())}
