"""Committed-baseline support: accept known findings, fail on new ones.

A baseline is a JSON file mapping finding fingerprints (rule + file +
line *content* + occurrence index — stable across line-number drift) to
a human-readable record.  ``--write-baseline`` snapshots the current
findings; ``--check`` fails only on findings whose fingerprint is not
in the baseline, and reports (without failing) baseline entries that no
longer match anything so the file shrinks over time.

Repo convention: the committed baseline should be empty — genuine
findings get fixed, deliberate exceptions get an inline
``# repro-lint: disable=RULE -- reason`` suppression next to the code
they excuse.  The baseline exists for incremental adoption (landing
the linter before a large fix-up) and for rules added faster than
their findings can be burned down.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path

from repro.lint.engine import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = ".repro-lint-baseline.json"


def fingerprints(findings: list[Finding]) -> dict[str, Finding]:
    """Fingerprint every finding, disambiguating identical lines by
    occurrence index (two copies of one offending line get two
    entries)."""
    seen: Counter = Counter()
    out: dict[str, Finding] = {}
    for f in findings:
        key = (f.rule_id, f.path, f.line_text)
        out[f.fingerprint(seen[key])] = f
        seen[key] += 1
    return out


def write_baseline(path: str | Path, findings: list[Finding]) -> dict:
    entries = {
        fp: {"rule": f.rule_id, "path": f.path, "line_text": f.line_text}
        for fp, f in fingerprints(findings).items()
    }
    payload = {"version": BASELINE_VERSION, "entries": entries}
    Path(path).write_text(json.dumps(payload, indent=2, sort_keys=True)
                          + "\n")
    return payload


def load_baseline(path: str | Path) -> dict[str, dict]:
    p = Path(path)
    if not p.is_file():
        return {}
    payload = json.loads(p.read_text())
    if payload.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {p} has version {payload.get('version')!r}; "
            f"this tool writes version {BASELINE_VERSION} — regenerate "
            f"with --write-baseline"
        )
    return dict(payload.get("entries", {}))


@dataclasses.dataclass
class BaselineDiff:
    new: list[Finding]          # findings not covered by the baseline
    accepted: list[Finding]     # findings the baseline covers
    stale: list[str]            # baseline fingerprints matching nothing


def apply_baseline(findings: list[Finding],
                   entries: dict[str, dict]) -> BaselineDiff:
    fps = fingerprints(findings)
    new = [f for fp, f in fps.items() if fp not in entries]
    accepted = [f for fp, f in fps.items() if fp in entries]
    stale = sorted(fp for fp in entries if fp not in fps)
    new.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return BaselineDiff(new=new, accepted=accepted, stale=stale)
