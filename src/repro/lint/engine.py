"""Lint engine: file walking, suppression comments, finding plumbing.

The engine owns everything rule-agnostic: parsing each file once into a
:class:`ModuleContext`, running every registered analyzer over it,
filtering ``# repro-lint: disable=RULE`` suppressions, and stamping
each surviving :class:`Finding` with a line-content fingerprint (stable
across unrelated line-number drift) that the baseline machinery keys
on.

Suppression grammar (checked on the finding's line, the line above it,
and file-wide):

    x = something()          # repro-lint: disable=JP102
    # repro-lint: disable=CC301  -- justification for the next line
    # repro-lint: disable-file=CK403  -- justification (whole file)

A bare ``disable=`` with no justification still works — but the
repo convention (enforced by review, not the tool) is that every
suppression carries a reason after ``--``.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
from pathlib import Path

from repro.lint.rules import RULES, Rule

_DISABLE_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9*,\s]+?)(?:\s*--.*)?$"
)
_DISABLE_FILE_RE = re.compile(
    r"#\s*repro-lint:\s*disable-file=([A-Za-z0-9*,\s]+?)(?:\s*--.*)?$"
)

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".pytest_cache"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule_id: str
    path: str                  # posix-relative to the lint root
    line: int
    col: int
    message: str
    line_text: str = ""        # stripped source line (fingerprint input)

    @property
    def rule(self) -> Rule:
        return RULES[self.rule_id]

    @property
    def severity(self) -> str:
        return self.rule.severity

    def fingerprint(self, occurrence: int = 0) -> str:
        """Stable identity for baselining: rule + file + line *content*
        (not number) + occurrence index among identical lines — so
        unrelated edits shifting line numbers don't churn the baseline.
        """
        blob = f"{self.rule_id}|{self.path}|{self.line_text}|{occurrence}"
        return hashlib.sha1(blob.encode()).hexdigest()[:20]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule_id,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "fix_hint": self.rule.fix_hint,
        }

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule_id} [{self.severity}] {self.message}")


def _parse_disables(blob: str) -> set[str]:
    return {tok.strip() for tok in blob.split(",") if tok.strip()}


class ModuleContext:
    """One parsed source file plus its suppression map."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self._line_disables: dict[int, set[str]] = {}
        self._file_disables: set[str] = set()
        for i, line in enumerate(self.lines, start=1):
            m = _DISABLE_FILE_RE.search(line)
            if m:
                self._file_disables |= _parse_disables(m.group(1))
                continue
            m = _DISABLE_RE.search(line)
            if m:
                self._line_disables[i] = _parse_disables(m.group(1))

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def _match(self, rules: set[str], rule_id: str) -> bool:
        return "*" in rules or rule_id in rules or rule_id[:2] in rules

    def suppressed(self, rule_id: str, line: int) -> bool:
        """A rule is suppressed on its own line, by a comment-only line
        directly above, or file-wide."""
        if self._match(self._file_disables, rule_id):
            return True
        for cand in (line, line - 1):
            rules = self._line_disables.get(cand)
            if rules is None:
                continue
            if cand == line - 1:
                # the line above only scopes to the next line when it is
                # a pure comment (otherwise it suppresses itself only)
                text = self.line_text(cand)
                if not text.startswith("#"):
                    continue
            if self._match(rules, rule_id):
                return True
        return False

    def finding(self, rule_id: str, node: ast.AST | tuple[int, int],
                message: str) -> Finding:
        if isinstance(node, tuple):
            line, col = node
        else:
            line = getattr(node, "lineno", 1)
            col = getattr(node, "col_offset", 0)
        return Finding(rule_id, self.rel, line, col, message,
                       self.line_text(line))


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]
    files_checked: int = 0
    suppressed: int = 0
    parse_errors: list[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "files_checked": self.files_checked,
            "suppressed": self.suppressed,
            "parse_errors": self.parse_errors,
            "findings": [f.to_dict() for f in self.findings],
        }


def iter_python_files(paths: list[Path]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS or part.startswith(".")
                           for part in f.parts):
                    out.append(f)
    return out


def _analyzers():
    from repro.lint.analyzers import ALL_ANALYZERS

    return ALL_ANALYZERS


def lint_paths(paths: list[str | Path], *,
               root: str | Path | None = None) -> LintResult:
    """Lint every ``.py`` under ``paths``; findings are reported with
    paths relative to ``root`` (default: the current directory)."""
    root = Path(root) if root is not None else Path.cwd()
    result = LintResult(findings=[])
    for file in iter_python_files([Path(p) for p in paths]):
        try:
            rel = file.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = file.as_posix()
        try:
            ctx = ModuleContext(file, rel, file.read_text())
        except (SyntaxError, UnicodeDecodeError, OSError) as exc:
            result.parse_errors.append(f"{rel}: {exc}")
            continue
        result.files_checked += 1
        for analyze in _analyzers():
            for finding in analyze(ctx):
                if ctx.suppressed(finding.rule_id, finding.line):
                    result.suppressed += 1
                else:
                    result.findings.append(finding)
    result.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return result
