"""``python -m repro.lint`` / ``repro-lint`` command line.

Exit-code contract (what CI keys on):

* ``0`` — no findings, or every finding is covered by the baseline
  (``--report-only`` always exits 0).
* ``1`` — at least one unbaselined finding.
* ``2`` — a file failed to parse, the baseline is unreadable, or the
  arguments are inconsistent.

Typical invocations::

    python -m repro.lint src tools                  # human output
    python -m repro.lint --json src                 # machine output
    python -m repro.lint --check --baseline .repro-lint-baseline.json src tools
    python -m repro.lint --write-baseline --baseline FILE src
    python -m repro.lint --report-only tests        # inventory, exit 0
    python -m repro.lint --list-rules
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.lint.baseline import (
    DEFAULT_BASELINE,
    apply_baseline,
    load_baseline,
    write_baseline,
)
from repro.lint.engine import lint_paths
from repro.lint.rules import RULES, rules_by_family

_FAMILY_TITLES = {
    "JP": "jax-purity",
    "DN": "donation",
    "CC": "concurrency",
    "CK": "cache-keys",
}


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-lint",
        description="Project-specific static analysis: JAX purity, "
                    "buffer donation, lock discipline, cache-key "
                    "invariants.",
    )
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (default: src/ "
                        "and tools/ if they exist)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON")
    p.add_argument("--baseline", metavar="FILE", nargs="?",
                   const=DEFAULT_BASELINE, default=None,
                   help=f"baseline file (default when given bare: "
                        f"{DEFAULT_BASELINE})")
    p.add_argument("--write-baseline", action="store_true",
                   help="snapshot current findings into the baseline "
                        "and exit 0")
    p.add_argument("--check", action="store_true",
                   help="fail (exit 1) on findings not covered by the "
                        "baseline")
    p.add_argument("--report-only", action="store_true",
                   help="print findings but always exit 0 (inventory "
                        "mode)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    return p


def _list_rules() -> None:
    for family, rules in rules_by_family().items():
        print(f"{family} ({_FAMILY_TITLES.get(family, family)})")
        for r in rules:
            print(f"  {r.id} [{r.severity:7s}] {r.name}: {r.summary}")


def main(argv: list[str] | None = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        _list_rules()
        return 0

    paths = args.paths or [p for p in ("src", "tools") if Path(p).is_dir()]
    if not paths:
        print("repro-lint: no paths given and no src/ or tools/ here",
              file=sys.stderr)
        return 2

    result = lint_paths(paths)
    for err in result.parse_errors:
        print(f"repro-lint: parse error: {err}", file=sys.stderr)

    baseline_path = args.baseline
    if baseline_path is None and not args.write_baseline \
            and Path(DEFAULT_BASELINE).is_file():
        baseline_path = DEFAULT_BASELINE

    if args.write_baseline:
        target = baseline_path or DEFAULT_BASELINE
        payload = write_baseline(target, result.findings)
        print(f"repro-lint: wrote {len(payload['entries'])} baseline "
              f"entr{'y' if len(payload['entries']) == 1 else 'ies'} "
              f"to {target}")
        return 0 if not result.parse_errors else 2

    entries = {}
    if baseline_path is not None:
        try:
            entries = load_baseline(baseline_path)
        except (ValueError, OSError, json.JSONDecodeError) as exc:
            print(f"repro-lint: bad baseline: {exc}", file=sys.stderr)
            return 2
    diff = apply_baseline(result.findings, entries)

    if args.as_json:
        payload = result.to_dict()
        payload["new_findings"] = [f.to_dict() for f in diff.new]
        payload["baselined"] = len(diff.accepted)
        payload["stale_baseline_entries"] = diff.stale
        print(json.dumps(payload, indent=2))
    else:
        for f in diff.new:
            print(f.render())
            if f.rule.fix_hint:
                print(f"    hint: {f.rule.fix_hint}")
        summary = (f"repro-lint: {result.files_checked} files, "
                   f"{len(diff.new)} finding(s)")
        if diff.accepted:
            summary += f", {len(diff.accepted)} baselined"
        if result.suppressed:
            summary += f", {result.suppressed} suppressed inline"
        if diff.stale:
            summary += (f", {len(diff.stale)} stale baseline entr"
                        f"{'y' if len(diff.stale) == 1 else 'ies'} "
                        f"(regenerate with --write-baseline)")
        print(summary)

    if result.parse_errors:
        return 2
    if args.report_only:
        return 0
    return 1 if diff.new else 0


if __name__ == "__main__":
    raise SystemExit(main())
