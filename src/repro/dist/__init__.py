from repro.dist.sharding import (
    ShardingRules,
    param_shardings,
    pspec_for,
    shard,
    use_sharding,
)

__all__ = [
    "ShardingRules",
    "param_shardings",
    "pspec_for",
    "shard",
    "use_sharding",
]
