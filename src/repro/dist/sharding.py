"""Logical-axes sharding: one rules table maps model-code axis names
onto whatever mesh the run happens to have.

Model code annotates arrays with *logical* names (``"embed"``,
``"act_batch"``, ...) via :func:`shard` and :class:`PSpec` axes; this
module resolves them to mesh axes through a :class:`ShardingRules`
table.  Resolution is mesh-aware and total:

* rules may name mesh axes the current mesh doesn't have (a host mesh
  has no ``"model"`` axis) — those silently replicate;
* a dimension that a mapped mesh axis doesn't divide falls back to
  replication (recorded, so ``plan_remesh`` can report it);
* a mesh axis is never used twice within one ``PartitionSpec``.

Inside ``with use_sharding(rules):`` every :func:`shard` call becomes a
``with_sharding_constraint``; outside any context it is the identity,
so the same model code runs unsharded on a laptop and sharded on a pod.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Default logical-axis -> mesh-axis table.  Tuples try the axes in
# order (DP runs over ("pod", "data") when both exist).  ``None``
# replicates.  Unknown logical names replicate.
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_kv_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
    # parameters
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    # stacked/scanned leading axes are never sharded
    "layers": None,
    "groups": None,
}


def _as_tuple(v) -> tuple:
    if v is None:
        return ()
    if isinstance(v, (tuple, list)):
        return tuple(v)
    return (v,)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """A mesh plus the logical->physical axis table for one run."""

    mesh: Mesh
    rules: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        merged = dict(DEFAULT_RULES)
        merged.update(self.rules or {})
        object.__setattr__(self, "rules", merged)

    def with_overrides(self, **overrides) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return ShardingRules(self.mesh, merged)

    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        """Mesh axes (present in this mesh) a logical axis maps onto."""
        if logical is None:
            return ()
        mapped = _as_tuple(self.rules.get(logical))
        return tuple(a for a in mapped if a in self.mesh.shape)

    def axis_size(self, axes) -> int:
        """Product of mesh-axis sizes (missing axes count as 1)."""
        return math.prod(
            self.mesh.shape.get(a, 1) for a in _as_tuple(axes)
        ) or 1

    @property
    def dp_axes(self) -> tuple[str, ...]:
        """Mesh axes the batch dimension shards over."""
        return self.mesh_axes_for("act_batch")


def pspec_for(
    shape,
    logical_axes,
    rules: ShardingRules,
    fallbacks: list | None = None,
) -> PartitionSpec:
    """PartitionSpec for an array of ``shape`` whose dims carry
    ``logical_axes`` names (None entries replicate).

    Mesh axes that don't divide the dimension, or that an earlier
    dimension already consumed, fall back to replication; each such
    event is appended to ``fallbacks`` as ``(logical_axis, dim)``.
    """
    axes = _as_tuple(logical_axes)
    if len(axes) < len(shape):
        axes = axes + (None,) * (len(shape) - len(axes))
    used: set[str] = set()
    entries: list = []
    for dim, logical in zip(range(len(shape)), axes):
        mapped = rules.mesh_axes_for(logical)
        avail = tuple(a for a in mapped if a not in used)
        extent = math.prod(rules.mesh.shape[a] for a in avail) if avail else 1
        if not avail:
            if mapped and fallbacks is not None:
                fallbacks.append((logical, dim))
            entries.append(None)
            continue
        if shape[dim] % extent != 0:
            if fallbacks is not None:
                fallbacks.append((logical, dim))
            entries.append(None)
            continue
        used.update(avail)
        entries.append(avail[0] if len(avail) == 1 else avail)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple)
        and all(isinstance(a, str) or a is None for a in x)
    )


def param_shardings(abstract_tree, axes_tree, rules: ShardingRules):
    """(NamedSharding tree, fallback list) for a pytree of abstract
    arrays and a parallel tree of logical-axes tuples."""
    leaves, treedef = jax.tree_util.tree_flatten(abstract_tree)
    axes_leaves = treedef.flatten_up_to(axes_tree)
    fallbacks: list = []
    shardings = []
    for leaf, axes in zip(leaves, axes_leaves):
        spec = pspec_for(tuple(leaf.shape), axes, rules, fallbacks)
        shardings.append(NamedSharding(rules.mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings), fallbacks


# --- the shard() constraint ---------------------------------------------------

_ACTIVE: list[ShardingRules] = []


@contextlib.contextmanager
def use_sharding(rules: ShardingRules):
    """Activate ``rules`` for :func:`shard` calls in this block (the
    block typically being a function body under jit tracing)."""
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> ShardingRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


def shard(x, *logical_axes):
    """Constrain ``x``'s sharding by logical axis names.  Identity when
    no rules are active (unsharded/debug runs)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = pspec_for(tuple(x.shape), logical_axes, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# --- work partitioning for the sharded reuse engines -------------------------


def local_shard_count() -> int:
    """Natural shard count for device-parallel host dispatch: the local
    device count (1 on a single-CPU/laptop run, so sharded entry points
    degenerate to the monolithic pass there)."""
    return jax.local_device_count()


def partition_segments(lengths, num_shards: int) -> list[list[int]]:
    """Deterministic LPT partition of independent work items.

    Items (identified by index into ``lengths``) are assigned
    longest-first to the currently least-loaded shard; every tie breaks
    on the lower index, so the partition is a pure function of
    ``(lengths, num_shards)`` — reruns and resumptions shard
    identically.  Within each shard, indices come back sorted, and
    every shard list is present (possibly empty).
    """
    num_shards = max(int(num_shards), 1)
    order = sorted(range(len(lengths)),
                   key=lambda i: (-int(lengths[i]), i))
    loads = [0] * num_shards
    groups: list[list[int]] = [[] for _ in range(num_shards)]
    for i in order:
        s = min(range(num_shards), key=lambda j: (loads[j], j))
        loads[s] += int(lengths[i])
        groups[s].append(i)
    return [sorted(g) for g in groups]
