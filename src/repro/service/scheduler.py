"""Microbatching scheduler: queue -> collect -> dedup -> group.

The service's concurrency story is deliberately simple: submitters put
:class:`PendingRequest` items on ONE bounded queue, and ONE worker
thread owns the Session.  The Session (and its artifact caches) are
never touched from two threads, so no stage needs locking — the
scheduler turns concurrency into batch size instead.

Batch formation (``MicroBatcher.collect``):

1. block for the first item (idle costs nothing);
2. keep draining the queue until either ``max_batch`` items are
   gathered or ``max_wait_s`` has elapsed since the first item — a
   partial batch *always* flushes when the wait window closes, a
   lone request is never stranded;
3. hand the batch to the service's executor.

Within a batch, :func:`coalesce` dedups identical computations (same
``key``: by default the same source object + an equal request), so N
clients asking the same question cost one evaluation fanned out to all
N futures.  The whole coalesced batch then goes to ONE
``Session.predict_many`` call — kernel-compatibility grouping happens
*inside* the batched kernel, which buckets rows by their own
(A_MAX, padded-M) shape (``repro.api.batched._row_shape_key``), so an
odd cache geometry can never force the common bucket to recompile and
the scheduler has nothing left to split.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

from repro.api.request import PredictionRequest


@dataclasses.dataclass
class PendingRequest:
    """One submitted request waiting in the queue."""

    source: object
    request: PredictionRequest
    key: object                 # dedup identity (hashable)
    future: Future
    enqueued_at: float          # time.monotonic() at submit


@dataclasses.dataclass
class Computation:
    """One unique computation a batch performs; ``waiters`` are every
    pending request that coalesced onto it (>= 1)."""

    key: object
    source: object
    request: PredictionRequest
    waiters: list[PendingRequest]


def default_key(source, request: PredictionRequest) -> object:
    """Dedup identity used when the submitter doesn't provide one.

    Source identity is the *object* (``id``), not the trace content:
    hashing a trace is O(N) and must stay on the worker thread.  The
    HTTP server resolves workloads through a cache, so equal specs map
    to one object; in-process callers submitting distinct-but-equal
    trace objects should pass an explicit ``key``.  The pending item
    pins the source, so the id cannot be recycled while queued.
    """
    return (id(source), request)


def coalesce(batch: list[PendingRequest]) -> list[Computation]:
    """Dedup a batch by key, preserving first-seen order."""
    by_key: dict[object, Computation] = {}
    for item in batch:
        comp = by_key.get(item.key)
        if comp is None:
            by_key[item.key] = Computation(
                item.key, item.source, item.request, [item]
            )
        else:
            comp.waiters.append(item)
    return list(by_key.values())


def resolve_future(future: Future, result=None, error=None) -> bool:
    """Resolve a waiter's future, tolerating callers that cancelled it
    while it was queued (``set_result`` on a cancelled future raises
    ``InvalidStateError`` — which must never kill the worker thread).
    Returns False when the future was already cancelled/resolved."""
    if not future.set_running_or_notify_cancel():
        return False
    if error is not None:
        future.set_exception(error)
    else:
        future.set_result(result)
    return True


_STOP = object()


class MicroBatcher:
    """Bounded queue + single collector thread.

    ``executor(batch)`` is called on the worker thread with each
    collected batch (a non-empty ``list[PendingRequest]``); it must
    resolve every item's future (result or exception) and never raise.
    """

    def __init__(self, executor, *, max_batch: int, max_wait_s: float,
                 queue_size: int, on_discard=None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._executor = executor
        self._on_discard = on_discard  # called with items left at stop
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queue: queue.Queue = queue.Queue(maxsize=queue_size)
        self._thread: threading.Thread | None = None
        self._stopped = False
        # serializes offer() against stop()'s flag flip: an offer either
        # lands before the stop sentinel (and is drained) or is rejected
        self._state_lock = threading.Lock()

    @property
    def depth(self) -> int:
        return self._queue.qsize()

    def start(self) -> None:
        if self._thread is not None:
            return
        # flips under the same lock as stop()/offer(): a restart racing
        # a concurrent offer() must not leave the flag torn
        with self._state_lock:
            self._stopped = False
        self._thread = threading.Thread(
            target=self._run, name="repro-service-batcher", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Stop the worker after draining everything already queued.

        The stop flag flips under the same lock ``offer`` holds, so
        every accepted item sits ahead of the sentinel and is served
        before the worker exits; later offers raise.  Anything
        unexpectedly left after the join (belt-and-braces — e.g. a
        sentinel re-queue interleaving) goes to ``on_discard`` so no
        waiter is ever stranded."""
        with self._state_lock:
            self._stopped = True
        thread, self._thread = self._thread, None
        if thread is not None:
            self._queue.put(_STOP)
            thread.join()
        # drain even when the worker never started (stop before start
        # must not strand an offered waiter either)
        leftovers = []
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                leftovers.append(item)
        if leftovers and self._on_discard is not None:
            self._on_discard(leftovers)

    def offer(self, item: PendingRequest) -> bool:
        """Enqueue without blocking; False means the queue is full (the
        caller sheds the request).  Raises ``RuntimeError`` once the
        batcher is stopped — a racing late submission must be rejected,
        not silently stranded behind the stop sentinel."""
        with self._state_lock:
            if self._stopped:
                raise RuntimeError("MicroBatcher is stopped")
            try:
                self._queue.put_nowait(item)
            except queue.Full:
                return False
            return True

    # --- worker side -------------------------------------------------------

    def collect(self, first: PendingRequest) -> list[PendingRequest]:
        """Gather one batch: up to ``max_batch`` items or until
        ``max_wait_s`` elapses past the first item, whichever first."""
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                item = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if item is _STOP:
                # re-queue so the outer loop sees it after this batch
                self._queue.put(_STOP)
                break
            batch.append(item)
        return batch

    def _run(self) -> None:
        while True:
            item = self._queue.get()
            if item is _STOP:
                return
            batch = self.collect(item)
            try:
                self._executor(batch)
            except BaseException as exc:  # noqa: BLE001 — keep serving
                # the executor contract is "never raise", but a dead
                # worker wedges the whole service; resolve the batch's
                # futures and keep going
                for pending in batch:
                    resolve_future(pending.future, error=exc)


@dataclasses.dataclass
class PoolStats:
    """Observable worker-pool behaviour (asserted by tests)."""

    submitted: int = 0          # accepted into the pending queue
    completed: int = 0          # futures resolved with a result
    failed: int = 0             # futures resolved with an exception
    cancelled: int = 0          # cancelled while pending
    shed: int = 0               # rejected, pending queue full
    active: int = 0             # jobs executing right now


class BoundedWorkerPool:
    """Fixed worker threads + a bounded pending queue for LONG jobs.

    The microbatcher above turns many small requests into batch size;
    this pool is its counterpart for requests that are individually
    expensive (config-space sweeps via ``POST /explore``): a separate,
    deliberately small lane so a multi-second search can never occupy
    the predict worker or its queue.  Backpressure is the same
    load-shedding contract — ``try_submit`` returns ``None`` when
    ``max_pending`` jobs are already waiting, and the HTTP layer maps
    that to 503 exactly like a full predict queue.
    """

    def __init__(self, *, max_workers: int = 1, max_pending: int = 2,
                 name: str = "repro-service-pool"):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.max_workers = max_workers
        self.max_pending = max_pending
        self._name = name
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending)
        self._threads: list[threading.Thread] = []
        self._stopped = False
        self.stats = PoolStats()
        # one lock serializes submit/stop/stat flips (MicroBatcher's
        # accepted-before-sentinel draining argument applies unchanged)
        self._state_lock = threading.Lock()

    @property
    def depth(self) -> int:
        return self._queue.qsize()

    def start(self) -> None:
        if self._threads:
            return
        with self._state_lock:
            self._stopped = False
        for i in range(self.max_workers):
            t = threading.Thread(
                target=self._run, name=f"{self._name}-{i}", daemon=True
            )
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        """Drain accepted jobs, then stop every worker.  Jobs still
        pending after the join (stop before start) resolve with a
        RuntimeError rather than stranding their waiters."""
        with self._state_lock:
            self._stopped = True
        threads, self._threads = self._threads, []
        for _ in threads:
            self._queue.put(_STOP)
        for t in threads:
            t.join()
        error = RuntimeError("worker pool stopped before this job ran")
        dropped = 0
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                continue
            _fn, future = item
            if resolve_future(future, error=error):
                dropped += 1
        with self._state_lock:
            self.stats.failed += dropped

    def try_submit(self, fn) -> Future | None:
        """Enqueue ``fn`` (a zero-arg callable); ``None`` means the
        pending lane is full and the caller sheds.  Raises
        ``RuntimeError`` once stopped."""
        future: Future = Future()
        with self._state_lock:
            if self._stopped:
                raise RuntimeError("BoundedWorkerPool is stopped")
            try:
                self._queue.put_nowait((fn, future))
            except queue.Full:
                self.stats.shed += 1
                return None
            self.stats.submitted += 1
        return future

    def stats_dict(self) -> dict:
        with self._state_lock:
            out = dataclasses.asdict(self.stats)
        out["depth"] = self.depth
        out["max_workers"] = self.max_workers
        out["max_pending"] = self.max_pending
        return out

    def _run(self) -> None:
        while True:
            item = self._queue.get()
            if item is _STOP:
                return
            fn, future = item
            # mark running BEFORE executing: a cancel can only win while
            # the job is still pending, never mid-flight
            if not future.set_running_or_notify_cancel():
                with self._state_lock:
                    self.stats.cancelled += 1
                continue
            with self._state_lock:
                self.stats.active += 1
            try:
                result = fn()
            except BaseException as exc:  # noqa: BLE001 — forwarded
                future.set_exception(exc)
                with self._state_lock:
                    self.stats.active -= 1
                    self.stats.failed += 1
            else:
                future.set_result(result)
                with self._state_lock:
                    self.stats.active -= 1
                    self.stats.completed += 1
