"""repro.service — concurrent prediction service with request
coalescing.

    from repro.service import PredictionService, ServiceConfig
    from repro.api import PredictionRequest

    with PredictionService(artifact_dir=".cache/artifacts") as svc:
        fut = svc.submit(workload, PredictionRequest(
            targets=("i7-5960X",), core_counts=(1, 4, 8),
        ))
        resp = fut.result()
        print(resp.result.to_table(), resp.timing.batch_size)

Many threads (or HTTP clients — ``python -m repro.service``) submit
independent :class:`repro.api.PredictionRequest`\\ s; a microbatching
scheduler dedups identical requests, coalesces compatible ones, and
evaluates each batch through ONE call into the batched vmapped SDCM
grid kernel via ``Session.predict_many``.  A shared
``Session(artifact_dir=...)`` means a warm disk store serves reuse
profiles with zero rebuilds across service processes.  Architecture,
tuning knobs, and failure modes: docs/service.md.
"""
from repro.service.scheduler import (
    Computation,
    MicroBatcher,
    PendingRequest,
    coalesce,
    default_key,
    resolve_future,
)
from repro.service.service import (
    PredictionService,
    RequestTiming,
    ServiceConfig,
    ServiceOverloadedError,
    ServiceResponse,
    ServiceStats,
)

__all__ = [
    "Computation",
    "MicroBatcher",
    "PendingRequest",
    "PredictionService",
    "RequestTiming",
    "ServiceConfig",
    "ServiceOverloadedError",
    "ServiceResponse",
    "ServiceStats",
    "coalesce",
    "default_key",
    "resolve_future",
]
