"""HTTP front door for the prediction service (stdlib only).

A ``ThreadingHTTPServer`` gives every client connection its own
handler thread; all those threads funnel into the service's ONE
bounded queue, so concurrent HTTP clients become microbatches for the
batched SDCM kernel exactly like in-process submitters.

Endpoints (JSON in/out):

    POST /predict   {"workload": "polybench/atx", "sizes": "smoke",
                     "targets": [...], "core_counts": [1, 4, 8],
                     "strategies": ["round_robin"], "runtime": true,
                     "runtime_model": "auto" | "eq" | "ecm" | "roofline"}
    POST /explore   {"workload": "polybench/atx", "sizes": "smoke",
                     "space": {"sets": [...], "ways": [...]},
                     "agent": "hillclimb", "budget": 256, "seed": 0}
    GET  /stats     service + session + store counters
    GET  /healthz   liveness

``/explore`` runs on the service's bounded explore pool (its own
worker lane), so a multi-second config sweep can never starve
``/predict`` microbatches; the handler thread blocks on the job's
future and returns the full ``run_explore`` result dict.

Error mapping: bad payloads -> 400, queue-full load shed -> 503 (with
``Retry-After``), anything else -> 500.  Workloads are resolved by
registry name (``polybench/atx``, ``model/llama3_8b/decode``; legacy
Table-4 abbreviations stay routable as aliases) through a cache, so
equal (workload, sizes) specs share one source object — and therefore
one declared fingerprint, one Session artifact set, and one dedup key
(aliases coalesce with their canonical spelling).
"""
from __future__ import annotations

import json
import threading
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api import PredictionRequest
from repro.hw.targets import ALL_TARGETS, CPU_TARGETS
from repro.service.service import PredictionService, ServiceOverloadedError

DEFAULT_PORT = 8177


class WorkloadResolver:
    """Cached registry resolution: one source object per canonical
    (workload, sizes) spec.  ``store`` (the service Session's
    ArtifactStore) lets model workloads answer ``op_counts`` from
    persisted metadata instead of re-lowering on every process start.
    """

    def __init__(self, store=None):
        self._lock = threading.Lock()
        self._store = store
        self._cache: dict[tuple[str, str | None], object] = {}

    def get(self, name: str, sizes: str | None):
        from repro.workloads import registry

        try:
            canonical = registry.canonical_name(name)
        except KeyError as exc:
            raise ValueError(exc.args[0] if exc.args else str(exc)) from exc
        key = (canonical, sizes)
        with self._lock:
            if key not in self._cache:
                self._cache[key] = registry.resolve(
                    canonical, sizes, store=self._store
                )
            return self._cache[key]


def build_request(payload: dict, workload) -> PredictionRequest:
    """Translate one JSON payload into a PredictionRequest.

    Target names are resolved eagerly so an unknown one is a
    ``ValueError`` here (HTTP 400), not a worker-side failure (500)."""
    targets = tuple(payload.get("targets") or CPU_TARGETS)
    unknown = [t for t in targets if t not in ALL_TARGETS]
    if unknown:
        raise ValueError(
            f"unknown target(s) {unknown} (choose from "
            f"{sorted(ALL_TARGETS)})"
        )
    window = payload.get("window_size")
    sampled = payload.get("sampled_rate")
    return PredictionRequest(
        targets=targets,
        core_counts=tuple(payload.get("core_counts") or (1,)),
        strategies=tuple(payload.get("strategies") or ("round_robin",)),
        modes=tuple(payload.get("modes") or ("throughput",)),
        counts=workload.op_counts if payload.get("runtime", True) else None,
        # PredictionRequest validates the name against every requested
        # target, so a bad model/target pairing is a 400 here too
        runtime_model=payload.get("runtime_model"),
        seed=int(payload.get("seed", 0)),
        window_size=int(window) if window is not None else None,
        # sampled profiles per request: the rate joins the frozen
        # request, so the scheduler's dedup key separates rates
        sampled_rate=float(sampled) if sampled is not None else None,
    )


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-service/1"
    protocol_version = "HTTP/1.1"

    # --- plumbing ----------------------------------------------------------

    @property
    def service(self) -> PredictionService:
        return self.server.service  # type: ignore[attr-defined]

    def _reply(self, code: int, obj: dict, headers: dict | None = None):
        blob = json.dumps(obj, default=float).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(blob)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(blob)

    def log_message(self, fmt, *args):  # quiet unless asked
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    # --- endpoints ---------------------------------------------------------

    def do_GET(self):
        if self.path == "/healthz":
            self._reply(200, {"ok": True})
        elif self.path == "/stats":
            self._reply(200, self.service.snapshot())
        else:
            self._reply(404, {"error": f"no such endpoint: {self.path}"})

    def do_POST(self):
        if self.path == "/explore":
            self._do_explore()
            return
        if self.path != "/predict":
            self._reply(404, {"error": f"no such endpoint: {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
            requested = payload["workload"]
            sizes = payload.get("sizes")
            resolver = self.server.resolver  # type: ignore[attr-defined]
            workload = resolver.get(requested, sizes)
            name = getattr(workload, "workload_name", requested)
            request = build_request(payload, workload)
        except (KeyError, TypeError, ValueError) as exc:
            self._reply(400, {"error": str(exc)})
            return
        try:
            # dedup on the canonical name so an alias coalesces with
            # its canonical spelling
            resp = self.service.predict(
                workload, request, key=(name, sizes, request)
            )
        except ServiceOverloadedError as exc:
            self._reply(503, {"error": str(exc)}, {"Retry-After": "1"})
            return
        except ValueError as exc:
            self._reply(400, {"error": str(exc)})
            return
        except Exception as exc:  # noqa: BLE001 — surfaced to the client
            self._reply(500, {"error": f"{type(exc).__name__}: {exc}"})
            return
        self._reply(200, {
            "workload": name,
            "requested": requested,
            "sizes": sizes,
            "cache_model": resp.result.cache_model,
            "trace_id": resp.result.trace_id,
            "predictions": resp.result.to_records(),
            "timing": asdict(resp.timing),
        })

    def _do_explore(self):
        from repro.explore import SearchSpace

        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
            requested = payload["workload"]
            sizes = payload.get("sizes")
            resolver = self.server.resolver  # type: ignore[attr-defined]
            workload = resolver.get(requested, sizes)
            name = getattr(workload, "workload_name", requested)
            space = SearchSpace.from_json(payload.get("space") or {})
            kwargs = dict(
                agent=payload.get("agent", "hillclimb"),
                budget=int(payload.get("budget", 256)),
                seed=int(payload.get("seed", 0)),
                mode=payload.get("mode", "throughput"),
                objective=payload.get("objective"),
                inner=payload.get("inner", "vmap"),
                refresh=bool(payload.get("refresh", False)),
            )
        except (KeyError, TypeError, ValueError) as exc:
            self._reply(400, {"error": str(exc)})
            return
        try:
            # unlike /predict this blocks the handler thread for the
            # whole search — the explore lane bounds how many do so
            result = self.service.explore(
                workload, space, workload=name, **kwargs
            )
        except ServiceOverloadedError as exc:
            self._reply(503, {"error": str(exc)}, {"Retry-After": "5"})
            return
        except ValueError as exc:
            self._reply(400, {"error": str(exc)})
            return
        except Exception as exc:  # noqa: BLE001 — surfaced to the client
            self._reply(500, {"error": f"{type(exc).__name__}: {exc}"})
            return
        self._reply(200, result)


class PredictionServer(ThreadingHTTPServer):
    """HTTP server bound to one PredictionService."""

    daemon_threads = True

    def __init__(self, service: PredictionService, host: str = "127.0.0.1",
                 port: int = DEFAULT_PORT, *, verbose: bool = False):
        super().__init__((host, port), _Handler)
        self.service = service
        self.resolver = WorkloadResolver(
            store=getattr(service.session, "store", None)
        )
        self.verbose = verbose

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def serve_background(self) -> threading.Thread:
        """Serve on a daemon thread (tests / selftest); ``shutdown()``
        to stop."""
        t = threading.Thread(
            target=self.serve_forever, name="repro-service-http", daemon=True
        )
        t.start()
        return t
