"""Minimal stdlib client for the prediction service HTTP API.

    from repro.service.client import ServiceClient

    client = ServiceClient("http://127.0.0.1:8177")
    out = client.predict("atx", core_counts=[1, 4, 8])
    for cell in out["predictions"]:
        print(cell["target"], cell["cores"], cell["t_pred_s"])

The client is a thin JSON wrapper — anything that can POST JSON
(curl, requests, a load balancer health check) speaks the same
protocol; see docs/service.md for the payload schema.
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request


class ServiceError(RuntimeError):
    """HTTP-level failure; carries the status code and server message."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status


class ServiceClient:
    def __init__(self, base_url: str, *, timeout: float = 60.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    def _call(self, path: str, payload: dict | None = None) -> dict:
        data = None if payload is None else json.dumps(payload).encode()
        req = urllib.request.Request(
            self.base_url + path, data=data,
            headers={"Content-Type": "application/json"},
        )
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read())
        except urllib.error.HTTPError as exc:
            try:
                message = json.loads(exc.read()).get("error", str(exc))
            except Exception:  # noqa: BLE001 — non-JSON error body
                message = str(exc)
            raise ServiceError(exc.code, message) from exc

    # --- endpoints ---------------------------------------------------------

    def predict(self, workload: str, *, sizes: str | None = None,
                targets=None, core_counts=(1,), strategies=None,
                modes=None, runtime: bool = True, seed: int = 0,
                window_size: int | None = None) -> dict:
        payload: dict = {
            "workload": workload,
            "core_counts": list(core_counts),
            "runtime": runtime,
            "seed": seed,
        }
        if sizes is not None:
            payload["sizes"] = sizes
        if targets is not None:
            payload["targets"] = list(targets)
        if strategies is not None:
            payload["strategies"] = list(strategies)
        if modes is not None:
            payload["modes"] = list(modes)
        if window_size is not None:
            payload["window_size"] = window_size
        return self._call("/predict", payload)

    def explore(self, workload: str, *, sizes: str | None = None,
                space: dict | None = None, agent: str = "hillclimb",
                budget: int = 256, seed: int = 0,
                objective: str | None = None, mode: str | None = None,
                inner: str | None = None, refresh: bool = False) -> dict:
        """Run a config-space search on the server's explore lane.

        Blocks until the search completes (searches are budget-bounded;
        size ``timeout`` accordingly) and returns the full
        ``run_explore`` result dict."""
        payload: dict = {
            "workload": workload,
            "agent": agent,
            "budget": budget,
            "seed": seed,
        }
        if sizes is not None:
            payload["sizes"] = sizes
        if space is not None:
            payload["space"] = space
        if objective is not None:
            payload["objective"] = objective
        if mode is not None:
            payload["mode"] = mode
        if inner is not None:
            payload["inner"] = inner
        if refresh:
            payload["refresh"] = True
        return self._call("/explore", payload)

    def stats(self) -> dict:
        return self._call("/stats")

    def healthz(self) -> dict:
        return self._call("/healthz")

    def wait_ready(self, timeout: float = 10.0) -> None:
        """Poll /healthz until the server answers (startup races)."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                self.healthz()
                return
            except (ServiceError, OSError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
