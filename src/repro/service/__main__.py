"""CLI for the concurrent prediction service.

    python -m repro.service                          # serve on :8177
    python -m repro.service --artifact-dir .cache/artifacts
    python -m repro.service --selftest               # in-process smoke

``--selftest`` is the CI gate for the documented entrypoint: it starts
the HTTP server on an ephemeral port, hammers it with concurrent
in-process clients (duplicate payloads included, so coalescing and
dedup are exercised), verifies every response is bit-identical to a
sequential ``Session.predict`` of the same request, prints a
machine-readable summary (service/session/store counters), and exits
non-zero on any mismatch.  With ``--artifact-dir`` the summary's
``session.profile_builds`` shows whether profiles came off the disk
store — a second selftest against a warm store reports zero rebuilds.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

from repro.api import AnalyticalSDCM, Session
from repro.service.client import ServiceClient
from repro.service.server import DEFAULT_PORT, PredictionServer, build_request
from repro.service.service import PredictionService, ServiceConfig

SELFTEST_PAYLOADS = (
    {"workload": "polybench/atx", "sizes": "smoke",
     "core_counts": [1, 2, 4]},
    # legacy Table-4 alias spelling: must keep resolving
    {"workload": "mvt", "sizes": "smoke", "core_counts": [1, 8],
     "targets": ["i7-5960X"]},
    # duplicate of the first VIA its alias: dedup must coalesce the
    # alias with the canonical spelling
    {"workload": "atx", "sizes": "smoke", "core_counts": [1, 2, 4]},
    # HLO model-derived workload through the TPU VMEM target
    {"workload": "model/llama3_8b/decode", "sizes": "smoke",
     "targets": ["tpu-v5e"], "core_counts": [1]},
)


def selftest(args) -> int:
    config = ServiceConfig(
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        queue_size=args.queue_size, artifact_dir=args.artifact_dir,
    )
    service = PredictionService(config=config)
    clients = 6

    failures: list[str] = []

    def run_client(client: ServiceClient) -> None:
        for payload, want in zip(SELFTEST_PAYLOADS, expected):
            try:
                got = client.predict(**payload)
            except Exception as exc:  # noqa: BLE001 — collected
                failures.append(f"{payload['workload']}: {exc}")
                continue
            if got["predictions"] != want:
                failures.append(
                    f"{payload['workload']}: response diverged from "
                    "sequential Session.predict"
                )

    with service:
        server = PredictionServer(service, args.host, args.port or 0)

        # reference: a plain sequential Session with the same cache
        # model — coalescing must not change a single bit of the
        # results.  Sources come from the server's own resolver so the
        # reference and the HTTP path share one object per spec (model
        # workloads lower their HLO at most once per process).
        reference = Session(cache_model=AnalyticalSDCM(backend="batched"))
        expected = []
        for payload in SELFTEST_PAYLOADS:
            workload = server.resolver.get(
                payload["workload"], payload.get("sizes")
            )
            request = build_request(payload, workload)
            result = reference.predict(workload, request)
            # through the same JSON float round-trip the HTTP path uses
            expected.append(json.loads(result.to_json())["predictions"])

        server.serve_background()
        try:
            client = ServiceClient(server.url)
            client.wait_ready()
            threads = [
                threading.Thread(target=run_client, args=(client,))
                for _ in range(clients)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            stats = client.stats()
        finally:
            server.shutdown()
            server.server_close()

    summary = {
        "selftest": "fail" if failures else "ok",
        "requests": clients * len(SELFTEST_PAYLOADS),
        "failures": failures,
        **stats,
    }
    print(json.dumps(summary, indent=2, default=float))
    if failures:
        print(f"SELFTEST FAILED: {len(failures)} mismatches",
              file=sys.stderr)
        return 1
    return 0


def serve(args) -> int:
    config = ServiceConfig(
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        queue_size=args.queue_size, artifact_dir=args.artifact_dir,
    )
    service = PredictionService(config=config)
    with service:
        server = PredictionServer(
            service, args.host, args.port, verbose=args.verbose
        )
        print(f"prediction service listening on {server.url}")
        print("  try: curl -s -X POST "
              f"{server.url}/predict -d "
              "'{\"workload\": \"polybench/atx\", "
              "\"core_counts\": [1, 4, 8]}'")
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.service",
        description="concurrent microbatching prediction service",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=DEFAULT_PORT)
    ap.add_argument("--artifact-dir", default=None,
                    help="shared disk ArtifactStore; a warm store means "
                         "zero profile rebuilds in this process")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="coalesced batch budget (flush when reached)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="batch collection window past the first request")
    ap.add_argument("--queue-size", type=int, default=256,
                    help="bounded queue depth; beyond it requests are "
                         "shed with ServiceOverloadedError / HTTP 503")
    ap.add_argument("--selftest", action="store_true",
                    help="start on an ephemeral port, run concurrent "
                         "in-process clients, verify bit-identity vs "
                         "sequential Session.predict, exit")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest(args)
    return serve(args)


if __name__ == "__main__":
    raise SystemExit(main())
