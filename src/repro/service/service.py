"""PredictionService: concurrent prediction over one shared Session.

The paper's asymmetry — profiles are collected once, every what-if
query afterwards is cheap analytical math — makes prediction a natural
high-QPS service.  This module composes the two halves built in
earlier PRs:

* the batched vmapped SDCM grid kernel (``repro.api.batched``), reached
  through ``Session.predict_many`` so N coalesced requests cost ONE
  jitted kernel call instead of N per-request loops;
* the disk :class:`repro.validate.store.ArtifactStore`
  (``artifact_dir=...``), so a warm store means zero reuse-profile
  rebuilds across service processes.

Concurrency model: submitters enqueue onto a bounded queue; a single
worker thread owns the Session and turns queue depth into batch size
(:mod:`repro.service.scheduler`).  Backpressure is load-shedding — a
full queue raises :class:`ServiceOverloadedError` at ``submit`` time
instead of letting latency grow without bound.

Failure modes (see docs/service.md):

* queue full            -> ``ServiceOverloadedError`` (``stats.shed``)
* bad request           -> ``ValueError`` at submit (before queueing)
* one computation fails -> the batch group retries each computation
  individually, so only the poisoned request's waiters see the error
* service stopped       -> ``RuntimeError`` on submit
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import ClassVar

from repro.api import AnalyticalSDCM, PredictionRequest, Session
from repro.api.results import PredictionSet
from repro.service.scheduler import (
    BoundedWorkerPool,
    MicroBatcher,
    PendingRequest,
    coalesce,
    default_key,
    resolve_future,
)

SHED_MESSAGE = (
    "prediction service queue is full ({depth} pending, limit {limit}); "
    "request shed — retry with backoff or raise ServiceConfig.queue_size"
)


class ServiceOverloadedError(RuntimeError):
    """Raised by ``submit`` when the bounded queue is full (load shed)."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Tuning knobs (documented in docs/service.md)."""

    max_batch: int = 64         # batch budget: flush when this many gathered
    max_wait_ms: float = 5.0    # flush window past the first item
    queue_size: int = 256       # bounded queue; beyond this, shed
    artifact_dir: str | None = None  # shared disk store (optional)
    # the /explore lane: long-running sweeps run on their own bounded
    # pool so a search can never starve /predict microbatches
    explore_workers: int = 1    # concurrent explore jobs
    explore_pending: int = 2    # queued explore jobs beyond that; then shed
    explore_budget_cap: int = 4096  # max unique configs per explore request

    @property
    def max_wait_s(self) -> float:
        return self.max_wait_ms / 1000.0


@dataclasses.dataclass
class ServiceStats:
    """Observable service behaviour (asserted by tests/benchmarks).

    Batch sizes are kept as running aggregates plus a bounded recent
    window (a long-running service must not accumulate per-batch
    history without limit); dedup shows up as ``coalesced <
    batched_requests``.  Store/profile counters live on the underlying
    ``Session.stats`` — ``snapshot()`` merges both.
    """

    RECENT_WINDOW: ClassVar[int] = 64

    submitted: int = 0          # accepted into the queue
    completed: int = 0          # futures resolved with a result
    failed: int = 0             # futures resolved with an exception
    cancelled: int = 0          # futures the caller cancelled while queued
    shed: int = 0               # rejected with ServiceOverloadedError
    batches: int = 0            # coalesced batches processed
    batched_requests: int = 0   # sum of batch sizes
    coalesced: int = 0          # unique computations actually evaluated
    deduped: int = 0            # requests served by another's computation
    kernel_calls: int = 0       # predict_many invocations (+ retries)
    queue_wait_s: float = 0.0   # summed per-request queue wait
    service_s: float = 0.0      # summed per-request in-batch service time
    max_batch_size: int = 0
    recent_batch_sizes: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=ServiceStats.RECENT_WINDOW)
    )

    @property
    def mean_batch_size(self) -> float:
        return self.batched_requests / max(self.batches, 1)

    def record_batch(self, size: int) -> None:
        self.batches += 1
        self.batched_requests += size
        self.max_batch_size = max(self.max_batch_size, size)
        self.recent_batch_sizes.append(size)

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["recent_batch_sizes"] = list(self.recent_batch_sizes)
        out["mean_batch_size"] = self.mean_batch_size
        return out


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Per-request observability returned alongside every result."""

    queue_wait_s: float         # submit -> batch formation
    service_s: float            # batch formation -> result ready
    batch_size: int             # requests in the coalesced batch
    group_size: int             # unique computations evaluated together
    shared: bool                # served by a deduped computation (>1 waiter)


@dataclasses.dataclass
class ServiceResponse:
    """What a resolved future carries: the grid result + timing."""

    result: PredictionSet
    timing: RequestTiming


class PredictionService:
    """Microbatching front-end over one Session (see module docstring).

    >>> with PredictionService(artifact_dir=".cache/artifacts") as svc:
    ...     resp = svc.predict(workload, request)
    ...     print(resp.result.to_table(), resp.timing.batch_size)

    Thread-safe: any number of threads may ``submit``/``predict``
    concurrently; the Session is only ever touched by the worker.
    """

    def __init__(self, session: Session | None = None, *,
                 config: ServiceConfig | None = None,
                 artifact_dir: str | None = None):
        self.config = config or ServiceConfig()
        if artifact_dir is None:
            artifact_dir = self.config.artifact_dir
        if session is None:
            # batched backend: the whole coalesced batch is one jit call
            session = Session(
                cache_model=AnalyticalSDCM(backend="batched"),
                artifact_dir=artifact_dir,
            )
        self.session = session
        self._artifact_dir = artifact_dir
        self.stats = ServiceStats()
        self._stats_lock = threading.Lock()
        self._batcher = MicroBatcher(
            self._execute_batch,
            max_batch=self.config.max_batch,
            max_wait_s=self.config.max_wait_s,
            queue_size=self.config.queue_size,
            on_discard=self._discard,
        )
        # explore jobs get their own small lane: separate worker
        # thread(s), separate bounded queue — a multi-second sweep can
        # never occupy the predict worker, and each job runs on a
        # private Session (sharing the disk store), so the predict
        # Session stays single-threaded
        self._explore_pool = BoundedWorkerPool(
            max_workers=self.config.explore_workers,
            max_pending=self.config.explore_pending,
            name="repro-service-explore",
        )
        self._running = False

    # --- lifecycle ---------------------------------------------------------

    def start(self) -> "PredictionService":
        self._running = True
        self._batcher.start()
        self._explore_pool.start()
        return self

    def stop(self) -> None:
        """Drain the queue, resolve every pending future, stop the
        worker.  Submissions after stop raise ``RuntimeError``; a
        submission that raced past the check and enqueued behind the
        stop sentinel gets that same error on its future rather than
        hanging its waiter."""
        if not self._running:
            return
        self._running = False
        self._batcher.stop()
        self._explore_pool.stop()

    def _discard(self, leftovers: list[PendingRequest]) -> None:
        error = RuntimeError(
            "PredictionService stopped before this request was served"
        )
        failed = 0
        for item in leftovers:
            if resolve_future(item.future, error=error):
                failed += 1
        with self._stats_lock:
            self.stats.failed += failed

    def __enter__(self) -> "PredictionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --- submission --------------------------------------------------------

    def submit(self, source, request: PredictionRequest, *,
               key: object = None) -> Future:
        """Enqueue one prediction; returns a Future resolving to a
        :class:`ServiceResponse`.

        ``key`` is the dedup identity — requests sharing a key within a
        batch are computed once and fanned out to every waiter.  The
        default keys on source *object* identity plus request equality
        (:func:`repro.service.scheduler.default_key`).

        Raises ``ServiceOverloadedError`` when the bounded queue is
        full and ``ValueError`` for a request matching no grid cells
        (both before any queueing).
        """
        if not self._running:
            raise RuntimeError("PredictionService is not running "
                               "(use `with service:` or call start())")
        if not any(True for _ in request.cells()):
            raise ValueError(
                f"request matched no grid cells: {request.describe()}"
            )
        item = PendingRequest(
            source=source, request=request,
            key=key if key is not None else default_key(source, request),
            future=Future(), enqueued_at=time.monotonic(),
        )
        try:
            accepted = self._batcher.offer(item)
        except RuntimeError:
            # lost the race against a concurrent stop()
            raise RuntimeError("PredictionService is not running "
                               "(use `with service:` or call start())")
        if not accepted:
            with self._stats_lock:
                self.stats.shed += 1
            raise ServiceOverloadedError(SHED_MESSAGE.format(
                depth=self._batcher.depth, limit=self.config.queue_size
            ))
        with self._stats_lock:
            self.stats.submitted += 1
        return item.future

    def predict(self, source, request: PredictionRequest, *,
                key: object = None, timeout: float | None = None
                ) -> ServiceResponse:
        """Blocking convenience: ``submit(...).result(timeout)``."""
        return self.submit(source, request, key=key).result(timeout)

    # --- explore lane ------------------------------------------------------

    def submit_explore(self, source, space, *, agent: str = "hillclimb",
                       budget: int = 256, seed: int = 0,
                       mode: str = "throughput",
                       objective: str | None = None,
                       inner: str = "vmap",
                       workload: str | None = None,
                       refresh: bool = False) -> Future:
        """Enqueue a config-space search (``repro.explore``) on the
        bounded explore pool; the Future resolves to the
        ``run_explore`` result dict.

        Validation (unknown agent, empty space, over-cap budget) raises
        ``ValueError`` here — before queueing — and a full explore lane
        raises ``ServiceOverloadedError``, exactly like ``submit``.
        Each job builds a private Session over the service's artifact
        dir: profiles and trajectories persist in the shared store, but
        the predict Session is never touched off its worker thread.
        """
        from repro.explore import make_agent, run_explore

        if not self._running:
            raise RuntimeError("PredictionService is not running "
                               "(use `with service:` or call start())")
        cap = self.config.explore_budget_cap
        if budget < 1 or budget > cap:
            raise ValueError(
                f"explore budget {budget} outside [1, {cap}] "
                "(ServiceConfig.explore_budget_cap)"
            )
        make_agent(agent)  # unknown agent -> ValueError before queueing
        artifact_dir = self._artifact_dir

        def job() -> dict:
            session = Session(
                cache_model=AnalyticalSDCM(backend="batched"),
                artifact_dir=artifact_dir,
            )
            return run_explore(
                source, space, agent=agent, budget=budget, seed=seed,
                session=session, mode=mode, objective=objective,
                inner=inner, workload=workload, refresh=refresh,
            )

        try:
            future = self._explore_pool.try_submit(job)
        except RuntimeError:
            raise RuntimeError("PredictionService is not running "
                               "(use `with service:` or call start())")
        if future is None:
            raise ServiceOverloadedError(
                f"explore lane is full ({self._explore_pool.depth} "
                f"pending, limit {self.config.explore_pending}); request "
                "shed — retry with backoff or raise "
                "ServiceConfig.explore_pending"
            )
        return future

    def explore(self, source, space, *, timeout: float | None = None,
                **kwargs) -> dict:
        """Blocking convenience: ``submit_explore(...).result()``."""
        return self.submit_explore(source, space, **kwargs).result(timeout)

    def snapshot(self) -> dict:
        """Service + Session counters in one json-serializable dict."""
        with self._stats_lock:
            out = {"service": self.stats.to_dict()}
        out["session"] = dataclasses.asdict(self.session.stats)
        out["explore"] = self._explore_pool.stats_dict()
        store = self.session.store
        if store is not None:
            out["store"] = dataclasses.asdict(store.stats)
        return out

    # --- worker side -------------------------------------------------------

    def _execute_batch(self, batch: list[PendingRequest]) -> None:
        """Runs on the worker thread with one collected batch.

        The whole coalesced batch is ONE ``predict_many`` call —
        kernel-compatibility grouping happens inside the batched
        kernel (per-row shape buckets), so splitting here would only
        fragment the batch into extra round-trips."""
        formed_at = time.monotonic()
        comps = coalesce(batch)
        with self._stats_lock:
            self.stats.record_batch(len(batch))
            self.stats.coalesced += len(comps)
            self.stats.deduped += len(batch) - len(comps)
        self._execute_group(comps, len(batch), formed_at)

    def _execute_group(self, group, batch_size: int,
                       formed_at: float) -> None:
        results: list[PredictionSet | Exception]
        try:
            with self._stats_lock:
                self.stats.kernel_calls += 1
            results = list(self.session.predict_many(
                [(c.source, c.request) for c in group]
            ))
        except Exception:
            # one poisoned computation must not fail the whole group:
            # retry each individually so only its waiters see the error
            results = []
            for comp in group:
                try:
                    with self._stats_lock:
                        self.stats.kernel_calls += 1
                    results.append(
                        self.session.predict(comp.source, comp.request)
                    )
                except Exception as exc:  # noqa: BLE001 — forwarded
                    results.append(exc)
        done_at = time.monotonic()
        completed = failed = cancelled = 0
        queue_wait = service = 0.0
        for comp, res in zip(group, results):
            for waiter in comp.waiters:
                timing = RequestTiming(
                    queue_wait_s=formed_at - waiter.enqueued_at,
                    service_s=done_at - formed_at,
                    batch_size=batch_size,
                    group_size=len(group),
                    shared=len(comp.waiters) > 1,
                )
                queue_wait += timing.queue_wait_s
                service += timing.service_s
                if isinstance(res, Exception):
                    if resolve_future(waiter.future, error=res):
                        failed += 1
                    else:
                        cancelled += 1
                elif resolve_future(waiter.future,
                                    ServiceResponse(res, timing)):
                    completed += 1
                else:  # caller cancelled while queued — never fatal
                    cancelled += 1
        with self._stats_lock:
            self.stats.completed += completed
            self.stats.failed += failed
            self.stats.cancelled += cancelled
            self.stats.queue_wait_s += queue_wait
            self.stats.service_s += service
