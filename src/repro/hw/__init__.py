from repro.hw.targets import (
    BROADWELL_E5_2699V4,
    CPU_TARGETS,
    HASWELL_I7_5960X,
    TPU_V5E,
    CPUTarget,
    TPUTarget,
    ZEN2_EPYC_7702P,
)

__all__ = [
    "BROADWELL_E5_2699V4",
    "CPU_TARGETS",
    "HASWELL_I7_5960X",
    "TPU_V5E",
    "CPUTarget",
    "TPUTarget",
    "ZEN2_EPYC_7702P",
]
