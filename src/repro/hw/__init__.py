from repro.hw.targets import (
    ALL_TARGETS,
    BROADWELL_E5_2699V4,
    CPU_TARGETS,
    HASWELL_I7_5960X,
    TPU_V5E,
    CPUTarget,
    TPUTarget,
    ZEN2_EPYC_7702P,
    resolve_target,
)

__all__ = [
    "ALL_TARGETS",
    "BROADWELL_E5_2699V4",
    "CPU_TARGETS",
    "HASWELL_I7_5960X",
    "TPU_V5E",
    "CPUTarget",
    "TPUTarget",
    "ZEN2_EPYC_7702P",
    "resolve_target",
]
