"""Hardware targets.

* The paper's three CPUs (Table 5) with latency/throughput parameters
  taken from vendor documentation, Agner Fog's instruction tables and
  7-cpu.com — same sources the paper cites (§4.2).  Values are modeling
  parameters, not measurements from this container.
* TPU v5e-class chip (the adaptation target): peak bf16 FLOP/s, HBM
  bandwidth, ICI link bandwidth per the project brief, VMEM treated as a
  software-managed last-level "cache" for the reuse-profile model.
* A GPU-like SM target (``gpu-sm``): wide-throughput / high-latency
  per-class port tables with an HBM memory chain, addressed through
  the same CPUTarget interface so the whole pipeline (SDCM, exact LRU,
  every runtime model, ``repro.validate --targets gpu-sm``) treats it
  as just another hierarchy.

CPU targets additionally carry OSACA-style per-class ``incore`` port
tables (``repro.core.incore.InCoreTimings``) feeding the ECM runtime
model; ``docs/runtime.md`` documents every table and
``tools/docs_check.py`` asserts docs and code agree both directions.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.incore import ClassTiming, InCoreTimings
from repro.core.levels import CacheLevelConfig


@dataclass(frozen=True)
class InstrTimings:
    """Per-class instruction latency δ (cycles) and reciprocal throughput β
    (cycles/instr) — paper §3.4.2 (T_CPU), sources: Agner Fog tables."""

    delta_int: float
    beta_int: float
    delta_fp: float
    beta_fp: float
    delta_div: float
    beta_div: float


@dataclass(frozen=True)
class CPUTarget:
    name: str
    microarch: str
    cores: int
    freq_hz: float
    levels: tuple[CacheLevelConfig, ...]
    # per-access latency δ (cycles) and reciprocal throughput β (cycles)
    # per level, ending with RAM — Eq. 6/7 inputs.
    level_latency_cy: tuple[float, ...]
    level_beta_cy: tuple[float, ...]
    ram_latency_cy: float
    ram_beta_cy: float
    instr: InstrTimings
    shared_level: int = -1  # index of the level shared across cores (LLC)
    word_bytes: int = 8
    # OSACA-style per-class port table for the ECM in-core model
    # (repro.core.incore); None falls back to a 1-port table derived
    # from ``instr``.  Aggregate βs stay consistent by construction:
    # instr.beta_X == incore.X.beta / incore.X.ports.
    incore: InCoreTimings | None = None

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.freq_hz


# --- Table 5 CPUs -----------------------------------------------------------

HASWELL_I7_5960X = CPUTarget(
    name="i7-5960X",
    microarch="haswell",
    cores=8,
    freq_hz=3.0e9,
    levels=(
        CacheLevelConfig("L1", 32 * 1024, 64, 8),
        CacheLevelConfig("L2", 256 * 1024, 64, 8),
        CacheLevelConfig("L3", 20 * 1024 * 1024, 64, 20),
    ),
    level_latency_cy=(4.0, 12.0, 36.0),
    level_beta_cy=(0.5, 3.0, 8.0),
    ram_latency_cy=240.0,
    ram_beta_cy=14.0,
    instr=InstrTimings(1.0, 0.25, 3.0, 0.5, 20.0, 8.0),
    # Haswell port model: 4 ALU ports (p0156), 2 FMA pipes (p01), one
    # radix div unit (p0), 2 load AGUs (p23), 1 store-data port (p4)
    incore=InCoreTimings(
        int_ops=ClassTiming(1.0, 1.0, 4),
        fp_ops=ClassTiming(3.0, 1.0, 2),
        div_ops=ClassTiming(20.0, 8.0, 1),
        loads=ClassTiming(4.0, 1.0, 2),
        stores=ClassTiming(4.0, 1.0, 1),
    ),
)

BROADWELL_E5_2699V4 = CPUTarget(
    name="Xeon E5-2699 v4",
    microarch="broadwell",
    cores=22,
    freq_hz=2.2e9,
    levels=(
        CacheLevelConfig("L1", 32 * 1024, 64, 8),
        CacheLevelConfig("L2", 256 * 1024, 64, 8),
        CacheLevelConfig("L3", 55 * 1024 * 1024, 64, 20),
    ),
    level_latency_cy=(4.0, 12.0, 50.0),
    level_beta_cy=(0.5, 3.0, 10.0),
    ram_latency_cy=200.0,
    ram_beta_cy=12.0,
    instr=InstrTimings(1.0, 0.25, 3.0, 0.5, 23.0, 10.0),
    # Broadwell keeps Haswell's port layout; the div unit is slower
    incore=InCoreTimings(
        int_ops=ClassTiming(1.0, 1.0, 4),
        fp_ops=ClassTiming(3.0, 1.0, 2),
        div_ops=ClassTiming(23.0, 10.0, 1),
        loads=ClassTiming(4.0, 1.0, 2),
        stores=ClassTiming(4.0, 1.0, 1),
    ),
)

ZEN2_EPYC_7702P = CPUTarget(
    name="EPYC 7702P",
    microarch="zen2",
    cores=64,
    freq_hz=2.0e9,
    levels=(
        # Table 5 lists chip-aggregate sizes (2MB/32MB/256MB over 64
        # cores); the per-core/CCX view used for simulation:
        CacheLevelConfig("L1", 32 * 1024, 64, 8),
        CacheLevelConfig("L2", 512 * 1024, 64, 8),
        CacheLevelConfig("L3", 16 * 1024 * 1024, 64, 16),
    ),
    level_latency_cy=(4.0, 12.0, 39.0),
    level_beta_cy=(0.5, 3.0, 9.0),
    ram_latency_cy=230.0,
    ram_beta_cy=13.0,
    instr=InstrTimings(1.0, 0.25, 3.0, 0.5, 13.0, 5.0),
    # Zen2: 4 ALUs, 2 FMA pipes (FP0/FP1), fast radix-4 divider,
    # 2 load + 1 store AGU ops per cycle
    incore=InCoreTimings(
        int_ops=ClassTiming(1.0, 1.0, 4),
        fp_ops=ClassTiming(3.0, 1.0, 2),
        div_ops=ClassTiming(13.0, 5.0, 1),
        loads=ClassTiming(4.0, 1.0, 2),
        stores=ClassTiming(4.0, 1.0, 1),
    ),
)

CPU_TARGETS = {
    t.name: t
    for t in (HASWELL_I7_5960X, BROADWELL_E5_2699V4, ZEN2_EPYC_7702P)
}


# --- GPU-like SM target (ECM adaptation; PPT-GPU-style abstraction) ---------
#
# One streaming multiprocessor modeled through the SAME CPUTarget
# interface: "cores" are SMs, the per-SM L1/shared-memory level is
# private, the chip L2 is the shared level, and the RAM terms model the
# HBM chain.  The in-core table is the GPU signature the ISSUE asks
# for: very WIDE throughput (32-lane port groups, β_eff « 1 cy/op) at
# HIGH dependent-issue latency (δ_int/fp ≈ 4–8 cy, SFU ≈ 16 cy) — the
# opposite corner of the (δ, β) plane from the CPUs, which is exactly
# what makes it a useful stress target for the ECM vs Eq. 4–7 split.

GPU_SM90_LIKE = CPUTarget(
    name="gpu-sm",
    microarch="sm90-like",
    cores=108,                       # SMs ("cores" in a grid request)
    freq_hz=1.4e9,
    levels=(
        # per-SM L1/shared-memory carveout; chip-wide L2
        CacheLevelConfig("L1", 128 * 1024, 128, 64),
        CacheLevelConfig("L2", 40 * 1024 * 1024, 128, 16),
    ),
    level_latency_cy=(28.0, 200.0),
    level_beta_cy=(0.25, 2.0),
    ram_latency_cy=480.0,            # HBM round trip
    ram_beta_cy=4.0,                 # HBM chain: wide but contended
    instr=InstrTimings(4.0, 0.03125, 4.0, 0.03125, 16.0, 0.0625),
    shared_level=1,
    word_bytes=4,
    incore=InCoreTimings(
        int_ops=ClassTiming(4.0, 1.0, 32),
        fp_ops=ClassTiming(4.0, 1.0, 32),
        div_ops=ClassTiming(16.0, 1.0, 16),   # SFU quad-pumped lanes
        loads=ClassTiming(28.0, 1.0, 4),      # LSU: 4 accesses/cy/SM
        stores=ClassTiming(28.0, 1.0, 4),
    ),
)


# --- TPU target (adaptation; constants from the project brief) --------------

@dataclass(frozen=True)
class TPUTarget:
    """TPU chip modeled through the SAME cache-hierarchy interface as
    the CPUs: ``levels``/``shared_level``/``cores`` make it a drop-in
    target for the ``repro.api`` pipeline (VMEM = one fully-associative
    shared level), so there is no separate TPU prediction code path.
    """

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bandwidth: float = 819e9         # bytes/s per chip
    ici_bandwidth: float = 50e9          # bytes/s per link
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2      # software-managed on-chip memory
    vmem_line: int = 512                 # modeling granule for reuse analysis
    chips_per_pod: int = 256
    # latency terms for the Eq.6-style chain (seconds)
    vmem_latency_s: float = 10e-9
    hbm_latency_s: float = 500e-9
    ici_latency_s: float = 1e-6
    host_bandwidth: float = 25e9
    shared_level: int = 0                # VMEM is shared by all compute units

    def vmem_cache_config(self) -> CacheLevelConfig:
        # VMEM modeled as a fully-associative "cache" over 512B granules:
        # with A == B the SDCM rule degenerates to the exact LRU stack
        # rule, matching a perfectly-managed scratchpad (DESIGN.md §2).
        n = self.vmem_bytes // self.vmem_line
        return CacheLevelConfig("VMEM", self.vmem_bytes, self.vmem_line, n)

    @property
    def levels(self) -> tuple[CacheLevelConfig, ...]:
        return (self.vmem_cache_config(),)

    @property
    def cores(self) -> int:
        # "core count" in a grid request maps to chips for this target
        return self.chips_per_pod


TPU_V5E = TPUTarget()

# Unified registry: every target the prediction API can address by name.
ALL_TARGETS: dict[str, CPUTarget | TPUTarget] = {
    **CPU_TARGETS,
    GPU_SM90_LIKE.name: GPU_SM90_LIKE,
    TPU_V5E.name: TPU_V5E,
}


def resolve_target(target):
    """Accept a target object or its registry name."""
    if isinstance(target, str):
        try:
            return ALL_TARGETS[target]
        except KeyError:
            raise KeyError(
                f"unknown target {target!r}; known: {sorted(ALL_TARGETS)}"
            ) from None
    return target
