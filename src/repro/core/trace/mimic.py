"""Algorithm 1 — private memory trace generation (paper §3.2).

From ONE sequential basic-block-labeled trace, synthesize the private
trace of each core as if the parallel section ran on ``num_cores``
cores with OpenMP static scheduling:

* blocks executed fewer times than there are cores (entry/exit blocks,
  per-thread prologues) are **copied** to every core;
* blocks with >= num_cores instances (loop bodies) are **split evenly**
  (optionally with a chunk size, like ``schedule(static, chunk)``);
* every non-shared reference gets a per-core address offset so mimicked
  references are distinct across cores; references to shared variables
  (the ``shared_var_trace`` label) keep their address on every core.

Disambiguation vs. the paper's pseudocode: when ``bb_count == num_cores``
the pseudocode's ``bb_count_per_core == 1`` test would hit the *copy*
branch even though the split branch computed the value; we key the copy
branch on ``bb_count < num_cores`` (the line-6 condition), which is the
stated intent ("Each core gets a copy of BB" only for under-replicated
blocks).
"""
from __future__ import annotations

import numpy as np

from .types import LabeledTrace


def choose_offset(addresses: np.ndarray, alignment: int = 4096) -> int:
    """Per-core address offset: larger than the trace's footprint and
    aligned, so mimicked references never collide with the originals
    (§3.2: "We choose the offset so that the mimicked memory references
    do not match the original")."""
    if len(addresses) == 0:
        return alignment
    span = int(addresses.max()) + 1
    return -(-span // alignment) * alignment  # ceil to alignment


def core_assignment(
    trace: LabeledTrace, num_cores: int, chunk_size: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """(replicate_mask, core_of_ref) for every reference.

    ``replicate_mask[i]`` — reference i is copied to every core.
    ``core_of_ref[i]``    — owning core otherwise.
    """
    counts_by_bb = trace.bb_counts
    max_bb = int(trace.bb_ids.max()) + 1 if len(trace) else 0
    counts = np.zeros(max_bb, dtype=np.int64)
    for bb, c in counts_by_bb.items():
        counts[bb] = c
    ref_counts = counts[trace.bb_ids] if len(trace) else np.zeros(0, np.int64)
    replicate = ref_counts < num_cores

    inst = trace.instance_index()
    if chunk_size is not None and chunk_size > 0:
        core = (inst // chunk_size) % num_cores
    else:
        per_core = np.maximum(ref_counts // num_cores, 1)
        core = np.minimum(inst // per_core, num_cores - 1)
    return replicate, core.astype(np.int64)


def gen_private_traces(
    trace: LabeledTrace,
    num_cores: int,
    *,
    chunk_size: int | None = None,
    offset: int | None = None,
) -> list[LabeledTrace]:
    """Algorithm 1: the mimicked private trace of each core."""
    if num_cores < 1:
        raise ValueError("num_cores must be >= 1")
    if num_cores == 1:
        return [trace]
    if offset is None:
        offset = choose_offset(trace.addresses)
    replicate, core_of_ref = core_assignment(trace, num_cores, chunk_size)

    out: list[LabeledTrace] = []
    for core in range(num_cores):
        sel = replicate | (core_of_ref == core)
        addrs = trace.addresses[sel].copy()
        shared = trace.shared_mask[sel]
        # offset non-shared references for cores other than the master
        if core > 0:
            addrs = np.where(shared, addrs, addrs + offset * core)
        out.append(
            LabeledTrace(
                addrs,
                trace.bb_ids[sel],
                shared,
                trace.inst_ids[sel],
                dict(trace.bb_names),
            )
        )
    return out
