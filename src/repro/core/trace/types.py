"""Labeled memory traces — the framework's analog of the paper's
basic-block-labeled Byfl trace (§3.2, Fig. 4).

A :class:`LabeledTrace` is a flat sequence of memory references, each
annotated with

* ``bb_ids``      — id of the basic block (straight-line region) the
                    reference was issued from; on the LM side this is the
                    HLO instruction index (DESIGN.md §2);
* ``inst_ids``    — id of the *dynamic instance* of that block (the
                    paper's BB_START/BB_END markers delimit instances;
                    consecutive instances of the same block are distinct);
* ``shared_mask`` — True for references to *shared variables* (the
                    paper's ``shared_var_trace`` label; on the LM side,
                    replicated buffers).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Protocol, runtime_checkable

import numpy as np


def _runs(ids: np.ndarray) -> np.ndarray:
    """Default instance ids: maximal runs of equal bb ids."""
    n = len(ids)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.ones(n, dtype=bool)
    starts[1:] = ids[1:] != ids[:-1]
    return (np.cumsum(starts) - 1).astype(np.int64)


@dataclass
class LabeledTrace:
    addresses: np.ndarray          # int64 [N]
    bb_ids: np.ndarray             # int32 [N]
    shared_mask: np.ndarray        # bool  [N]
    inst_ids: np.ndarray | None = None  # int64 [N], unique per dynamic instance
    bb_names: dict[int, str] = field(default_factory=dict)

    def __post_init__(self):
        self.addresses = np.asarray(self.addresses, dtype=np.int64)
        self.bb_ids = np.asarray(self.bb_ids, dtype=np.int32)
        self.shared_mask = np.asarray(self.shared_mask, dtype=bool)
        n = len(self.addresses)
        if self.inst_ids is None:
            self.inst_ids = _runs(self.bb_ids)
        else:
            self.inst_ids = np.asarray(self.inst_ids, dtype=np.int64)
        if not (len(self.bb_ids) == len(self.shared_mask) == len(self.inst_ids) == n):
            raise ValueError("trace fields must have equal length")

    def __len__(self) -> int:
        return len(self.addresses)

    def _instance_firsts(self) -> np.ndarray:
        """Indices of the first reference of every instance, in order."""
        n = len(self)
        if n == 0:
            return np.zeros(0, dtype=np.int64)
        starts = np.ones(n, dtype=bool)
        starts[1:] = self.inst_ids[1:] != self.inst_ids[:-1]
        return np.flatnonzero(starts)

    @property
    def bb_counts(self) -> dict[int, int]:
        """Number of dynamic instances of each basic block (Alg. 1 input)."""
        firsts = self._instance_firsts()
        if len(firsts) == 0:
            return {}
        uniq, counts = np.unique(self.bb_ids[firsts], return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, counts)}

    def instance_index(self) -> np.ndarray:
        """Per-reference rank of its instance among same-block instances
        (0-based) — drives Algorithm 1's even split."""
        n = len(self)
        if n == 0:
            return np.zeros(0, dtype=np.int64)
        firsts = self._instance_firsts()
        first_bbs = self.bb_ids[firsts]
        order = np.argsort(first_bbs, kind="stable")
        sorted_bbs = first_bbs[order]
        grp_start = np.ones(len(firsts), dtype=bool)
        grp_start[1:] = sorted_bbs[1:] != sorted_bbs[:-1]
        grp_idx = np.cumsum(grp_start) - 1
        first_pos_of_grp = np.flatnonzero(grp_start)
        ranks = np.empty(len(firsts), dtype=np.int64)
        ranks[order] = np.arange(len(firsts)) - first_pos_of_grp[grp_idx]
        # broadcast instance rank to every reference of the instance
        starts = np.ones(n, dtype=bool)
        starts[1:] = self.inst_ids[1:] != self.inst_ids[:-1]
        inst_of_ref = np.cumsum(starts) - 1
        return ranks[inst_of_ref]

    def slice(self, start: int, stop: int) -> "LabeledTrace":
        """Contiguous sub-trace [start, stop) — views, no copies."""
        return LabeledTrace(
            self.addresses[start:stop],
            self.bb_ids[start:stop],
            self.shared_mask[start:stop],
            self.inst_ids[start:stop],
            self.bb_names,
        )

    def windows(self, window_size: int) -> Iterator["LabeledTrace"]:
        """Fixed-size windows (last one may be short) — makes every
        in-memory trace a :class:`ChunkedTraceSource`."""
        if window_size < 1:
            raise ValueError("window_size must be >= 1")
        for i in range(0, len(self), window_size):
            yield self.slice(i, i + window_size)

    def concat(self, other: "LabeledTrace") -> "LabeledTrace":
        shift = (self.inst_ids.max() + 1) if len(self) else 0
        return LabeledTrace(
            np.concatenate([self.addresses, other.addresses]),
            np.concatenate([self.bb_ids, other.bb_ids]),
            np.concatenate([self.shared_mask, other.shared_mask]),
            np.concatenate([self.inst_ids, other.inst_ids + shift]),
            {**self.bb_names, **other.bb_names},
        )


def rebatch_windows(
    pieces: Iterator[LabeledTrace] | list, window_size: int
) -> Iterator[LabeledTrace]:
    """Re-chunk arbitrarily-sized LabeledTrace pieces into fixed
    ``window_size`` windows (last one may be short).

    The single pend-buffer loop shared by every streaming producer
    (the interleaver's merged batches, synthetic benchmark sources) —
    emitted windows carry window-local instance ids.
    """
    if window_size < 1:
        raise ValueError("window_size must be >= 1")
    pend_a = np.empty(0, dtype=np.int64)
    pend_b = np.empty(0, dtype=np.int32)
    pend_s = np.empty(0, dtype=bool)
    names: dict[int, str] = {}
    it = iter(pieces)
    done = False
    while not done:
        try:
            t = next(it)
            names.update(t.bb_names)
            pend_a = np.concatenate([pend_a, t.addresses])
            pend_b = np.concatenate([pend_b, t.bb_ids])
            pend_s = np.concatenate([pend_s, t.shared_mask])
        except StopIteration:
            done = True
        while len(pend_a) >= window_size or (done and len(pend_a)):
            n = min(window_size, len(pend_a))
            yield LabeledTrace(pend_a[:n], pend_b[:n], pend_s[:n], None, names)
            pend_a, pend_b, pend_s = pend_a[n:], pend_b[n:], pend_s[n:]


@runtime_checkable
class ChunkedTraceSource(Protocol):
    """A trace that can be consumed as fixed-size windows.

    The streaming pipeline (``reuse_distance_windows``,
    ``interleave_windows``, ``Session(window_size=...)``) never asks for
    the whole trace — only for windows — so a source backed by a file,
    a generator, or an instrumentation pipe can feed traces far larger
    than RAM.  ``LabeledTrace`` satisfies the protocol structurally.
    """

    def __len__(self) -> int: ...

    def windows(self, window_size: int) -> Iterator[LabeledTrace]: ...


def trace_from_blocks(blocks: list[tuple[str, np.ndarray, np.ndarray]]) -> LabeledTrace:
    """Build a trace from (bb_name, addresses, shared_mask) instances.

    Every tuple is ONE dynamic instance (a BB_START..BB_END region);
    repeated bb_names share a bb id but get distinct instance ids.
    """
    name_to_id: dict[str, int] = {}
    addr_parts, id_parts, shared_parts, inst_parts = [], [], [], []
    for inst, (name, addrs, shared) in enumerate(blocks):
        bb = name_to_id.setdefault(name, len(name_to_id))
        addrs = np.asarray(addrs, dtype=np.int64)
        shared = np.broadcast_to(np.asarray(shared, dtype=bool), addrs.shape)
        addr_parts.append(addrs)
        id_parts.append(np.full(len(addrs), bb, dtype=np.int32))
        shared_parts.append(shared.copy())
        inst_parts.append(np.full(len(addrs), inst, dtype=np.int64))
    if not addr_parts:
        return LabeledTrace(
            np.empty(0, np.int64), np.empty(0, np.int32), np.empty(0, bool)
        )
    return LabeledTrace(
        np.concatenate(addr_parts),
        np.concatenate(id_parts),
        np.concatenate(shared_parts),
        np.concatenate(inst_parts),
        {v: k for k, v in name_to_id.items()},
    )
