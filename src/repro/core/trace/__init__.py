from repro.core.trace.interleave import interleave_traces
from repro.core.trace.mimic import gen_private_traces
from repro.core.trace.types import LabeledTrace, trace_from_blocks

__all__ = [
    "interleave_traces",
    "gen_private_traces",
    "LabeledTrace",
    "trace_from_blocks",
]
