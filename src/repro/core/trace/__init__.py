from repro.core.trace.interleave import interleave_traces, interleave_windows
from repro.core.trace.mimic import gen_private_traces
from repro.core.trace.types import (
    ChunkedTraceSource,
    LabeledTrace,
    rebatch_windows,
    trace_from_blocks,
)

__all__ = [
    "interleave_traces",
    "interleave_windows",
    "gen_private_traces",
    "ChunkedTraceSource",
    "LabeledTrace",
    "rebatch_windows",
    "trace_from_blocks",
]
