"""Algorithm 2 — interleaving private traces into the shared trace
(paper §3.2.1).

Strategies:

* ``round_robin`` — one reference per core in turn, exhausted cores are
  skipped (the paper's primary strategy; deterministic, and the natural
  analog of XLA's static schedule on the TPU side);
* ``uniform``     — at every step a uniformly-random *non-exhausted*
  core is chosen (exact, implemented phase-vectorized);
* ``chunked``     — round-robin over chunks of ``chunk_size`` references
  (models coarser timeslices).

All strategies preserve per-core program order (a trace is a FIFO), and
the interleaved trace is a permutation of the concatenation of inputs —
both properties are enforced by tests.
"""
from __future__ import annotations

import numpy as np

from .types import LabeledTrace, rebatch_windows


def _merge_by_key(traces: list[LabeledTrace], keys: list[np.ndarray]) -> LabeledTrace:
    addr = np.concatenate([t.addresses for t in traces])
    bb = np.concatenate([t.bb_ids for t in traces])
    shared = np.concatenate([t.shared_mask for t in traces])
    shift, inst_parts = 0, []
    for t in traces:
        inst_parts.append(t.inst_ids + shift)
        shift += int(t.inst_ids.max()) + 1 if len(t) else 0
    inst = np.concatenate(inst_parts)
    core = np.concatenate(
        [np.full(len(t), c, dtype=np.int32) for c, t in enumerate(traces)]
    )
    key = np.concatenate(keys)
    order = np.lexsort((core, key))
    names: dict[int, str] = {}
    for t in traces:
        names.update(t.bb_names)
    return LabeledTrace(addr[order], bb[order], shared[order], inst[order], names)


def _round_robin_keys(traces: list[LabeledTrace], chunk: int = 1) -> list[np.ndarray]:
    # sort by (position // chunk, core): chunk=1 is exact Algorithm 2
    # round-robin (exhausted cores naturally drop out of later rounds).
    return [np.arange(len(t), dtype=np.int64) // chunk for t in traces]


def _uniform_choice_sequence(
    lengths: list[int], rng: np.random.Generator
) -> np.ndarray:
    """Exact Algorithm-2 uniform interleaving, phase-vectorized.

    Each step picks uniformly among cores that still have references.
    We sample in bulk and cut each phase at the first exhaustion, which
    is distribution-identical to the per-step loop.
    """
    remaining = np.array(lengths, dtype=np.int64)
    alive = np.flatnonzero(remaining > 0)
    chosen = np.empty(int(remaining.sum()), dtype=np.int64)
    pos = 0
    while alive.size:
        budget = int(remaining[alive].sum())
        draw = alive[rng.integers(0, alive.size, size=budget)]
        # cut the phase at the first index where some core's cumulative
        # count hits its remaining quota (that core exhausts there)
        cut = budget
        for c in alive:
            idx = np.flatnonzero(draw == c)
            if idx.size >= remaining[c]:
                cut = min(cut, int(idx[remaining[c] - 1]) + 1)
        take = draw[:cut]
        chosen[pos : pos + cut] = take
        pos += cut
        uniq, cnt = np.unique(take, return_counts=True)
        remaining[uniq] -= cnt
        alive = np.flatnonzero(remaining > 0)
    return chosen[:pos]


def _uniform_keys(
    traces: list[LabeledTrace], rng: np.random.Generator
) -> list[np.ndarray]:
    choice = _uniform_choice_sequence([len(t) for t in traces], rng)
    step = np.arange(len(choice), dtype=np.int64)
    keys = []
    for c in range(len(traces)):
        keys.append(step[choice == c])
    return keys


def interleave_traces(
    traces: list[LabeledTrace],
    strategy: str = "round_robin",
    *,
    chunk_size: int = 1,
    seed: int = 0,
) -> LabeledTrace:
    """Algorithm 2: merge private traces into the shared-cache trace."""
    if not traces:
        raise ValueError("need at least one trace")
    if strategy == "round_robin":
        keys = _round_robin_keys(traces, 1)
    elif strategy == "chunked":
        keys = _round_robin_keys(traces, max(chunk_size, 1))
    elif strategy == "uniform":
        keys = _uniform_keys(traces, np.random.default_rng(seed))
    else:
        raise ValueError(f"unknown interleaving strategy: {strategy}")
    return _merge_by_key(traces, keys)


# ---------------------------------------------------------------------------
# Streaming interleaver — Algorithm 2 over windows (ISSUE-2 tentpole).
# ---------------------------------------------------------------------------


class _CoreBuffer:
    """Bounded per-core read buffer over a ChunkedTraceSource."""

    def __init__(self, source, window_size: int):
        self._iter = iter(source.windows(window_size))
        self.addr = np.empty(0, dtype=np.int64)
        self.bb = np.empty(0, dtype=np.int32)
        self.shared = np.empty(0, dtype=bool)
        self.start = 0          # absolute per-core position of addr[0]
        self.done = False

    def pull(self) -> bool:
        try:
            t = next(self._iter)
        except StopIteration:
            self.done = True
            return False
        self.addr = np.concatenate([self.addr, t.addresses])
        self.bb = np.concatenate([self.bb, t.bb_ids])
        self.shared = np.concatenate([self.shared, t.shared_mask])
        return True

    def frontier_key(self, chunk: int) -> float:
        """Chunk key of the first position NOT yet buffered."""
        if self.done:
            return float("inf")
        return (self.start + len(self.addr)) // chunk

    def take_until(self, key_limit: float, chunk: int):
        """Split off the prefix whose chunk keys are < key_limit."""
        if key_limit == float("inf"):
            cut = len(self.addr)
        else:
            cut = int(min(len(self.addr),
                          max(key_limit * chunk - self.start, 0)))
        keys = (self.start + np.arange(cut, dtype=np.int64)) // chunk
        taken = (self.addr[:cut], self.bb[:cut], self.shared[:cut], keys)
        self.addr = self.addr[cut:]
        self.bb = self.bb[cut:]
        self.shared = self.shared[cut:]
        self.start += cut
        return taken


def interleave_windows(
    traces,
    strategy: str = "round_robin",
    *,
    window_size: int = 1 << 14,
    chunk_size: int = 1,
    seed: int = 0,
):
    """Streaming Algorithm 2: yield ``window_size``-sized windows of the
    interleaved shared trace without concatenating whole traces.

    Accepts any ``ChunkedTraceSource`` per core (``LabeledTrace``
    qualifies).  Peak memory is O(cores x (chunk + window)).  Emitted
    reference order is identical to ``interleave_traces`` for the
    deterministic strategies; ``uniform`` needs the global random choice
    sequence and stays in-memory-only.

    Windows carry window-local instance ids (the global renumbering of
    ``_merge_by_key`` needs the whole trace); the streaming consumers —
    reuse-distance and profile accumulation — only read addresses.
    """
    if strategy == "round_robin":
        chunk = 1
    elif strategy == "chunked":
        chunk = max(chunk_size, 1)
    elif strategy == "uniform":
        raise ValueError(
            "uniform interleaving draws one global random sequence over "
            "all trace lengths and cannot stream; use interleave_traces"
        )
    else:
        raise ValueError(f"unknown interleaving strategy: {strategy}")
    del seed  # deterministic strategies ignore it (signature parity)
    sources = list(traces)
    if not sources:
        raise ValueError("need at least one trace")
    names: dict[int, str] = {}
    for s in sources:
        names.update(getattr(s, "bb_names", {}))
    bufs = [_CoreBuffer(s, window_size) for s in sources]

    def merged_batches():
        """Key-ordered batches, each cut at a safe chunk boundary."""
        target = 0.0
        while True:
            for buf in bufs:
                while not buf.done and buf.frontier_key(chunk) <= target:
                    buf.pull()
            safe = min(buf.frontier_key(chunk) for buf in bufs)
            parts = [buf.take_until(safe, chunk) for buf in bufs]
            core_ids = np.concatenate(
                [np.full(len(p[0]), c, dtype=np.int64)
                 for c, p in enumerate(parts)]
            )
            keys = np.concatenate([p[3] for p in parts])
            order = np.lexsort((core_ids, keys))
            yield LabeledTrace(
                np.concatenate([p[0] for p in parts])[order],
                np.concatenate([p[1] for p in parts])[order],
                np.concatenate([p[2] for p in parts])[order],
                None,
                names,
            )
            if safe == float("inf"):
                return
            target = safe

    yield from rebatch_windows(merged_batches(), window_size)
