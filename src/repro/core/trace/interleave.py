"""Algorithm 2 — interleaving private traces into the shared trace
(paper §3.2.1).

Strategies:

* ``round_robin`` — one reference per core in turn, exhausted cores are
  skipped (the paper's primary strategy; deterministic, and the natural
  analog of XLA's static schedule on the TPU side);
* ``uniform``     — at every step a uniformly-random *non-exhausted*
  core is chosen (exact, implemented phase-vectorized);
* ``chunked``     — round-robin over chunks of ``chunk_size`` references
  (models coarser timeslices).

All strategies preserve per-core program order (a trace is a FIFO), and
the interleaved trace is a permutation of the concatenation of inputs —
both properties are enforced by tests.
"""
from __future__ import annotations

import numpy as np

from .types import LabeledTrace


def _merge_by_key(traces: list[LabeledTrace], keys: list[np.ndarray]) -> LabeledTrace:
    addr = np.concatenate([t.addresses for t in traces])
    bb = np.concatenate([t.bb_ids for t in traces])
    shared = np.concatenate([t.shared_mask for t in traces])
    shift, inst_parts = 0, []
    for t in traces:
        inst_parts.append(t.inst_ids + shift)
        shift += int(t.inst_ids.max()) + 1 if len(t) else 0
    inst = np.concatenate(inst_parts)
    core = np.concatenate(
        [np.full(len(t), c, dtype=np.int32) for c, t in enumerate(traces)]
    )
    key = np.concatenate(keys)
    order = np.lexsort((core, key))
    names: dict[int, str] = {}
    for t in traces:
        names.update(t.bb_names)
    return LabeledTrace(addr[order], bb[order], shared[order], inst[order], names)


def _round_robin_keys(traces: list[LabeledTrace], chunk: int = 1) -> list[np.ndarray]:
    # sort by (position // chunk, core): chunk=1 is exact Algorithm 2
    # round-robin (exhausted cores naturally drop out of later rounds).
    return [np.arange(len(t), dtype=np.int64) // chunk for t in traces]


def _uniform_choice_sequence(
    lengths: list[int], rng: np.random.Generator
) -> np.ndarray:
    """Exact Algorithm-2 uniform interleaving, phase-vectorized.

    Each step picks uniformly among cores that still have references.
    We sample in bulk and cut each phase at the first exhaustion, which
    is distribution-identical to the per-step loop.
    """
    remaining = np.array(lengths, dtype=np.int64)
    alive = np.flatnonzero(remaining > 0)
    chosen = np.empty(int(remaining.sum()), dtype=np.int64)
    pos = 0
    while alive.size:
        budget = int(remaining[alive].sum())
        draw = alive[rng.integers(0, alive.size, size=budget)]
        # cut the phase at the first index where some core's cumulative
        # count hits its remaining quota (that core exhausts there)
        cut = budget
        for c in alive:
            idx = np.flatnonzero(draw == c)
            if idx.size >= remaining[c]:
                cut = min(cut, int(idx[remaining[c] - 1]) + 1)
        take = draw[:cut]
        chosen[pos : pos + cut] = take
        pos += cut
        uniq, cnt = np.unique(take, return_counts=True)
        remaining[uniq] -= cnt
        alive = np.flatnonzero(remaining > 0)
    return chosen[:pos]


def _uniform_keys(
    traces: list[LabeledTrace], rng: np.random.Generator
) -> list[np.ndarray]:
    choice = _uniform_choice_sequence([len(t) for t in traces], rng)
    step = np.arange(len(choice), dtype=np.int64)
    keys = []
    for c in range(len(traces)):
        keys.append(step[choice == c])
    return keys


def interleave_traces(
    traces: list[LabeledTrace],
    strategy: str = "round_robin",
    *,
    chunk_size: int = 1,
    seed: int = 0,
) -> LabeledTrace:
    """Algorithm 2: merge private traces into the shared-cache trace."""
    if not traces:
        raise ValueError("need at least one trace")
    if strategy == "round_robin":
        keys = _round_robin_keys(traces, 1)
    elif strategy == "chunked":
        keys = _round_robin_keys(traces, max(chunk_size, 1))
    elif strategy == "uniform":
        keys = _uniform_keys(traces, np.random.default_rng(seed))
    else:
        raise ValueError(f"unknown interleaving strategy: {strategy}")
    return _merge_by_key(traces, keys)
