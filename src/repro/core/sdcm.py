"""SDCM — the Brehob–Enbody analytical cache model (paper Eq. 1–3).

Conditional hit probability of an access with reuse distance D on an
A-way associative cache of B blocks:

    P(h | D) = sum_{a=0}^{A-1} C(D, a) (A/B)^a ((B-A)/B)^(D-a)      (Eq. 1)

i.e. the CDF of Binomial(D, A/B) at A-1.  Direct-mapped (A=1) reduces to
((B-1)/B)^D (Eq. 2).  The unconditional program hit rate folds the reuse
profile (Eq. 3):  P(h) = sum_i P(D_i) · P(h | D_i).

Three implementations:
  * ``phit_given_d``      — JAX, numerically-stable binomial CDF via the
                            regularized incomplete beta function;
  * ``phit_given_d_np``   — float64 numpy oracle (log-space term sum);
  * ``kernels/sdcm``      — Pallas TPU kernel (recurrence sum), validated
                            against the numpy oracle in interpret mode.
"""
from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.special import betainc, gammaln, logsumexp

from .reuse.distance import INF_RD
from .reuse.profile import ReuseProfile

# Associativities up to this bound use the explicit log-space binomial
# sum (exact to ~1e-6 in f32); beyond it, betainc.  f32 betainc drifts
# by ~1e-2 for large D with tiny A/B, the log-space sum does not.
_LOGSPACE_MAX_ASSOC = 64


def _binom_cdf_logspace(df: jnp.ndarray, assoc: int, p: float) -> jnp.ndarray:
    """P[Bin(D, p) <= assoc-1] via a log-space term sum over k < assoc.

    log C(D,k) is built incrementally (cumsum of log((D-j+1)/j)) —
    magnitudes stay ~k·log(D), so f32 keeps ~1e-6 accuracy where the
    gammaln-difference form catastrophically cancels at large D.
    """
    d_col = df[..., None]  # [..., 1]
    j = jnp.arange(1, assoc, dtype=jnp.float32)  # [A-1]
    ratios = jnp.log(jnp.maximum(d_col - j + 1.0, 1e-30)) - jnp.log(j)
    log_comb = jnp.concatenate(
        [jnp.zeros_like(d_col), jnp.cumsum(ratios, axis=-1)], axis=-1
    )  # [..., A] : log C(D, k) for k = 0..A-1
    k = jnp.arange(assoc, dtype=jnp.float32)
    log_terms = log_comb + k * jnp.log(p) + (d_col - k) * jnp.log1p(-p)
    log_terms = jnp.where(k <= d_col, log_terms, -jnp.inf)
    return jnp.minimum(jnp.exp(logsumexp(log_terms, axis=-1)), 1.0)


def phit_given_d(d: jnp.ndarray, assoc: int, blocks: int) -> jnp.ndarray:
    """P(h | D) for an array of reuse distances (INF_RD -> 0). JAX path."""
    d = jnp.asarray(d)
    df = d.astype(jnp.float32)
    a = float(assoc)
    b = float(blocks)
    if assoc >= blocks:
        # fully associative: exact LRU rule — hit iff D < B.
        p = jnp.where(df < b, 1.0, 0.0)
    elif assoc == 1:
        p = jnp.exp(df * jnp.log1p(-1.0 / b))  # Eq. 2, stable form
    elif assoc <= _LOGSPACE_MAX_ASSOC:
        p = jnp.where(df <= a - 1.0, 1.0, _binom_cdf_logspace(df, assoc, a / b))
    else:
        # P[Bin(D, A/B) <= A-1] = I_{1-A/B}(D-A+1, A)
        x = (b - a) / b
        p = jnp.where(
            df <= a - 1.0,
            1.0,
            betainc(jnp.maximum(df - a + 1.0, 1e-6), a, x),
        )
    return jnp.where(d == INF_RD, 0.0, p).astype(jnp.float32)


def phit_given_d_np(d, assoc: int, blocks: int) -> np.ndarray:
    """Float64 oracle: direct log-space summation of Eq. 1."""
    d = np.asarray(d, dtype=np.int64)
    out = np.zeros(d.shape, dtype=np.float64)
    a_total, b_total = float(assoc), float(blocks)
    if assoc >= blocks:
        out = np.where((d >= 0) & (d < blocks), 1.0, 0.0)
        return np.where(d == INF_RD, 0.0, out)
    p = a_total / b_total
    logp, log1mp = math.log(p), math.log1p(-p)
    for i, dv in np.ndenumerate(d):
        if dv == INF_RD:
            out[i] = 0.0
        elif dv <= assoc - 1:
            out[i] = 1.0
        else:
            s = 0.0
            for k in range(assoc):
                lg = (
                    math.lgamma(dv + 1)
                    - math.lgamma(k + 1)
                    - math.lgamma(dv - k + 1)
                    + k * logp
                    + (dv - k) * log1mp
                )
                s += math.exp(lg)
            out[i] = min(1.0, s)
    return out


def hit_rate(profile: ReuseProfile, assoc: int, blocks: int) -> float:
    """Unconditional P(h) (Eq. 3) from a reuse profile."""
    if profile.total == 0:
        return 0.0
    ph = phit_given_d_np(profile.distances, assoc, blocks)
    return float(np.dot(profile.probabilities, ph))


def hit_rate_jax(profile: ReuseProfile, assoc: int, blocks: int) -> float:
    ph = phit_given_d(jnp.asarray(profile.distances), assoc, blocks)
    pr = jnp.asarray(profile.probabilities, dtype=jnp.float32)
    return float(jnp.dot(pr, ph))
