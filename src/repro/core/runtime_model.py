"""Analytical runtime prediction — paper §3.4 (Eq. 4–7).

    T_pred = T_mem + T_cpu                                        (Eq. 4)
    T_mem  = (δ_avg + (b-1)·β_avg)/b · total_mem                  (Eq. 5)
    δ_avg  = P1·δ1 + (1-P1)[P2·δ2 + (1-P2)[P3·δ3 + (1-P3)·δRAM]]  (Eq. 6)
    β_avg  = same chain over reciprocal throughputs               (Eq. 7)

plus the §3.4.2 non-contiguous block-size correction and the two-mode
(latency-bound vs throughput-bound) T_CPU.  Counts are divided across
cores (the paper's Fig. 7 tasklist divides ALU ops by core count).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # break the hw<->core import cycle (annotations only)
    from repro.hw.targets import CPUTarget, InstrTimings


@dataclass(frozen=True)
class OpCounts:
    """Byfl-style operation counts for a kernel (paper §3.4)."""

    int_ops: float = 0.0
    fp_ops: float = 0.0
    div_ops: float = 0.0
    loads: float = 0.0
    stores: float = 0.0
    total_bytes: float = 0.0  # total memory footprint touched (bytes)

    @property
    def mem_ops(self) -> float:
        return self.loads + self.stores

    def scaled(self, f: float) -> "OpCounts":
        return OpCounts(
            self.int_ops * f,
            self.fp_ops * f,
            self.div_ops * f,
            self.loads * f,
            self.stores * f,
            self.total_bytes * f,
        )


def level_chain(values: list[float], hit_rates: list[float], final: float) -> float:
    """The Eq. 6/7 chain:  Σ over levels of P_i·v_i weighted by upstream
    misses, terminating in the RAM/final term.

    ``values`` and ``hit_rates`` must be per-level parallel lists: a
    2-level rate list against a 3-level cost list would silently drop
    the deepest level under ``zip`` truncation, so a length mismatch is
    an error, not a shorter chain.
    """
    if len(values) != len(hit_rates):
        raise ValueError(
            f"level_chain needs one hit rate per level: got "
            f"{len(hit_rates)} rates for {len(values)} levels"
        )
    acc = final
    for p, v in zip(reversed(hit_rates), reversed(values)):
        acc = p * v + (1.0 - p) * acc
    return acc


def effective_latency_cy(target: CPUTarget, hit_rates: list[float]) -> float:
    """δ_avg (Eq. 6), in cycles."""
    return level_chain(list(target.level_latency_cy), hit_rates, target.ram_latency_cy)


def effective_beta_cy(target: CPUTarget, hit_rates: list[float]) -> float:
    """β_avg (Eq. 7), in cycles."""
    return level_chain(list(target.level_beta_cy), hit_rates, target.ram_beta_cy)


def cumulative_to_conditional(hit_rates: list[float]) -> list[float]:
    """Convert the paper's cumulative per-level hit rates (Table 6
    metric) into conditional (given upstream miss) rates for the chain.
    The paper plugs cumulative rates into Eq. 6 directly; the conversion
    is offered because the conditional chain is the textbook AMAT form —
    benchmarks report both (EXPERIMENTS.md)."""
    cond = []
    miss_prob = 1.0
    for p_cum in hit_rates:
        served_here = max(0.0, p_cum - (1.0 - miss_prob))
        cond.append(min(1.0, served_here / miss_prob) if miss_prob > 1e-12 else 1.0)
        miss_prob = max(0.0, 1.0 - p_cum)
    return cond


def noncontiguous_block_size(
    b_new: float, transfer_chunk: float, max_block: float
) -> float:
    """§3.4.2 block-size clamping: gaps inflate the block, transfers
    quantize to the chunk C, and blocks cap at S.

    The cap applies AFTER quantization: when C does not divide S, the
    ceil-to-chunk of a block just under the cap overshoots it (e.g.
    C=64, S=100, b_new=99 -> 128), and S is the hardware's hard limit.
    """
    if b_new <= transfer_chunk:
        return transfer_chunk
    if b_new >= max_block:
        return max_block
    import math

    return min(math.ceil(b_new / transfer_chunk) * transfer_chunk, max_block)


def t_mem_s(
    target: CPUTarget,
    hit_rates: list[float],
    total_bytes: float,
    *,
    block_bytes: float | None = None,
    gap_bytes: float = 0.0,
    transfer_chunk: float | None = None,
    max_block: float | None = None,
    conditional_chain: bool = False,
) -> float:
    """T_mem (Eq. 5), seconds.  ``gap_bytes > 0`` engages the
    non-contiguous model of §3.4.2."""
    rates = cumulative_to_conditional(hit_rates) if conditional_chain else hit_rates
    delta = effective_latency_cy(target, rates)
    beta = effective_beta_cy(target, rates)
    b = float(block_bytes if block_bytes is not None else target.word_bytes)
    if gap_bytes > 0.0:
        chunk = float(transfer_chunk if transfer_chunk is not None else target.levels[0].line_size)
        cap = float(max_block if max_block is not None else target.levels[-1].line_size * 64)
        b = noncontiguous_block_size(b + gap_bytes, chunk, cap)
    per_byte_cy = (delta + (b - 1.0) * beta) / b
    return per_byte_cy * total_bytes * target.cycle_s


def t_cpu_s(target: CPUTarget, counts: OpCounts, mode: str = "throughput") -> float:
    """T_CPU (§3.4.2), seconds, for the per-core share of `counts`.

    ``throughput`` — pipelined issue: one latency then β per instr;
    ``latency``    — serialized dependent chain: δ per instr.
    """
    t = target.instr
    classes = [
        (counts.int_ops, t.delta_int, t.beta_int),
        (counts.fp_ops, t.delta_fp, t.beta_fp),
        (counts.div_ops, t.delta_div, t.beta_div),
    ]
    cy = 0.0
    for n, delta, beta in classes:
        if n <= 0:
            continue
        if mode == "throughput":
            cy += delta + max(n - 1.0, 0.0) * beta
        elif mode == "latency":
            cy += n * delta
        else:
            raise ValueError(f"unknown T_CPU mode: {mode}")
    return cy * target.cycle_s


def predict_runtime_s(
    target: CPUTarget,
    hit_rates: list[float],
    counts: OpCounts,
    num_cores: int,
    *,
    mode: str = "throughput",
    gap_bytes: float = 0.0,
    conditional_chain: bool = False,
) -> dict:
    """T_pred (Eq. 4) for the parallel section on ``num_cores`` cores.

    Work (ops and bytes) is divided evenly across cores, the paper's
    assumption ("we assume that the total workload is distributed among
    multiple cores evenly") — which also reproduces its known failure
    mode on non-scaling apps (§4.2, jacobi).
    """
    share = counts.scaled(1.0 / max(num_cores, 1))
    tm = t_mem_s(
        target,
        hit_rates,
        share.total_bytes,
        gap_bytes=gap_bytes,
        conditional_chain=conditional_chain,
    )
    tc = t_cpu_s(target, share, mode=mode)
    return {"t_pred_s": tm + tc, "t_mem_s": tm, "t_cpu_s": tc}
