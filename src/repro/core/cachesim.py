"""Exact multi-level set-associative LRU cache simulation.

This is the framework's ground-truth stand-in for the paper's PAPI
hardware counters (§4.1): the container has no PAPI/perf access, so
predicted hit rates are validated against an *exact* LRU simulation of
the same traces.

Metric convention follows the paper's Table 6: the level-L hit rate is
cumulative —  1 - (misses at L) / (total memory accesses)  — which is
what `1 - PAPI_L2_DCM/(PAPI_LD_INS+PAPI_SR_INS)` measures.  Lower levels
see only the miss-filtered trace (inclusive hierarchy).

Exactness: an access hits an A-way LRU set-associative cache iff the
number of distinct same-set lines touched since its line's last use is
< A; we compute those per-set distances exactly (see
``per_set_reuse_distances``).
"""
from __future__ import annotations

import numpy as np

from .levels import CacheLevelConfig, LevelResult
from .reuse.distance import per_set_reuse_distances

__all__ = [
    "CacheLevelConfig",
    "LevelResult",
    "simulate_level",
    "simulate_hierarchy",
]


def simulate_level(addresses: np.ndarray, cfg: CacheLevelConfig) -> np.ndarray:
    """Boolean hit mask for one level (exact LRU)."""
    if len(addresses) == 0:
        return np.zeros(0, dtype=bool)
    rds = per_set_reuse_distances(
        addresses, line_size=cfg.line_size, num_sets=cfg.num_sets
    )
    return (rds >= 0) & (rds < cfg.effective_assoc)


def simulate_hierarchy(
    addresses, levels: list[CacheLevelConfig]
) -> list[LevelResult]:
    """Exact LRU simulation of an inclusive multi-level hierarchy."""
    addresses = np.asarray(addresses, dtype=np.int64)
    total = len(addresses)
    results: list[LevelResult] = []
    current = addresses
    for cfg in levels:
        hit_mask = simulate_level(current, cfg)
        hits = int(hit_mask.sum())
        misses = len(current) - hits
        results.append(
            LevelResult(
                name=cfg.name,
                accesses=len(current),
                hits=hits,
                cumulative_hit_rate=1.0 - misses / max(total, 1),
            )
        )
        current = current[~hit_mask]
    return results
