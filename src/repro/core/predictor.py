"""Legacy end-to-end predictor — now a thin DEPRECATED shim over
:class:`repro.api.Session` (see docs/api_migration.md).

The class predates the unified pipeline: it recomputes reuse profiles
on every ``predict`` call and only speaks CPU targets.  It is kept so
existing scripts keep working bit-for-bit — internally every method
routes through the same stage implementations the new API uses, with
artifact caching disabled to preserve the legacy per-call cost model.

New code should build a :class:`repro.api.PredictionRequest` and run it
through a cached ``Session`` instead.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

from repro.core.reuse.profile import ReuseProfile
from repro.core.runtime_model import OpCounts
from repro.core.trace.types import LabeledTrace
from repro.hw.targets import CPUTarget


@dataclass
class Prediction:
    target: str
    num_cores: int
    strategy: str
    hit_rates: dict[str, float]        # level name -> predicted P(h)
    t_pred_s: float
    t_mem_s: float
    t_cpu_s: float
    private_profile: ReuseProfile | None = None
    shared_profile: ReuseProfile | None = None


class PPTMulticorePredictor:
    """Deprecated: use ``repro.api.Session`` + ``PredictionRequest``.

    Trace -> profiles -> SDCM hit rates -> Eq.4-7 runtime, exactly as
    before; each call recomputes its artifacts (the legacy behaviour —
    the new Session amortizes them across a whole grid).
    """

    def __init__(self, target: CPUTarget):
        warnings.warn(
            "PPTMulticorePredictor is deprecated; use repro.api.Session "
            "with a PredictionRequest (docs/api_migration.md)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.api.session import Session

        self.target = target
        self._session = Session(cache=False)

    def _level_profiles(
        self, trace: LabeledTrace, num_cores: int, strategy: str, seed: int
    ) -> tuple[ReuseProfile, ReuseProfile]:
        art = self._session.artifacts(
            trace, num_cores, strategy=strategy, seed=seed,
            line_size=self.target.levels[0].line_size,
        )
        return art.prd, art.crd

    def hit_rates(
        self,
        trace: LabeledTrace,
        num_cores: int,
        *,
        strategy: str = "round_robin",
        seed: int = 0,
    ) -> tuple[dict[str, float], ReuseProfile, ReuseProfile]:
        art = self._session.artifacts(
            trace, num_cores, strategy=strategy, seed=seed,
            line_size=self.target.levels[0].line_size,
        )
        rates = self._session.cache_model.hit_rates(self.target, art)
        return rates, art.prd, art.crd

    def predict(
        self,
        trace: LabeledTrace,
        num_cores: int,
        counts: OpCounts,
        *,
        strategy: str = "round_robin",
        mode: str = "throughput",
        gap_bytes: float = 0.0,
        seed: int = 0,
        keep_profiles: bool = False,
    ) -> Prediction:
        from repro.api.request import PredictionRequest

        req = PredictionRequest(
            targets=(self.target,),
            core_counts=(num_cores,),
            strategies=(strategy,),
            modes=(mode,),
            counts=counts,
            seed=seed,
            gap_bytes=gap_bytes,
            keep_profiles=keep_profiles,
            respect_core_limit=False,
        )
        cell = self._session.predict(trace, req).predictions[0]
        return Prediction(
            target=cell.target,
            num_cores=cell.cores,
            strategy=cell.strategy,
            hit_rates=cell.hit_rates,
            t_pred_s=cell.t_pred_s,
            t_mem_s=cell.t_mem_s,
            t_cpu_s=cell.t_cpu_s,
            private_profile=cell.private_profile,
            shared_profile=cell.shared_profile,
        )

    def sweep_cores(
        self,
        trace: LabeledTrace,
        core_counts: list[int],
        counts: OpCounts,
        **kw,
    ) -> list[Prediction]:
        """Predict across core counts from the single trace — the
        paper's scalability claim, one trace collection amortized."""
        return [self.predict(trace, c, counts, **kw) for c in core_counts]

    def ground_truth_hit_rates(
        self,
        trace: LabeledTrace,
        num_cores: int,
        *,
        strategy: str = "round_robin",
        seed: int = 0,
    ) -> dict[str, float]:
        """Exact LRU simulation of the same mimicked traces — the
        container's PAPI stand-in (DESIGN.md §7)."""
        return self._session.ground_truth_hit_rates(
            trace, self.target, num_cores, strategy=strategy, seed=seed
        )
