"""End-to-end PPT-Multicore predictor (paper Fig. 1).

One sequential labeled trace in; per-level cache hit rates and the
predicted runtime of the parallel section out — for ANY core count,
without re-tracing (the paper's headline property: "predictions for
various core counts without having to rerun the application").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import sdcm
from repro.core.cachesim import simulate_hierarchy
from repro.core.reuse.crd import multicore_profiles
from repro.core.reuse.distance import reuse_distances
from repro.core.reuse.profile import ReuseProfile, profile_from_distances
from repro.core.runtime_model import OpCounts, predict_runtime_s
from repro.core.trace.interleave import interleave_traces
from repro.core.trace.mimic import gen_private_traces
from repro.core.trace.types import LabeledTrace

if True:  # lazy: repro.hw imports repro.core (cachesim) — avoid the cycle
    from typing import TYPE_CHECKING
    if TYPE_CHECKING:
        from repro.hw.targets import CPUTarget


@dataclass
class Prediction:
    target: str
    num_cores: int
    strategy: str
    hit_rates: dict[str, float]        # level name -> predicted P(h)
    t_pred_s: float
    t_mem_s: float
    t_cpu_s: float
    private_profile: ReuseProfile | None = None
    shared_profile: ReuseProfile | None = None


class PPTMulticorePredictor:
    """Trace -> profiles -> SDCM hit rates -> Eq.4-7 runtime.

    Private levels (below ``target.shared_level``) are predicted from
    the PRD of the mimicked private traces; the shared LLC from the CRD
    of the interleaved trace.  Per the paper's Table-6 metric, every
    level's SDCM is evaluated against the *full* profile at that level's
    geometry (cumulative hit rates).
    """

    def __init__(self, target: CPUTarget):
        self.target = target

    def _level_profiles(
        self, trace: LabeledTrace, num_cores: int, strategy: str, seed: int
    ) -> tuple[ReuseProfile, ReuseProfile]:
        line = self.target.levels[0].line_size
        if num_cores == 1:
            prof = profile_from_distances(reuse_distances(trace.addresses, line))
            return prof, prof
        privates = gen_private_traces(trace, num_cores)
        # PRD of the master core (cores are symmetric by construction;
        # averaging over cores is available via multicore_profiles).
        prd = profile_from_distances(reuse_distances(privates[0].addresses, line))
        shared = interleave_traces(privates, strategy, seed=seed)
        crd = profile_from_distances(reuse_distances(shared.addresses, line))
        return prd, crd

    def hit_rates(
        self,
        trace: LabeledTrace,
        num_cores: int,
        *,
        strategy: str = "round_robin",
        seed: int = 0,
    ) -> tuple[dict[str, float], ReuseProfile, ReuseProfile]:
        prd, crd = self._level_profiles(trace, num_cores, strategy, seed)
        shared_idx = self.target.shared_level % len(self.target.levels)
        rates: dict[str, float] = {}
        for i, lvl in enumerate(self.target.levels):
            prof = crd if i >= shared_idx else prd
            rates[lvl.name] = sdcm.hit_rate(prof, lvl.effective_assoc, lvl.num_lines)
        return rates, prd, crd

    def predict(
        self,
        trace: LabeledTrace,
        num_cores: int,
        counts: OpCounts,
        *,
        strategy: str = "round_robin",
        mode: str = "throughput",
        gap_bytes: float = 0.0,
        seed: int = 0,
        keep_profiles: bool = False,
    ) -> Prediction:
        rates, prd, crd = self.hit_rates(
            trace, num_cores, strategy=strategy, seed=seed
        )
        timing = predict_runtime_s(
            self.target,
            [rates[l.name] for l in self.target.levels],
            counts,
            num_cores,
            mode=mode,
            gap_bytes=gap_bytes,
        )
        return Prediction(
            target=self.target.name,
            num_cores=num_cores,
            strategy=strategy,
            hit_rates=rates,
            t_pred_s=timing["t_pred_s"],
            t_mem_s=timing["t_mem_s"],
            t_cpu_s=timing["t_cpu_s"],
            private_profile=prd if keep_profiles else None,
            shared_profile=crd if keep_profiles else None,
        )

    def sweep_cores(
        self,
        trace: LabeledTrace,
        core_counts: list[int],
        counts: OpCounts,
        **kw,
    ) -> list[Prediction]:
        """Predict across core counts from the single trace — the
        paper's scalability claim, one trace collection amortized."""
        return [self.predict(trace, c, counts, **kw) for c in core_counts]

    def ground_truth_hit_rates(
        self,
        trace: LabeledTrace,
        num_cores: int,
        *,
        strategy: str = "round_robin",
        seed: int = 0,
    ) -> dict[str, float]:
        """Exact LRU simulation of the same mimicked traces — the
        container's PAPI stand-in (DESIGN.md §7)."""
        shared_idx = self.target.shared_level % len(self.target.levels)
        if num_cores == 1:
            res = simulate_hierarchy(trace.addresses, list(self.target.levels))
            return {r.name: r.cumulative_hit_rate for r in res}
        privates = gen_private_traces(trace, num_cores)
        shared = interleave_traces(privates, strategy, seed=seed)
        out: dict[str, float] = {}
        # private levels: simulate the master core's private hierarchy
        res_priv = simulate_hierarchy(
            privates[0].addresses, list(self.target.levels[:shared_idx])
        )
        for r in res_priv:
            out[r.name] = r.cumulative_hit_rate
        # shared levels: simulate on the interleaved trace
        res_shared = simulate_hierarchy(
            shared.addresses, list(self.target.levels)
        )
        for r, lvl in zip(res_shared, self.target.levels):
            if lvl.name not in out:
                out[lvl.name] = r.cumulative_hit_rate
        return out
