"""PPT-Multicore core: reuse-profile analytical performance prediction.

The paper's pipeline (Fig. 1):  labeled trace -> mimicked private
traces (Alg. 1) -> interleaved shared trace (Alg. 2) -> PRD/CRD reuse
profiles -> SDCM hit rates (Eq. 1-3) -> analytical runtime (Eq. 4-7).
"""
from repro.core.predictor import PPTMulticorePredictor, Prediction
from repro.core.runtime_model import OpCounts, predict_runtime_s
from repro.core.sdcm import hit_rate, phit_given_d, phit_given_d_np

__all__ = [
    "PPTMulticorePredictor",
    "Prediction",
    "OpCounts",
    "predict_runtime_s",
    "hit_rate",
    "phit_given_d",
    "phit_given_d_np",
]
