"""PPT-Multicore core: reuse-profile analytical performance prediction.

The paper's pipeline (Fig. 1):  labeled trace -> mimicked private
traces (Alg. 1) -> interleaved shared trace (Alg. 2) -> PRD/CRD reuse
profiles -> SDCM hit rates (Eq. 1-3) -> analytical runtime (Eq. 4-7).

Re-exports resolve lazily (PEP 562): ``repro.hw.targets`` imports the
leaf ``repro.core.levels``, and an eager predictor import here would
close an hw <-> core cycle.
"""
from __future__ import annotations

_EXPORTS = {
    "PPTMulticorePredictor": "repro.core.predictor",
    "Prediction": "repro.core.predictor",
    "OpCounts": "repro.core.runtime_model",
    "predict_runtime_s": "repro.core.runtime_model",
    "hit_rate": "repro.core.sdcm",
    "phit_given_d": "repro.core.sdcm",
    "phit_given_d_np": "repro.core.sdcm",
    "CacheLevelConfig": "repro.core.levels",
    "LevelResult": "repro.core.levels",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
