"""PPT tasklist ingestion (paper Fig. 7).

The PPT Simian PDES model consumes a *tasklist*: per parallel section,
the instruction-class counts (divided by core count), memory footprint
and the reuse profiles.  We keep the same shape as a plain dict /
JSON-serializable record so predictions can be driven from files the
way PPT drives Simian.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core.reuse.profile import ReuseProfile, profile_from_pairs
from repro.core.runtime_model import OpCounts


@dataclass
class Task:
    name: str
    num_cores: int
    counts: OpCounts
    block_bytes: float
    private_profile: ReuseProfile
    shared_profile: ReuseProfile

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "num_cores": self.num_cores,
            # Fig. 7 divides ALU op counts by the core count when
            # emitting the tasklist; we store raw totals plus the core
            # count and divide at evaluation time (equivalent, lossless).
            "iALU": self.counts.int_ops,
            "fALU": self.counts.fp_ops,
            "fDIV": self.counts.div_ops,
            "loads": self.counts.loads,
            "stores": self.counts.stores,
            "total_bytes": self.counts.total_bytes,
            "block_bytes": self.block_bytes,
            "private_profile": _profile_to_lists(self.private_profile),
            "shared_profile": _profile_to_lists(self.shared_profile),
        }

    @staticmethod
    def from_dict(d: dict) -> "Task":
        return Task(
            name=d["name"],
            num_cores=int(d["num_cores"]),
            counts=OpCounts(
                int_ops=d["iALU"],
                fp_ops=d["fALU"],
                div_ops=d["fDIV"],
                loads=d["loads"],
                stores=d["stores"],
                total_bytes=d["total_bytes"],
            ),
            block_bytes=d["block_bytes"],
            private_profile=_profile_from_lists(d["private_profile"]),
            shared_profile=_profile_from_lists(d["shared_profile"]),
        )


def _profile_to_lists(p: ReuseProfile) -> dict:
    return {
        "distances": [int(x) for x in p.distances],
        "counts": [int(x) for x in p.counts],
    }


def _profile_from_lists(d: dict) -> ReuseProfile:
    return profile_from_pairs(
        np.asarray(d["distances"], dtype=np.int64),
        np.asarray(d["counts"], dtype=np.int64),
    )


def save_tasklist(tasks: list[Task], path: str) -> None:
    with open(path, "w") as f:
        json.dump([t.to_dict() for t in tasks], f)


def load_tasklist(path: str) -> list[Task]:
    with open(path) as f:
        return [Task.from_dict(d) for d in json.load(f)]
