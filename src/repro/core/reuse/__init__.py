from repro.core.reuse.distance import (
    DEFAULT_WINDOW,
    INF_RD,
    per_set_reuse_distances,
    reuse_distance_windows,
    reuse_distances,
    reuse_distances_ref,
    reuse_distances_streaming,
)
from repro.core.reuse.profile import (
    ReuseProfile,
    log2_binned,
    profile_from_distances,
    profile_from_distances_incremental,
    profile_from_trace,
)
from repro.core.reuse.crd import MulticoreProfiles, crd_profile, multicore_profiles

__all__ = [
    "DEFAULT_WINDOW",
    "INF_RD",
    "per_set_reuse_distances",
    "reuse_distance_windows",
    "reuse_distances",
    "reuse_distances_ref",
    "reuse_distances_streaming",
    "ReuseProfile",
    "log2_binned",
    "profile_from_distances",
    "profile_from_distances_incremental",
    "profile_from_trace",
    "MulticoreProfiles",
    "crd_profile",
    "multicore_profiles",
]
