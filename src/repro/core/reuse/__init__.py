from repro.core.reuse.distance import (
    DEFAULT_WINDOW,
    INF_RD,
    per_set_reuse_distances,
    reuse_distance_windows,
    reuse_distance_windows_device,
    reuse_distances,
    reuse_distances_ref,
    reuse_distances_streaming,
)
from repro.core.reuse.batched import (
    reuse_distances_batched,
    reuse_distances_offline,
)
from repro.core.reuse.fused import (
    FusedReuseHistogram,
    binned_profile_from_distances,
    binned_profile_windows,
    profile_from_binned_hist,
)
from repro.core.reuse.profile import (
    ReuseProfile,
    log2_binned,
    profile_from_distances,
    profile_from_distances_incremental,
    profile_from_trace,
)
from repro.core.reuse.sampled import (
    SAMPLE_BOUND_DELTA,
    sample_lines_mask,
    sampled_profile_windows,
    sampled_reuse_profile,
    sampling_error_bound,
)
from repro.core.reuse.crd import MulticoreProfiles, crd_profile, multicore_profiles

__all__ = [
    "DEFAULT_WINDOW",
    "INF_RD",
    "per_set_reuse_distances",
    "reuse_distance_windows",
    "reuse_distance_windows_device",
    "reuse_distances",
    "reuse_distances_batched",
    "reuse_distances_offline",
    "reuse_distances_ref",
    "reuse_distances_streaming",
    "FusedReuseHistogram",
    "binned_profile_from_distances",
    "binned_profile_windows",
    "profile_from_binned_hist",
    "ReuseProfile",
    "log2_binned",
    "profile_from_distances",
    "profile_from_distances_incremental",
    "profile_from_trace",
    "MulticoreProfiles",
    "crd_profile",
    "multicore_profiles",
    "SAMPLE_BOUND_DELTA",
    "sample_lines_mask",
    "sampled_profile_windows",
    "sampled_reuse_profile",
    "sampling_error_bound",
]
