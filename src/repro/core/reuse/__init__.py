from repro.core.reuse.distance import (
    INF_RD,
    per_set_reuse_distances,
    reuse_distances,
    reuse_distances_ref,
)
from repro.core.reuse.profile import (
    ReuseProfile,
    log2_binned,
    profile_from_distances,
    profile_from_trace,
)
from repro.core.reuse.crd import MulticoreProfiles, crd_profile, multicore_profiles

__all__ = [
    "INF_RD",
    "per_set_reuse_distances",
    "reuse_distances",
    "reuse_distances_ref",
    "ReuseProfile",
    "log2_binned",
    "profile_from_distances",
    "profile_from_trace",
    "MulticoreProfiles",
    "crd_profile",
    "multicore_profiles",
]
