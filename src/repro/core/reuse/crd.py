"""PRD / CRD construction (paper §2.4, Table 3; §3.2–3.3).

* PRD — *private-stack* reuse profile: reuse distances of one core's
  mimicked private trace.
* CRD — *concurrent* reuse profile: reuse distances of the interleaved
  shared trace, exhibiting dilation (remote refs inflate D), overlap
  (shared data between the endpoints deflates it) and interception
  (the reused datum itself is shared).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.trace.interleave import interleave_traces
from repro.core.trace.mimic import gen_private_traces
from repro.core.trace.types import LabeledTrace

from .distance import reuse_distances
from .profile import ReuseProfile, profile_from_distances


@dataclass(frozen=True)
class MulticoreProfiles:
    num_cores: int
    private: list[ReuseProfile]   # per core, PRD
    shared: ReuseProfile          # CRD of the interleaved trace
    strategy: str


def prd_profiles(
    private_traces: list[LabeledTrace], line_size: int = 1
) -> list[ReuseProfile]:
    return [
        profile_from_distances(reuse_distances(t.addresses, line_size))
        for t in private_traces
    ]


def crd_profile(
    private_traces: list[LabeledTrace],
    strategy: str = "round_robin",
    *,
    line_size: int = 1,
    chunk_size: int = 1,
    seed: int = 0,
) -> ReuseProfile:
    shared = interleave_traces(
        private_traces, strategy, chunk_size=chunk_size, seed=seed
    )
    return profile_from_distances(reuse_distances(shared.addresses, line_size))


def multicore_profiles(
    trace: LabeledTrace,
    num_cores: int,
    *,
    strategy: str = "round_robin",
    line_size: int = 1,
    chunk_size: int | None = None,
    seed: int = 0,
) -> MulticoreProfiles:
    """One sequential trace -> PRD per core + CRD (the paper's pipeline)."""
    privates = gen_private_traces(trace, num_cores, chunk_size=chunk_size)
    return MulticoreProfiles(
        num_cores=num_cores,
        private=prd_profiles(privates, line_size),
        shared=crd_profile(
            privates, strategy, line_size=line_size, seed=seed
        ),
        strategy=strategy,
    )
