"""Reuse profiles P(D) — paper §2.3 (Table 2) and §3.3.1.

A reuse profile is the histogram of reuse distances of a trace: the
distance values, their counts, and the empirical probability P(D).
``INF_RD`` (-1) carries the compulsory-miss mass (D = ∞).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .distance import INF_RD, reuse_distances


@dataclass(frozen=True)
class ReuseProfile:
    """Histogram of reuse distances.

    Attributes
    ----------
    distances : sorted distinct distances; ``INF_RD`` first when present.
    counts    : occurrence count per distance.
    total     : total number of accesses (== counts.sum()).
    error_bound : declared sup-norm error of an approximate profile
        (``core.reuse.sampled``); ``None`` for exact profiles, ``0.0``
        for a sampled pass at rate 1.0.
    """

    distances: np.ndarray
    counts: np.ndarray
    total: int
    error_bound: float | None = None

    def with_error_bound(self, bound: float | None) -> "ReuseProfile":
        return ReuseProfile(self.distances, self.counts, self.total, bound)

    @property
    def probabilities(self) -> np.ndarray:
        return self.counts / max(self.total, 1)

    @property
    def inf_fraction(self) -> float:
        """Compulsory-miss mass P(D = ∞)."""
        mask = self.distances == INF_RD
        if not mask.any():
            return 0.0
        return float(self.counts[mask][0]) / max(self.total, 1)

    def finite(self) -> tuple[np.ndarray, np.ndarray]:
        """(distances, probabilities) excluding the ∞ bucket."""
        mask = self.distances != INF_RD
        return self.distances[mask], self.probabilities[mask]

    def merged_with(self, other: "ReuseProfile") -> "ReuseProfile":
        return ReuseProfile.merge([self, other])

    @staticmethod
    def merge(profiles) -> "ReuseProfile":
        """Sum any number of histograms — the streaming accumulator's
        combine step (windows, shards, and sampled replicas all merge
        through here)."""
        profiles = list(profiles)
        if not profiles:
            return ReuseProfile(
                np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64), 0
            )
        dists = np.concatenate([p.distances for p in profiles])
        counts = np.concatenate([p.counts for p in profiles])
        merged = profile_from_pairs(dists, counts)
        # merging approximate profiles can't tighten their error: the
        # merged profile carries the loosest declared bound
        bounds = [p.error_bound for p in profiles if p.error_bound is not None]
        return merged.with_error_bound(max(bounds)) if bounds else merged

    def scaled(self, factor: float) -> "ReuseProfile":
        """Scale counts (e.g. trace-sampling extrapolation)."""
        counts = np.maximum(np.round(self.counts * factor), 0).astype(np.int64)
        return ReuseProfile(
            self.distances, counts, int(counts.sum()), self.error_bound
        )


def profile_from_pairs(distances, counts) -> ReuseProfile:
    distances = np.asarray(distances, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    order = np.argsort(distances, kind="stable")
    distances, counts = distances[order], counts[order]
    uniq, start = np.unique(distances, return_index=True)
    summed = np.add.reduceat(counts, start) if len(distances) else counts[:0]
    return ReuseProfile(uniq, summed.astype(np.int64), int(summed.sum()))


def profile_from_distances(rds) -> ReuseProfile:
    """Build a reuse profile from raw reuse distances (Table 2)."""
    rds = np.asarray(rds, dtype=np.int64)
    uniq, counts = np.unique(rds, return_counts=True)
    return ReuseProfile(uniq, counts.astype(np.int64), int(rds.size))


def profile_from_trace(addresses, line_size: int = 1) -> ReuseProfile:
    return profile_from_distances(reuse_distances(addresses, line_size))


def profile_from_distances_incremental(rd_windows) -> ReuseProfile:
    """Fold an iterable of reuse-distance windows into one profile.

    The streaming accumulator: each window is histogrammed and merged
    into the running (distances, counts) pair, so peak memory is
    O(distinct distances + window) — the O(N) distance array never
    exists.  Feed it ``reuse_distance_windows(...)``.
    """
    acc_d = np.empty(0, dtype=np.int64)
    acc_c = np.empty(0, dtype=np.int64)
    for rds in rd_windows:
        rds = np.asarray(rds, dtype=np.int64)
        if rds.size == 0:
            continue
        u, c = np.unique(rds, return_counts=True)
        merged = profile_from_pairs(
            np.concatenate([acc_d, u]), np.concatenate([acc_c, c])
        )
        acc_d, acc_c = merged.distances, merged.counts
    return ReuseProfile(acc_d, acc_c, int(acc_c.sum()))


def log2_binned(profile: ReuseProfile, num_bins: int = 64) -> ReuseProfile:
    """Coarsen a profile into log2 bins (keeps SDCM accuracy, shrinks size).

    Bin representative = geometric-ish midpoint; the ∞ bucket is kept.
    """
    dists, counts = profile.distances, profile.counts
    inf_mask = dists == INF_RD
    fin_d, fin_c = dists[~inf_mask], counts[~inf_mask]
    out_d, out_c = [], []
    if inf_mask.any():
        out_d.append(INF_RD)
        out_c.append(int(counts[inf_mask].sum()))
    if fin_d.size:
        bins = np.zeros_like(fin_d)
        pos = fin_d > 0
        bins[pos] = np.floor(np.log2(fin_d[pos])).astype(np.int64) + 1
        bins = np.minimum(bins, num_bins - 1)
        for b in np.unique(bins):
            sel = bins == b
            w = fin_c[sel].astype(np.float64)
            rep = int(round(float(np.average(fin_d[sel], weights=w))))
            out_d.append(rep)
            out_c.append(int(w.sum()))
    return profile_from_pairs(np.array(out_d), np.array(out_c))
