"""Reuse (LRU stack) distance computation — paper §2.3 / §3.3.1.

The paper replaces the O(N·M) stack algorithm with a tree-based
O(N·log M) method [Niu et al., PARDA].  We implement the tree as a
Fenwick (binary-indexed) tree carried through a ``jax.lax.scan`` so the
whole pass is a single XLA program: O(N·log N) work, O(N) memory.

Conventions
-----------
* A reuse distance of ``INF_RD`` (= -1 sentinel) marks a first-touch
  (compulsory) access, the paper's ``D = ∞``.
* Distances are measured in *distinct elements* (addresses or cache
  lines) accessed strictly between two uses of the same element
  (Table 1 of the paper).
"""
from __future__ import annotations

import functools
from typing import Iterable, Iterator

import numpy as np

import jax
import jax.numpy as jnp

INF_RD: int = -1

# Streaming-scan window default.  XLA:CPU's scan carries the Fenwick
# tree by value (one O(timeline) copy per step), so small timelines are
# faster as well as smaller; 16Ki refs balances per-step copy cost
# against per-window dispatch overhead on current CPU backends.
DEFAULT_WINDOW: int = 1 << 14

# Above this many references, reuse_distances routes to the vectorized
# offline engine (core/reuse/batched.py): bit-identical output, no
# sequential scan, and no per-trace-length XLA compilation.  Below it
# the jitted Fenwick scan is fast enough and stays the default oracle.
RD_OFFLINE_THRESHOLD: int = 1 << 13

# per_set_reuse_distances switches from the monolithic stably-
# concatenated scan (whose O(N)-per-step timeline collapses past ~50k
# refs) to the batched multi-segment engine above this size.
PER_SET_BATCH_THRESHOLD: int = 1 << 15


# ---------------------------------------------------------------------------
# Reference oracle: classic O(N·M) LRU stack (paper's "conventional" method).
# ---------------------------------------------------------------------------

def reuse_distances_ref(addresses) -> np.ndarray:
    """O(N·M) LRU-stack reuse distances.  Ground-truth oracle for tests.

    Reproduces Table 1 of the paper exactly (first touch -> INF_RD).
    """
    stack: list = []  # stack[0] is most-recently-used
    out = np.empty(len(addresses), dtype=np.int64)
    for t, a in enumerate(addresses):
        try:
            d = stack.index(a)
            out[t] = d
            stack.pop(d)
        except ValueError:
            out[t] = INF_RD
        stack.insert(0, a)
    return out


# ---------------------------------------------------------------------------
# Tree-based O(N log N) method as a single lax.scan (paper §3.3.1).
# ---------------------------------------------------------------------------

def compact_ids(addresses) -> np.ndarray:
    """Map arbitrary (possibly 64-bit) addresses to dense int32 ids."""
    arr = np.asarray(addresses)
    _, inv = np.unique(arr, return_inverse=True)
    return inv.astype(np.int32)


def _fenwick_levels(n: int) -> int:
    """Number of Fenwick iterations needed for a tree of n slots."""
    return max(1, int(n).bit_length())


@jax.jit
def _fenwick_rd_scan(ids: jnp.ndarray) -> jnp.ndarray:
    """Reuse distances over dense ids via a Fenwick tree in a lax.scan.

    The Fenwick tree stores a 1 at the (1-indexed) position of the
    *latest* occurrence of every id seen so far; the number of distinct
    ids touched in an open window (last, i) is then a prefix-sum
    difference — the Bennett–Kruskal formulation used by tree-based RD
    algorithms.
    """
    n = ids.shape[0]
    tree_size = n + 2
    levels = _fenwick_levels(tree_size)

    def query(tree, k):
        # prefix sum over 1-indexed positions 1..k; tree[0] is always 0.
        def body(_, state):
            s, k = state
            valid = k > 0
            s = s + jnp.where(valid, tree[jnp.maximum(k, 0)], 0)
            k = jnp.where(valid, k - (k & -k), k)
            return s, k

        s, _ = jax.lax.fori_loop(0, levels, body, (jnp.int32(0), k))
        return s

    def update(tree, k, v):
        def body(_, state):
            tree, k = state
            valid = (k >= 1) & (k < tree_size)
            idx = jnp.where(valid, k, 0)
            tree = tree.at[idx].add(jnp.where(valid, v, 0))
            k = k + jnp.maximum(k & -k, 1)
            return tree, k

        tree, _ = jax.lax.fori_loop(0, levels, body, (tree, k))
        # tree[0] may have accumulated masked garbage-free zeros only.
        return tree

    def step(carry, x):
        tree, last_occ = carry
        i, a = x
        last = last_occ[a]
        # distinct ids at 0-indexed positions (last, i) exclusive
        #  == ones at 1-indexed positions [last+2, i] == Q(i) - Q(last+1)
        rd = query(tree, i) - query(tree, last + 1)
        rd = jnp.where(last < 0, jnp.int32(INF_RD), rd)
        tree = jax.lax.cond(
            last >= 0,
            lambda t: update(t, last + 1, jnp.int32(-1)),
            lambda t: t,
            tree,
        )
        tree = update(tree, i + 1, jnp.int32(1))
        last_occ = last_occ.at[a].set(i)
        return (tree, last_occ), rd

    tree0 = jnp.zeros((tree_size,), dtype=jnp.int32)
    last0 = jnp.full((n,), -1, dtype=jnp.int32)
    xs = (jnp.arange(n, dtype=jnp.int32), ids)
    (_, _), rds = jax.lax.scan(step, (tree0, last0), xs)
    return rds


def reuse_distances(addresses, line_size: int = 1, *,
                    method: str = "auto") -> np.ndarray:
    """Reuse distances of a trace, optionally at cache-line granularity.

    ``line_size > 1`` maps addresses to lines first (cache prediction
    operates on line reuse, paper §3.3.2).

    ``method`` selects the exact engine — all three are bit-identical:
    ``"scan"`` is the jitted Fenwick ``lax.scan`` (the §3.3.1 oracle),
    ``"offline"`` the vectorized order-statistics pass
    (:mod:`.batched`), and ``"auto"`` (default) routes traces larger
    than :data:`RD_OFFLINE_THRESHOLD` offline, where the monolithic
    scan's O(N)-per-step timeline copy collapses its throughput.
    """
    if method not in ("auto", "scan", "offline"):
        raise ValueError(f"unknown reuse-distance method: {method}")
    arr = np.asarray(addresses, dtype=np.int64)
    if arr.size == 0:
        return np.empty(0, dtype=np.int64)
    if line_size > 1:
        arr = arr // line_size
    if method == "offline" or (
        method == "auto" and arr.size >= RD_OFFLINE_THRESHOLD
    ):
        from .batched import reuse_distances_offline

        return reuse_distances_offline(arr)
    ids = compact_ids(arr)
    return np.asarray(_fenwick_rd_scan(jnp.asarray(ids)), dtype=np.int64)


def split_by_set(
    addresses, *, line_size: int, num_sets: int
) -> tuple[list[np.ndarray], np.ndarray]:
    """Stable per-set decomposition of a trace.

    Returns the per-set line-id segments (sets in ascending order,
    program order preserved within each set) and the stable sort
    ``order`` mapping concatenated segment positions back to original
    trace positions (``out[order] = concat(per_segment_results)``).
    Shared by the per-set distance paths and the profile benchmark so
    the decomposition can never drift between them.
    """
    arr = np.asarray(addresses, dtype=np.int64)
    lines = arr // line_size
    sets = lines % num_sets
    order = np.argsort(sets, kind="stable")
    cuts = np.flatnonzero(np.diff(sets[order])) + 1
    return np.split(lines[order], cuts), order


def per_set_reuse_distances(
    addresses, *, line_size: int, num_sets: int, method: str = "auto"
) -> np.ndarray:
    """Per-set reuse distances for set-associative LRU simulation.

    An access hits a ``A``-way set-associative LRU cache iff the number
    of *distinct same-set lines* touched since the last use of its line
    is < A.  The per-set subtraces are independent, which makes this
    the canonical batched workload:

    * ``method="monolithic"`` stably concatenates the subtraces and
      runs ONE global Fenwick scan (within the reordered trace, the
      window between two occurrences of a line contains only same-set
      accesses) — exact, but the O(N) timeline makes each scan step
      cost O(N) on XLA:CPU;
    * ``method="batched"`` hands each set's subtrace to
      :func:`repro.core.reuse.batched.reuse_distances_batched`, which
      scans whole shape buckets of sets in parallel per dispatch;
    * ``"auto"`` (default) uses the batched engine once the trace
      exceeds :data:`PER_SET_BATCH_THRESHOLD` references.

    All methods are bit-identical.
    """
    if method not in ("auto", "monolithic", "batched"):
        raise ValueError(f"unknown per-set method: {method}")
    arr = np.asarray(addresses, dtype=np.int64)
    if arr.size == 0:
        return np.empty(0, dtype=np.int64)
    if method == "batched" or (
        method == "auto"
        and num_sets > 1
        and arr.size >= PER_SET_BATCH_THRESHOLD
    ):
        from .batched import reuse_distances_batched

        segments, order = split_by_set(
            arr, line_size=line_size, num_sets=num_sets
        )
        rds = reuse_distances_batched(segments)
        out = np.empty(arr.size, dtype=np.int64)
        out[order] = np.concatenate(rds) if rds else np.empty(0, np.int64)
        return out
    lines = arr // line_size
    sets = lines % num_sets
    order = np.argsort(sets, kind="stable")
    ids = compact_ids(lines[order])
    rd_sorted = np.asarray(_fenwick_rd_scan(jnp.asarray(ids)), dtype=np.int64)
    out = np.empty_like(rd_sorted)
    out[order] = rd_sorted
    return out


# ---------------------------------------------------------------------------
# Streaming (checkpointed) Fenwick pass — peak memory O(window + working
# set), not O(N)  (ISSUE-2 tentpole; PARDA-style chunked scan).
# ---------------------------------------------------------------------------
#
# The in-memory pass above indexes its Fenwick tree by *absolute time*,
# so tree and last-occurrence buffers are O(N).  The streaming pass
# exploits the invariant that at any instant the tree holds exactly one
# 1 per distinct id (at its latest occurrence): reuse distances depend
# only on the *order* of those ones, not their absolute positions.  We
# therefore run the same scan over fixed-size windows appended to a
# bounded timeline, and when the timeline fills up we *compact* it —
# re-number the at-most-M live positions 0..M-1 in time order and
# rebuild the tree host-side in O(M).  Peak memory is O(timeline) =
# O(window + distinct lines), independent of trace length, and the
# emitted distances are bit-identical to the monolithic pass.
#
# The per-window scan carries ``(tree, last_slot)`` as donated jit
# buffers, so consecutive windows update device state in place instead
# of allocating fresh O(timeline) arrays each call.


class _IdMap:
    """Incremental address -> dense int32 id map (vectorized)."""

    def __init__(self):
        self._keys = np.empty(0, dtype=np.int64)   # sorted known addresses
        self._ids = np.empty(0, dtype=np.int32)    # id of each sorted key
        self.n = 0

    def map(self, keys: np.ndarray) -> np.ndarray:
        pos = np.searchsorted(self._keys, keys)
        hit = np.zeros(len(keys), dtype=bool)
        in_range = pos < self._keys.size
        hit[in_range] = self._keys[pos[in_range]] == keys[in_range]
        new = np.unique(keys[~hit])
        if new.size:
            ins = np.searchsorted(self._keys, new)
            self._keys = np.insert(self._keys, ins, new)
            self._ids = np.insert(
                self._ids, ins,
                np.arange(self.n, self.n + new.size, dtype=np.int32),
            )
            self.n += int(new.size)
            # Fix up the already-computed positions instead of re-running
            # a full searchsorted over all known keys: a key's index in
            # the merged array is its index among the old keys plus the
            # number of new keys sorting strictly before it — and for a
            # new key the 'left' search over ``new`` is exactly its own
            # insertion rank, so one small search covers both cases.
            pos = pos + np.searchsorted(new, keys)
        return self._ids[pos]


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


def _fenwick_from_ones_prefix(num_ones: int, cap: int) -> np.ndarray:
    """Fenwick tree over ``cap`` slots with 1s at 1-indexed 1..num_ones.

    O(cap) vectorized construction: tree[i] covers (i - lowbit(i), i],
    and the prefix count of a 1..m ones block is min(i, m).
    """
    idx = np.arange(cap, dtype=np.int64)
    low = idx & -idx
    tree = np.minimum(idx, num_ones) - np.minimum(idx - low, num_ones)
    tree[0] = 0
    return tree.astype(np.int32)


@functools.lru_cache(maxsize=None)
def _window_scan_fn(cap: int):
    """Jitted one-window Fenwick scan over a ``cap``-slot timeline.

    Cached per timeline capacity; ``tree`` and ``last_slot`` are donated
    so repeated windows reuse the same device buffers.  The step body is
    tuned for XLA:CPU scan throughput: both prefix queries run through
    ONE unrolled descent on a length-2 index vector, and the two point
    updates (+1 at the new position, -1 at the stale one) land in ONE
    2-element scatter-add per Fenwick level.
    """
    levels = _fenwick_levels(cap)

    def query2(tree, k2):
        # prefix sums at two 1-indexed positions simultaneously
        s2 = jnp.zeros((2,), dtype=jnp.int32)
        for _ in range(levels):
            valid = k2 > 0
            s2 = s2 + jnp.where(valid, tree[jnp.maximum(k2, 0)], 0)
            k2 = jnp.where(valid, k2 - (k2 & -k2), k2)
        return s2

    def update2(tree, k2, v2):
        # climb both update paths together; masked lanes write 0 to
        # tree[0], which query2 never reads
        for _ in range(levels):
            valid = (k2 >= 1) & (k2 < cap)
            idx = jnp.where(valid, k2, 0)
            tree = tree.at[idx].add(jnp.where(valid, v2, 0))
            k2 = k2 + jnp.maximum(k2 & -k2, 1)
        return tree

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def run(tree, last_slot, ids, base_slot):
        def step(carry, x):
            tree, last_slot = carry
            j, a = x
            slot = base_slot + j
            last = last_slot[a]
            q = query2(tree, jnp.stack([slot, last + 1]))
            rd = jnp.where(last < 0, jnp.int32(INF_RD), q[0] - q[1])
            seen = last >= 0
            k2 = jnp.stack([slot + 1, jnp.where(seen, last + 1, 0)])
            v2 = jnp.stack(
                [jnp.int32(1), jnp.where(seen, jnp.int32(-1), 0)]
            )
            tree = update2(tree, k2, v2)
            last_slot = last_slot.at[a].set(slot)
            return (tree, last_slot), rd

        n = ids.shape[0]
        xs = (jnp.arange(n, dtype=jnp.int32), ids)
        (tree, last_slot), rds = jax.lax.scan(step, (tree, last_slot), xs)
        return tree, last_slot, rds

    return run


def iter_address_windows(
    source, *, window_size: int = DEFAULT_WINDOW, line_size: int = 1
) -> Iterator[np.ndarray]:
    """Normalize any trace-like input into int64 line-id windows.

    Accepts a ``ChunkedTraceSource`` (anything with ``.windows()``,
    including ``LabeledTrace``), a flat address array, or an iterable of
    already-windowed pieces (``LabeledTrace`` windows or arrays).
    """
    if hasattr(source, "windows"):
        pieces: Iterable = source.windows(window_size)
    elif isinstance(source, np.ndarray) or (
        isinstance(source, (list, tuple))
        and (
            len(source) == 0
            or (
                not hasattr(source[0], "addresses")
                and np.ndim(source[0]) == 0
            )
        )
    ):
        arr = np.asarray(source, dtype=np.int64)
        pieces = (
            arr[i: i + window_size] for i in range(0, arr.size, window_size)
        )
    else:  # an iterator/iterable of windows
        pieces = source
    for piece in pieces:
        a = piece.addresses if hasattr(piece, "addresses") else piece
        a = np.asarray(a, dtype=np.int64)
        if line_size > 1:
            a = a // line_size
        yield a


def reuse_distance_windows(
    source,
    line_size: int = 1,
    *,
    window_size: int = DEFAULT_WINDOW,
) -> Iterator[np.ndarray]:
    """Yield per-window reuse distances of a (possibly huge) trace.

    Bit-identical, window-by-window, to ``reuse_distances`` over the
    concatenated trace; peak memory is O(window + distinct lines).  Feed
    the windows to ``profile_from_distances_incremental`` to build a
    :class:`ReuseProfile` without ever materializing the O(N) distance
    array.
    """
    for rds in reuse_distance_windows_device(
        source, line_size, window_size=window_size
    ):
        yield np.asarray(rds, dtype=np.int64)


def reuse_distance_windows_device(
    source,
    line_size: int = 1,
    *,
    window_size: int = DEFAULT_WINDOW,
) -> Iterator[jnp.ndarray]:
    """Device-resident variant of :func:`reuse_distance_windows`.

    Yields each window's distances as the int32 device array the
    Fenwick scan produced — the fused profile path
    (:mod:`repro.core.reuse.fused`) feeds these straight into the
    ``kernels/reuse_hist`` histogram, so a streaming profile build
    never materializes distances host-side.
    """
    if window_size < 1:
        raise ValueError("window_size must be >= 1")
    idmap = _IdMap()
    last_time = np.empty(0, dtype=np.int64)  # per id: last global position
    tree = last_slot = None
    cap = id_cap = 0
    base_slot = 0
    global_pos = 0

    for awin in iter_address_windows(
        source, window_size=window_size, line_size=line_size
    ):
        w = int(awin.size)
        if w == 0:
            yield jnp.empty(0, dtype=jnp.int32)
            continue
        ids = idmap.map(awin)
        n_ids = idmap.n
        if n_ids > last_time.size:
            grown = np.full(_pow2(n_ids), -1, dtype=np.int64)
            grown[: last_time.size] = last_time
            last_time = grown
        if last_slot is not None and n_ids > id_cap:
            id_cap = _pow2(n_ids)
            pad = id_cap - last_slot.shape[0]
            last_slot = jnp.concatenate(
                [last_slot, jnp.full(pad, -1, dtype=jnp.int32)]
            )
        if tree is None or base_slot + w + 2 > cap:
            # compact: live ones renumbered 0..m-1 in time order
            seen = np.flatnonzero(last_time[:n_ids] >= 0)
            order = seen[np.argsort(last_time[seen], kind="stable")]
            m = int(order.size)
            # room for >= 2 windows past the compacted prefix, so a
            # near-full working set doesn't force per-window rebuilds
            cap = max(cap, _pow2(max(m + 2 * w + 2, 4 * window_size)))
            id_cap = max(id_cap, _pow2(n_ids))
            ls = np.full(id_cap, -1, dtype=np.int32)
            ls[order] = np.arange(m, dtype=np.int32)
            tree = jnp.asarray(_fenwick_from_ones_prefix(m, cap))
            last_slot = jnp.asarray(ls)
            base_slot = m
        run = _window_scan_fn(cap)
        tree, last_slot, rds = run(
            tree, last_slot, jnp.asarray(ids), jnp.int32(base_slot)
        )
        # host-side checkpoint: last occurrence position of each id
        rev_ids, rev_idx = np.unique(ids[::-1], return_index=True)
        last_time[rev_ids] = global_pos + (w - 1 - rev_idx)
        base_slot += w
        global_pos += w
        yield rds


def reuse_distances_streaming(
    source,
    line_size: int = 1,
    *,
    window_size: int = DEFAULT_WINDOW,
) -> np.ndarray:
    """Streaming counterpart of :func:`reuse_distances`.

    Materializes only the output; the scan state is bounded by the
    window and the working set.  Bit-identical to the in-memory pass for
    every window size (enforced by tests).
    """
    parts = list(
        reuse_distance_windows(source, line_size, window_size=window_size)
    )
    if not parts:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(parts)
