"""Reuse (LRU stack) distance computation — paper §2.3 / §3.3.1.

The paper replaces the O(N·M) stack algorithm with a tree-based
O(N·log M) method [Niu et al., PARDA].  We implement the tree as a
Fenwick (binary-indexed) tree carried through a ``jax.lax.scan`` so the
whole pass is a single XLA program: O(N·log N) work, O(N) memory.

Conventions
-----------
* A reuse distance of ``INF_RD`` (= -1 sentinel) marks a first-touch
  (compulsory) access, the paper's ``D = ∞``.
* Distances are measured in *distinct elements* (addresses or cache
  lines) accessed strictly between two uses of the same element
  (Table 1 of the paper).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

INF_RD: int = -1


# ---------------------------------------------------------------------------
# Reference oracle: classic O(N·M) LRU stack (paper's "conventional" method).
# ---------------------------------------------------------------------------

def reuse_distances_ref(addresses) -> np.ndarray:
    """O(N·M) LRU-stack reuse distances.  Ground-truth oracle for tests.

    Reproduces Table 1 of the paper exactly (first touch -> INF_RD).
    """
    stack: list = []  # stack[0] is most-recently-used
    out = np.empty(len(addresses), dtype=np.int64)
    for t, a in enumerate(addresses):
        try:
            d = stack.index(a)
            out[t] = d
            stack.pop(d)
        except ValueError:
            out[t] = INF_RD
        stack.insert(0, a)
    return out


# ---------------------------------------------------------------------------
# Tree-based O(N log N) method as a single lax.scan (paper §3.3.1).
# ---------------------------------------------------------------------------

def compact_ids(addresses) -> np.ndarray:
    """Map arbitrary (possibly 64-bit) addresses to dense int32 ids."""
    arr = np.asarray(addresses)
    _, inv = np.unique(arr, return_inverse=True)
    return inv.astype(np.int32)


def _fenwick_levels(n: int) -> int:
    """Number of Fenwick iterations needed for a tree of n slots."""
    return max(1, int(n).bit_length())


@jax.jit
def _fenwick_rd_scan(ids: jnp.ndarray) -> jnp.ndarray:
    """Reuse distances over dense ids via a Fenwick tree in a lax.scan.

    The Fenwick tree stores a 1 at the (1-indexed) position of the
    *latest* occurrence of every id seen so far; the number of distinct
    ids touched in an open window (last, i) is then a prefix-sum
    difference — the Bennett–Kruskal formulation used by tree-based RD
    algorithms.
    """
    n = ids.shape[0]
    tree_size = n + 2
    levels = _fenwick_levels(tree_size)

    def query(tree, k):
        # prefix sum over 1-indexed positions 1..k; tree[0] is always 0.
        def body(_, state):
            s, k = state
            valid = k > 0
            s = s + jnp.where(valid, tree[jnp.maximum(k, 0)], 0)
            k = jnp.where(valid, k - (k & -k), k)
            return s, k

        s, _ = jax.lax.fori_loop(0, levels, body, (jnp.int32(0), k))
        return s

    def update(tree, k, v):
        def body(_, state):
            tree, k = state
            valid = (k >= 1) & (k < tree_size)
            idx = jnp.where(valid, k, 0)
            tree = tree.at[idx].add(jnp.where(valid, v, 0))
            k = k + jnp.maximum(k & -k, 1)
            return tree, k

        tree, _ = jax.lax.fori_loop(0, levels, body, (tree, k))
        # tree[0] may have accumulated masked garbage-free zeros only.
        return tree

    def step(carry, x):
        tree, last_occ = carry
        i, a = x
        last = last_occ[a]
        # distinct ids at 0-indexed positions (last, i) exclusive
        #  == ones at 1-indexed positions [last+2, i] == Q(i) - Q(last+1)
        rd = query(tree, i) - query(tree, last + 1)
        rd = jnp.where(last < 0, jnp.int32(INF_RD), rd)
        tree = jax.lax.cond(
            last >= 0,
            lambda t: update(t, last + 1, jnp.int32(-1)),
            lambda t: t,
            tree,
        )
        tree = update(tree, i + 1, jnp.int32(1))
        last_occ = last_occ.at[a].set(i)
        return (tree, last_occ), rd

    tree0 = jnp.zeros((tree_size,), dtype=jnp.int32)
    last0 = jnp.full((n,), -1, dtype=jnp.int32)
    xs = (jnp.arange(n, dtype=jnp.int32), ids)
    (_, _), rds = jax.lax.scan(step, (tree0, last0), xs)
    return rds


def reuse_distances(addresses, line_size: int = 1) -> np.ndarray:
    """Reuse distances of a trace, optionally at cache-line granularity.

    ``line_size > 1`` maps addresses to lines first (cache prediction
    operates on line reuse, paper §3.3.2).
    """
    arr = np.asarray(addresses, dtype=np.int64)
    if arr.size == 0:
        return np.empty(0, dtype=np.int64)
    if line_size > 1:
        arr = arr // line_size
    ids = compact_ids(arr)
    return np.asarray(_fenwick_rd_scan(jnp.asarray(ids)), dtype=np.int64)


def per_set_reuse_distances(
    addresses, *, line_size: int, num_sets: int
) -> np.ndarray:
    """Per-set reuse distances for set-associative LRU simulation.

    An access hits a ``A``-way set-associative LRU cache iff the number
    of *distinct same-set lines* touched since the last use of its line
    is < A.  We compute this exactly in one Fenwick pass by stably
    concatenating the per-set subtraces: within the reordered trace, the
    window between two occurrences of a line contains only same-set
    accesses, so the global scan yields the per-set distances.
    """
    arr = np.asarray(addresses, dtype=np.int64)
    if arr.size == 0:
        return np.empty(0, dtype=np.int64)
    lines = arr // line_size
    sets = lines % num_sets
    order = np.argsort(sets, kind="stable")
    ids = compact_ids(lines[order])
    rd_sorted = np.asarray(_fenwick_rd_scan(jnp.asarray(ids)), dtype=np.int64)
    out = np.empty_like(rd_sorted)
    out[order] = rd_sorted
    return out


def reuse_distances_sampled(
    addresses, line_size: int = 1, *, rate: float = 0.1,
    max_window: int = 100_000, seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Sampled exact reuse distances — the Schuff/Chennupati accelerator
    (beyond-paper §Perf on the paper's own hot spot).

    A random ``rate`` fraction of references get their RD computed
    exactly as the distinct-line count of their reuse window (np.unique
    — vectorized, no sequential Fenwick pass).  Windows longer than
    ``max_window`` saturate to ``max_window`` distinct lines (they miss
    every practical cache anyway).  Returns (distances, weights): each
    sampled distance represents 1/rate references — feed both to
    ``profile_from_pairs`` after aggregation, or directly to
    ``ReuseProfile`` via np.unique.
    """
    arr = np.asarray(addresses, dtype=np.int64) // line_size
    n = arr.size
    if n == 0:
        return np.empty(0, np.int64), np.empty(0, np.float64)
    # previous-occurrence index per reference
    last: dict[int, int] = {}
    prev = np.full(n, -1, np.int64)
    # vectorized prev via argsort-groupby
    order = np.argsort(arr, kind="stable")
    sorted_vals = arr[order]
    same = np.empty(n, bool)
    same[0] = False
    same[1:] = sorted_vals[1:] == sorted_vals[:-1]
    prev_sorted = np.where(same, np.concatenate([[0], order[:-1]]), -1)
    prev[order] = prev_sorted

    rng = np.random.default_rng(seed)
    k = max(1, int(n * rate))
    sample = np.sort(rng.choice(n, size=k, replace=False))
    dists = np.empty(k, np.int64)
    for i, idx in enumerate(sample):
        j = prev[idx]
        if j < 0:
            dists[i] = -1  # infinity marker (cold miss)
            continue
        window = arr[j + 1: idx]
        if window.size > max_window:
            dists[i] = max_window
        else:
            dists[i] = np.unique(window).size
    weights = np.full(k, n / k, np.float64)
    return dists, weights
