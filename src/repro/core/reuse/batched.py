"""Batched multi-segment reuse-distance engines (ISSUE-5 tentpole).

The monolithic Fenwick scan in :mod:`.distance` processes ONE trace at
~30-60k refs/s on XLA:CPU — one sequential ``lax.scan`` step per
reference, each step carrying the whole timeline by value.  But the
pipeline is full of *independent* segments whose scans never interact:

* the per-set subtraces of ``per_set_reuse_distances`` (one segment per
  cache set — the exact-LRU simulator's dominant cost);
* the per-core mimicked traces a ``Session.artifacts`` sweep builds one
  at a time;
* the validation runner's workload x strategy matrix.

:func:`reuse_distances_batched` scans many segments **in parallel in
one dispatch**, choosing between two exact engines per shape bucket:

``fenwick``
    A vmapped multi-segment Fenwick scan (PARDA-style independent-chunk
    parallelism): segments are padded into pow2 ``(timeline cap, row
    count)`` shape buckets — one cached jit per bucket, the same trick
    as :mod:`repro.api.batched`'s per-row grouping — and advance window
    by window with donated ``(tree, last_slot)`` carries, so a scan
    step retires one reference of EVERY segment at once.  The step body
    unrolls ``_BLOCK`` references per ``lax.scan`` step to amortize the
    carry copy XLA:CPU performs at scan-step boundaries.  Timelines are
    compacted host-side (live positions renumbered in time order, the
    streaming scan's invariant) whenever the window would overflow the
    bucket cap, so the device state stays O(working set + window) per
    segment.  This is the engine that compiles natively on TPU, where
    the distances stay device-resident for the fused
    ``kernels/reuse_hist`` histogram.

``offline``
    A fully vectorized host pass with no sequential scan at all, via
    the order-statistics identity

        rd[t] = #{s < t : prev[s] <= prev[t]} - prev[t] - 1

    (prev = previous occurrence of the same line, -1 for first touch;
    the second term of the 2D dominance count collapses because
    ``prev[s] < s`` always).  The count-smaller-before-self term is
    computed by a bottom-up vectorized mergesort — log2(N) rounds of
    ``np.searchsorted`` over composite (pair, value) keys — giving a
    flat O(N log^2 N) pass at >300k refs/s for 1M references,
    independent of the working-set size.  Because ``prev`` offsets
    cancel per segment, any number of segments evaluate in ONE pass
    over their stable concatenation.

Both engines are bit-identical, segment by segment, to the monolithic
oracle (property-tested in ``tests/core/test_batched_rd.py``).
``engine="auto"`` picks ``fenwick`` for wide buckets of small-timeline
segments (per-set shapes) — and always on TPU backends — and
``offline`` for narrow buckets of long segments, where a CPU scan is
dispatch-bound.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .distance import INF_RD, _fenwick_levels, compact_ids

__all__ = [
    "reuse_distances_batched",
    "reuse_distances_offline",
    "count_leq_before",
]

# Window of references each vmapped dispatch advances every segment by.
# Small on purpose: the timeline cap is m + 2*window + 2, and the scan's
# per-step carry copy scales with the cap — wide-and-shallow dispatches
# (many rows, short windows) are the measured CPU sweet spot.
DEFAULT_SEGMENT_WINDOW = 512

# References retired per lax.scan step (unrolled): XLA:CPU copies the
# (rows, cap) carry at every scan-step boundary, so the copy is paid
# once per _BLOCK references instead of once per reference.
_BLOCK = 8

# engine="auto" routes a bucket to the fenwick engine on CPU only when
# the dispatch is wide enough to amortize per-step overhead and the
# timeline cap keeps the per-step carry copy small (measured: >=3x the
# sequential streaming scan in that regime, slower outside it).
_FENWICK_MIN_ROWS = 128
_FENWICK_MAX_CAP = 4096


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


# ---------------------------------------------------------------------------
# Offline engine: vectorized order-statistics pass (no sequential scan).
# ---------------------------------------------------------------------------


def _prev_occurrence(keys: np.ndarray) -> np.ndarray:
    """Index of the previous occurrence of each key (-1 = first touch)."""
    n = keys.size
    order = np.argsort(keys, kind="stable")
    sv = keys[order]
    same = np.empty(n, dtype=bool)
    if n:
        same[0] = False
        same[1:] = sv[1:] == sv[:-1]
    prev = np.empty(n, dtype=np.int64)
    prev[order] = np.where(same, np.concatenate(([0], order[:-1])), -1)
    return prev


def count_leq_before(
    values: np.ndarray, *, num_shards: int | None = None
) -> np.ndarray:
    """A[t] = #{s < t : values[s] <= values[t]}, fully vectorized.

    Bottom-up mergesort: at each level, blocks of width ``w`` are sorted
    by value (stable in the original index); every right-block element
    counts its left-block peers via one ``np.searchsorted`` over
    composite ``pair * stride + value`` keys, and the merged order is
    rebuilt from searchsorted ranks (no per-level argsort).  O(N log^2 N)
    comparisons, all inside numpy kernels.

    ``num_shards > 1`` decomposes the count into that many contiguous
    chunks: each chunk's *within*-chunk counts are an independent
    mergesort pass (parallelizable across devices/workers), and the
    *cross*-chunk contribution is one ``np.searchsorted`` of the chunk
    against the sorted prefix of all earlier chunks.  The decomposition
    is an exact integer identity — bit-identical to the monolithic pass
    for every shard count (property-tested).
    """
    p = np.asarray(values, dtype=np.int64)
    n = p.size
    if n == 0:
        return np.empty(0, dtype=np.int64)
    if num_shards is not None and num_shards > 1 and n > 1:
        shards = min(int(num_shards), n)
        bounds = np.linspace(0, n, shards + 1).astype(np.int64)
        out = np.empty(n, dtype=np.int64)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            # independent per-chunk pass + one merge-step correction
            out[lo:hi] = count_leq_before(p[lo:hi])
            if lo:
                prefix = np.sort(p[:lo], kind="stable")
                out[lo:hi] += np.searchsorted(prefix, p[lo:hi],
                                              side="right")
        return out
    if n >= (1 << 31):  # composite pair*stride keys would overflow int64
        raise ValueError("count_leq_before supports < 2^31 elements")
    out = np.zeros(n, dtype=np.int64)
    stride = np.int64(n + 2)
    # every value must fit under the composite-key stride; prev arrays
    # (the hot path) are already in [-1, n) and skip the compression
    if -1 <= int(p.min()) and int(p.max()) < n:
        vals = p + 1
    else:  # rank-compress, order-preserving (ties share a rank)
        _, vals = np.unique(p, return_inverse=True)
        vals = vals.astype(np.int64) + 1
    idx = np.arange(n, dtype=np.int64)  # block-sorted original indices
    width = 1
    while width < n:
        pair = idx // (2 * width)
        is_right = ((idx // width) & 1).astype(bool)
        v = vals[idx]
        left_pair = pair[~is_right]          # ascending (blocks in order)
        comp_left = left_pair * stride + v[~is_right]
        starts = np.searchsorted(left_pair, pair)
        # right elements: count left peers with value <= theirs (ties
        # count — the predicate is <=, and left indices precede right)
        q_right = pair[is_right] * stride + v[is_right]
        cnt = np.searchsorted(comp_left, q_right, side="right")
        cnt -= starts[is_right]
        out[idx[is_right]] += cnt
        # merge: left rank i goes to i + #right strictly smaller (ties
        # keep the left/lower-index element first); right rank j goes to
        # j + cnt (its <= count).  Ranks are local to each pair block.
        right_pair = pair[is_right]
        comp_right = q_right
        rstarts = np.searchsorted(right_pair, pair)
        cnt_l = np.searchsorted(comp_right, pair[~is_right] * stride
                                + v[~is_right], side="left")
        cnt_l -= rstarts[~is_right]
        # local rank within the sorted block = position - block start in
        # the idx ordering; blocks are contiguous runs of length width
        pos = np.arange(n, dtype=np.int64)
        block_start = (pos // width) * width
        local_rank = pos - block_start
        pair_base = pair * (2 * width)
        new_pos = np.empty(n, dtype=np.int64)
        new_pos[~is_right] = (pair_base[~is_right] + local_rank[~is_right]
                              + cnt_l)
        new_pos[is_right] = (pair_base[is_right] + local_rank[is_right]
                             + cnt)
        merged = np.empty(n, dtype=np.int64)
        merged[new_pos] = idx  # a permutation: stable merge per pair
        idx = merged
        width *= 2
    return out


def reuse_distances_offline(
    keys: np.ndarray, *, num_shards: int | None = None
) -> np.ndarray:
    """Exact reuse distances of one key sequence, no sequential scan.

    ``rd[t] = #{s < t : prev[s] <= prev[t]} - prev[t] - 1`` — every
    earlier position with an earlier-or-equal previous occurrence is
    either a distinct line in the reuse window or accounted for by the
    ``prev[t] + 1`` correction.  Bit-identical to the Fenwick scan.
    ``num_shards`` chunk-parallelizes the dominance count (see
    :func:`count_leq_before`).
    """
    keys = np.asarray(keys)
    if keys.size == 0:
        return np.empty(0, dtype=np.int64)
    prev = _prev_occurrence(keys)
    rd = count_leq_before(prev, num_shards=num_shards) - prev - 1
    return np.where(prev < 0, np.int64(INF_RD), rd)


def _offline_segments(
    seg_ids: list[np.ndarray], num_shards: int | None = None
) -> list[np.ndarray]:
    """All segments in ONE offline pass over their stable concatenation.

    Takes the segments' already-densified ids (``compact_ids`` output —
    computed once per segment by the caller for bucket sizing) and
    keys them per segment via composite ``segment * stride + id``.
    ``prev`` offsets cancel per segment: every reference of an earlier
    segment has ``prev < segment offset <= prev[t]`` for any finite-rd
    ``t``, so the dominance count picks up exactly the offset that the
    ``prev[t] + 1`` term subtracts back out.
    """
    lens = [len(s) for s in seg_ids]
    if sum(lens) == 0:
        return [np.empty(0, dtype=np.int64) for _ in seg_ids]
    flat = np.concatenate([s.astype(np.int64) for s in seg_ids])
    stride = np.int64(max(int(s.max()) for s in seg_ids if s.size) + 1)
    seg = np.repeat(np.arange(len(seg_ids), dtype=np.int64), lens)
    rd = reuse_distances_offline(seg * stride + flat, num_shards=num_shards)
    out = []
    off = 0
    for ln in lens:
        out.append(rd[off:off + ln])
        off += ln
    return out


# ---------------------------------------------------------------------------
# Fenwick engine: vmapped multi-segment windowed scan.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _multi_scan_fn(cap: int, block: int):
    """Jitted one-window scan advancing every row's Fenwick state.

    One compilation per (timeline cap, unroll block) — row count and
    window width specialize through jit's own shape cache.  ``tree`` and
    ``last_slot`` are donated carries: consecutive windows update the
    same device buffers in place.
    """
    levels = _fenwick_levels(cap)

    def one(tree, last_slot, ids, valid, base):
        def query2(tree, k2):
            s2 = jnp.zeros((2,), dtype=jnp.int32)
            for _ in range(levels):
                ok = k2 > 0
                s2 = s2 + jnp.where(ok, tree[jnp.maximum(k2, 0)], 0)
                k2 = jnp.where(ok, k2 - (k2 & -k2), k2)
            return s2

        def update2(tree, k2, v2):
            for _ in range(levels):
                ok = (k2 >= 1) & (k2 < cap)
                pos = jnp.where(ok, k2, 0)
                tree = tree.at[pos].add(jnp.where(ok, v2, 0))
                k2 = k2 + jnp.maximum(k2 & -k2, 1)
            return tree

        def substep(tree, last_slot, slot, a, m):
            last = last_slot[a]
            q = query2(tree, jnp.stack([slot, last + 1]))
            rd = jnp.where(last < 0, jnp.int32(INF_RD), q[0] - q[1])
            rd = jnp.where(m, rd, jnp.int32(INF_RD))
            seen = (last >= 0) & m
            k2 = jnp.stack([slot + 1, jnp.where(seen, last + 1, 0)])
            v2 = jnp.stack([jnp.where(m, jnp.int32(1), 0),
                            jnp.where(seen, jnp.int32(-1), 0)])
            tree = update2(tree, k2, v2)
            last_slot = last_slot.at[a].set(jnp.where(m, slot, last))
            return tree, last_slot, rd

        def step(carry, x):
            tree, last_slot = carry
            j_blk, a_blk, m_blk = x
            rds = []
            for b in range(block):  # unrolled: one carry copy per block
                tree, last_slot, rd = substep(
                    tree, last_slot, base + j_blk[b], a_blk[b], m_blk[b]
                )
                rds.append(rd)
            return (tree, last_slot), jnp.stack(rds)

        w = ids.shape[0]
        xs = (
            jnp.arange(w, dtype=jnp.int32).reshape(-1, block),
            ids.reshape(-1, block),
            valid.reshape(-1, block),
        )
        (tree, last_slot), rds = jax.lax.scan(step, (tree, last_slot), xs)
        return tree, last_slot, rds.reshape(-1)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def run(tree, last_slot, ids, valid, base):
        return jax.vmap(one, in_axes=(0, 0, 0, 0, 0))(
            tree, last_slot, ids, valid, base
        )

    return run


def _fenwick_rows_from_prefix(num_ones: np.ndarray, cap: int) -> np.ndarray:
    """Per-row Fenwick trees with ones at 1-indexed 1..num_ones[k]."""
    idx = np.arange(cap, dtype=np.int64)
    low = idx & -idx
    m = num_ones.astype(np.int64)[:, None]
    tree = np.minimum(idx, m) - np.minimum(idx - low, m)
    tree[:, 0] = 0
    return tree.astype(np.int32)


def _fenwick_bucket(seg_ids: list[np.ndarray], cap: int, window: int,
                    sink) -> None:
    """Scan one shape bucket of segments window by window.

    ``sink(row, lo, rds_row, count)`` receives each row's distances for
    window positions [lo, lo+count) (one device->host transfer per
    window, sliced per row).
    """
    k = len(seg_ids)
    kp = _pow2(k)
    lens = np.array([len(s) for s in seg_ids] + [0] * (kp - k),
                    dtype=np.int64)
    lmax = int(lens.max())
    w = min(_pow2(lmax), window)
    w = max(_BLOCK, (w + _BLOCK - 1) // _BLOCK * _BLOCK)
    run = _multi_scan_fn(cap, _BLOCK)

    last_time = np.full((kp, cap), -1, dtype=np.int64)
    base = np.zeros(kp, dtype=np.int32)
    tree = last_slot = None
    gpos = 0
    ids_win = np.zeros((kp, w), dtype=np.int32)
    valid_win = np.zeros((kp, w), dtype=bool)

    for lo in range(0, lmax, w):
        ids_win[:] = 0
        valid_win[:] = False
        for r in range(k):
            cnt = min(max(int(lens[r]) - lo, 0), w)
            if cnt:
                ids_win[r, :cnt] = seg_ids[r][lo:lo + cnt]
                valid_win[r, :cnt] = True
        if tree is None or int(base.max()) + w + 2 > cap:
            # compact: live ids renumbered 0..m-1 in last-touch order
            live = last_time >= 0
            keys = np.where(live, last_time, np.iinfo(np.int64).max)
            ranks = np.argsort(np.argsort(keys, axis=1), axis=1)
            m = live.sum(axis=1).astype(np.int32)
            tree = jnp.asarray(_fenwick_rows_from_prefix(m, cap))
            last_slot = jnp.asarray(
                np.where(live, ranks, -1).astype(np.int32)
            )
            base = m.copy()
        tree, last_slot, rds = run(
            tree, last_slot, jnp.asarray(ids_win), jnp.asarray(valid_win),
            jnp.asarray(base),
        )
        rds_host = np.asarray(rds)
        for r in range(k):
            cnt = min(max(int(lens[r]) - lo, 0), w)
            if cnt:
                sink(r, lo, rds_host[r], cnt)
        # host checkpoint: latest touch position per (row, id)
        flat_idx = (np.arange(kp)[:, None] * cap + ids_win).ravel()
        flat_pos = (gpos + np.arange(w))[None, :].repeat(kp, axis=0).ravel()
        sel = valid_win.ravel()
        fi, fp = flat_idx[sel][::-1], flat_pos[sel][::-1]
        uniq, first = np.unique(fi, return_index=True)
        last_time.ravel()[uniq] = fp[first]
        base = base + np.int32(w)
        gpos += w


# ---------------------------------------------------------------------------
# Public entry: engine selection + shape bucketing.
# ---------------------------------------------------------------------------


def _as_lines(segment, line_size: int) -> np.ndarray:
    arr = getattr(segment, "addresses", segment)
    arr = np.asarray(arr, dtype=np.int64)
    return arr // line_size if line_size > 1 else arr


def _bucket_key(n: int, m: int, window: int) -> tuple[int, int, int]:
    """(timeline cap, window, pow2 window count) for one segment."""
    w = min(_pow2(max(n, 1)), window)
    w = max(_BLOCK, (w + _BLOCK - 1) // _BLOCK * _BLOCK)
    cap = _pow2(max(m + 2 * w + 2, 4))
    return cap, w, _pow2(max(-(-n // w), 1))


def reuse_distances_batched(
    segments,
    line_size: int = 1,
    *,
    engine: str = "auto",
    window: int = DEFAULT_SEGMENT_WINDOW,
    num_shards: int | None = None,
) -> list[np.ndarray]:
    """Exact reuse distances of many independent segments, batched.

    Each segment (an address array or anything with ``.addresses``) is
    scanned as if alone — the result is bit-identical, per segment, to
    ``reuse_distances(segment, line_size)`` — but segments are grouped
    into pow2 shape buckets and each bucket is evaluated in parallel:
    one vmapped Fenwick dispatch per window (``engine="fenwick"``) or
    one vectorized offline pass (``engine="offline"``).  ``"auto"``
    picks per bucket (see module docstring).

    ``num_shards`` (default: the local device count, via
    ``repro.dist.sharding.local_shard_count``) splits the work into
    that many independent pieces: segments are LPT-partitioned across
    shards (``repro.dist.sharding.partition_segments``) and each
    shard's group evaluates separately; a lone oversized segment
    instead chunk-parallelizes its offline dominance count.  The merge
    is a scatter by original segment index, so results are
    bit-identical to the monolithic pass for every shard count.
    """
    if engine not in ("auto", "fenwick", "offline"):
        raise ValueError(f"unknown batched RD engine: {engine}")
    from repro.dist.sharding import local_shard_count, partition_segments

    shards = (local_shard_count() if num_shards is None
              else max(int(num_shards), 1))
    segs = [_as_lines(s, line_size) for s in segments]
    out: list[np.ndarray | None] = [None] * len(segs)

    for i, s in enumerate(segs):
        if s.size == 0:
            out[i] = np.empty(0, dtype=np.int64)

    todo = [i for i, o in enumerate(out) if o is None]
    if not todo:
        return out  # type: ignore[return-value]

    if shards > 1 and len(todo) > 1:
        # deterministic LPT split; each group is an independent batched
        # pass (the unit a multi-device dispatch hands one device), and
        # the merge is a pure scatter by original index
        groups = partition_segments([segs[i].size for i in todo], shards)
        for group in groups:
            if not group:
                continue
            sub = reuse_distances_batched(
                [segs[todo[j]] for j in group],
                engine=engine, window=window, num_shards=1,
            )
            for j, rd in zip(group, sub):
                out[todo[j]] = rd
        return out  # type: ignore[return-value]

    ids = {i: compact_ids(segs[i]) for i in todo}
    buckets: dict[tuple[int, int, int], list[int]] = {}
    for i in todo:
        m = int(ids[i].max()) + 1
        buckets.setdefault(_bucket_key(len(ids[i]), m, window), []).append(i)

    on_tpu = jax.default_backend() == "tpu"
    for (cap, w, _), idxs in buckets.items():
        use_fenwick = engine == "fenwick" or (
            engine == "auto"
            and (on_tpu or (_pow2(len(idxs)) >= _FENWICK_MIN_ROWS
                            and cap <= _FENWICK_MAX_CAP))
        )
        if not use_fenwick:
            # shards > 1 here means a single oversized segment (the
            # multi-segment case already split above): parallelize its
            # dominance count instead
            count_shards = shards if shards > 1 else None
            for i, rd in zip(
                idxs,
                _offline_segments([ids[i] for i in idxs],
                                  num_shards=count_shards),
            ):
                out[i] = rd
            continue
        for i in idxs:
            out[i] = np.empty(len(ids[i]), dtype=np.int64)

        def sink(r, lo, rds_row, cnt, idxs=idxs):
            out[idxs[r]][lo:lo + cnt] = rds_row[:cnt]

        _fenwick_bucket([ids[i] for i in idxs], cap, w, sink)
    return out  # type: ignore[return-value]
