"""SHARDS-style sampled reuse profiles — constant memory, bounded error.

The exact engines (`distance.py`, `batched.py`) compute every reuse
distance; this module computes an *estimate* of the reuse profile from a
spatially-hashed sample of the trace, the SHARDS construction (Waldspurger
et al., FAST'15) adapted to the repo's profile/SDCM pipeline:

1. **Spatial sampling.**  A cache line is *sampled* iff a deterministic
   64-bit hash of its line id (keyed by ``seed``) falls below
   ``rate * 2**64``.  Every reference to a sampled line is kept, every
   reference to an unsampled line dropped — so the sampled subtrace
   preserves the full reuse structure *of the sampled lines*.
2. **Exact distances on the subtrace.**  Reuse distances of the sampled
   subtrace are computed with the exact engines.  Because each distinct
   line in any reuse window is kept independently with probability R,
   the measured distance ``d`` is a binomial thinning of the true
   distance ``D``: ``d ~ Binomial(D, R)``, so ``d / R`` is an unbiased
   estimator of ``D``.
3. **Rescaling.**  Finite distances rescale ``d -> round(d / R)``;
   counts rescale ``c -> round(c / R)`` (each sampled reference stands
   for ``1/R`` references).  ``INF_RD`` (cold-miss) mass keeps its
   distance and rescales its count only.

At ``rate == 1.0`` every line is sampled and rescaling is skipped
entirely, so the result is bit-identical to the exact pass (property-
tested).  Sampling is deterministic per ``(seed, rate)``.

**Error bound.**  Spatial sampling keeps or drops every reference to a
line *together*, so the profile estimate is a cluster (per-line) sample:
its variance is governed by the line masses ``w_l`` (references per
line), not the raw reference count.  The declared per-profile bound is a
Bernstein sup-norm bound on the Horvitz-Thompson estimate of the
reuse-distance CDF, with ``L = ln(2 (n+1) / SAMPLE_BOUND_DELTA)`` (the
``n+1`` union-bounds over every CDF threshold)::

    V        = (1 - R) * sum_l w_l^2 / (R * n^2)     # exact HT variance
    eps      = sqrt(2 V L) + w_max L / (3 R n)
    bound(R) = min(1, eps * n / S_hat + |n - S_hat| / S_hat)

where ``S_hat = kept_refs / R`` is the sample's own mass estimate.  The
line-mass moments ``sum_l w_l^2`` and ``w_max`` are themselves
Horvitz-Thompson-estimated from the sampled lines (a sampled line's
mass is exact — every one of its references is kept); callers without
mass information fall back to the uniform-trace case ``w_l = 1`` and
``bound = min(1, eps)``, the classical ``sqrt((1-R) ln(.) / (2 R n))``
DKW shape.  The ``S_hat`` terms cover the Hajek ratio: the rescaled
profile divides by its own estimated total (``kept / R``), so when the
spatial filter drops a line that carries most of the trace the sample's
moment estimates see none of that mass — but ``S_hat << n`` is directly
observed, and the ratio correction inflates the bound toward 1 in
exactly that regime.  SDCM's P(hit) is the expectation of a monotone
[0,1] function of D, so a sup-norm CDF deviation bounds the hit-rate
deviation by the same epsilon.  The bound holds with probability
``>= 1 - SAMPLE_BOUND_DELTA``, is ``0.0`` at ``rate >= 1.0`` (the pass
is exact), and in its uniform form is monotone non-increasing in both
``rate`` and ``n`` (with measured mass moments it tracks the data: a
fixed working set keeps the cluster variance ~constant as ``n`` grows).
``repro.validate``'s ``sampled_check`` gates per-cell sampled-vs-exact
SDCM deviation against exactly this declared bound — conservative at
validation-smoke trace lengths, tight enough to be a real gate at the
``validation-xxl`` (>= 1M refs) scale the sampled path exists for.

Memory: the scan state is O(window + R * working set) — fixed-rate
SHARDS, so peak RSS is flat in the trace length for a bounded working
set (the ``--sampling-smoke`` benchmark gate).
"""
from __future__ import annotations

import math

import numpy as np

from .distance import (
    DEFAULT_WINDOW,
    iter_address_windows,
    reuse_distance_windows,
    reuse_distances,
)
from .profile import (
    ReuseProfile,
    profile_from_distances,
    profile_from_distances_incremental,
    profile_from_pairs,
)

__all__ = [
    "SAMPLE_BOUND_DELTA",
    "sample_lines_mask",
    "sampling_error_bound",
    "sampled_reuse_profile",
    "sampled_profile_windows",
]

# Confidence parameter of the DKW bound: the declared error bound holds
# with probability >= 1 - SAMPLE_BOUND_DELTA over the hash seed.
# docs/sampling.md documents this constant and tools/docs_check.py
# cross-checks the documented value against this source.
SAMPLE_BOUND_DELTA = 1e-6

# splitmix64 finalizer constants — a well-mixed 64-bit permutation, so
# thresholding the hash is equivalent to Bernoulli(rate) line sampling.
_MIX_GAMMA = 0x9E3779B97F4A7C15
_MIX_MULT_1 = 0xBF58476D1CE4E5B9
_MIX_MULT_2 = 0x94D049BB133111EB
_U64 = np.uint64


def _hash_lines(lines: np.ndarray, seed: int) -> np.ndarray:
    """Deterministic 64-bit spatial hash of line ids, keyed by seed."""
    with np.errstate(over="ignore"):
        z = lines.astype(np.int64).view(_U64) + _U64(
            (int(seed) * _MIX_GAMMA) & 0xFFFFFFFFFFFFFFFF
        )
        z = (z ^ (z >> _U64(30))) * _U64(_MIX_MULT_1)
        z = (z ^ (z >> _U64(27))) * _U64(_MIX_MULT_2)
        return z ^ (z >> _U64(31))


def sample_lines_mask(lines, *, rate: float, seed: int = 0) -> np.ndarray:
    """Boolean keep-mask over line ids: hash(line, seed) < rate * 2^64.

    Spatial, not temporal: every occurrence of a line shares one verdict,
    which is what preserves reuse structure within the sample.
    """
    _check_rate(rate)
    lines = np.asarray(lines, dtype=np.int64)
    if rate >= 1.0:
        return np.ones(lines.shape, dtype=bool)
    threshold = _U64(min(int(rate * 2.0**64), 2**64 - 1))
    return _hash_lines(lines, seed) < threshold


def sampling_error_bound(
    rate: float, n_refs: int, *,
    sq_line_mass: float | None = None,
    max_line_mass: float | None = None,
    kept_refs: int | None = None,
) -> float:
    """Bernstein sup-norm bound on the sampled profile's CDF (and hence
    on downstream SDCM hit-rate deviation).  0.0 when the pass is exact
    (rate >= 1).

    ``sq_line_mass`` is (an estimate of) ``sum_l w_l^2`` over the FULL
    trace's per-line reference masses and ``max_line_mass`` the largest
    single mass — the cluster-sampling design effect.  Omitting them
    assumes a uniform trace (``w_l == 1``), which understates the bound
    for skewed traces; the profile builders always pass the
    Horvitz-Thompson estimates from the sample.

    ``kept_refs`` is the raw number of references that survived the
    spatial filter.  The profile normalizes by its OWN estimated mass
    ``S_hat = kept_refs / R`` (a Hajek ratio estimator), not by the true
    ``n`` — so the declared bound must also cover the ratio error, and
    ``|n - S_hat|`` is directly observable.  When a single line carries
    most of the trace and the filter drops it, the sample's moment
    estimates see none of that mass, but ``S_hat << n`` exposes the loss
    and inflates the bound toward 1 — without ``kept_refs`` the bound is
    the pure HT form and silently understates exactly that regime.
    """
    _check_rate(rate)
    if rate >= 1.0:
        return 0.0
    n = max(int(n_refs), 1)
    ssq = float(n) if sq_line_mass is None else max(float(sq_line_mass), 1.0)
    wmax = 1.0 if max_line_mass is None else max(float(max_line_mass), 1.0)
    log_term = math.log(2.0 * (n + 1) / SAMPLE_BOUND_DELTA)
    variance = (1.0 - rate) * ssq / (rate * float(n) ** 2)
    eps = math.sqrt(2.0 * variance * log_term) + wmax * log_term / (3.0 * rate * n)
    if kept_refs is None:
        return min(1.0, eps)
    s_hat = float(kept_refs) / rate
    if s_hat <= 0.0:
        return 1.0
    return min(1.0, eps * (n / s_hat) + abs(n - s_hat) / s_hat)


def _check_rate(rate: float) -> None:
    if not (0.0 < float(rate) <= 1.0):
        raise ValueError(f"sampling rate must be in (0, 1], got {rate!r}")


def _mass_moments(counts: np.ndarray, rate: float) -> tuple[float, float]:
    """HT estimates of (sum_l w_l^2, w_max) over the FULL trace from the
    sampled lines' (exact) masses: each sampled line's squared mass
    stands for 1/R lines' worth of second moment."""
    if counts.size == 0:
        return 0.0, 1.0
    c = counts.astype(np.float64)
    return float((c * c).sum() / rate), float(c.max())


def _rescale(profile: ReuseProfile, rate: float, bound: float) -> ReuseProfile:
    """d -> round(d / R), counts -> round(c / R); INF_RD mass keeps its
    marker distance.  Attaches the declared error bound."""
    inv = 1.0 / rate
    dists = profile.distances.astype(np.float64)
    finite = profile.distances >= 0
    dists = np.where(finite, np.round(dists * inv), profile.distances)
    counts = np.maximum(np.round(profile.counts * inv), 1).astype(np.int64)
    rescaled = profile_from_pairs(dists.astype(np.int64), counts)
    return rescaled.with_error_bound(bound)


def sampled_reuse_profile(
    addresses, line_size: int = 1, *, rate: float, seed: int = 0
) -> ReuseProfile:
    """Sampled reuse profile of an in-memory trace.

    Bit-identical to ``profile_from_distances(reuse_distances(...))``
    at ``rate == 1.0`` (modulo the attached ``error_bound == 0.0``).
    """
    _check_rate(rate)
    arr = np.asarray(addresses, dtype=np.int64)
    if line_size > 1:
        arr = arr // line_size
    n_refs = int(arr.size)
    if rate >= 1.0:
        exact = profile_from_distances(reuse_distances(arr))
        return exact.with_error_bound(0.0)
    kept = arr[sample_lines_mask(arr, rate=rate, seed=seed)]
    ssq, wmax = _mass_moments(
        np.unique(kept, return_counts=True)[1], rate
    )
    sub = profile_from_distances(reuse_distances(kept))
    return _rescale(sub, rate, sampling_error_bound(
        rate, n_refs, sq_line_mass=ssq, max_line_mass=wmax,
        kept_refs=int(kept.size),
    ))


def _rebatch(chunks, window_size: int):
    """Regroup variable-length chunks into uniform ``window_size``
    windows (plus one final partial) without ever holding more than
    one window's worth of buffered refs."""
    buf: list[np.ndarray] = []
    have = 0
    for c in chunks:
        if c.size == 0:
            continue
        buf.append(c)
        have += int(c.size)
        if have >= window_size:
            flat = np.concatenate(buf)
            off = 0
            while flat.size - off >= window_size:
                yield flat[off:off + window_size]
                off += window_size
            rest = flat[off:]
            buf = [rest] if rest.size else []
            have = int(rest.size)
    if have:
        yield np.concatenate(buf)


def sampled_profile_windows(
    source,
    line_size: int = 1,
    *,
    rate: float,
    seed: int = 0,
    window_size: int = DEFAULT_WINDOW,
) -> ReuseProfile:
    """Streaming sampled profile — the trace never exists in memory.

    Each address window is hash-filtered before it reaches the streaming
    Fenwick scan, so the scan state tracks only sampled lines:
    O(window + rate * working set) peak memory at any trace length.
    Identical to :func:`sampled_reuse_profile` on the same trace (the
    streaming scan is bit-identical to the in-memory pass).
    """
    _check_rate(rate)
    n_refs = 0
    # per-sampled-line masses for the bound's HT moments: O(sampled
    # distinct lines) state, the same order as the scan's own tracking
    mass: dict[int, int] = {}

    def counted():
        nonlocal n_refs
        for win in iter_address_windows(
            source, window_size=window_size, line_size=line_size
        ):
            n_refs += int(win.size)
            kept = win[sample_lines_mask(win, rate=rate, seed=seed)]
            if rate < 1.0 and kept.size:
                vals, cnts = np.unique(kept, return_counts=True)
                for v, c in zip(vals.tolist(), cnts.tolist()):
                    mass[v] = mass.get(v, 0) + c
            yield kept

    if rate >= 1.0:
        prof = profile_from_distances_incremental(
            reuse_distance_windows(counted(), window_size=window_size)
        )
        return prof.with_error_bound(0.0)
    # re-chunk the (variable-length) filtered windows to a uniform
    # width: the scan is bit-identical across window boundaries, and
    # uniform shapes keep the jitted scan at O(1) compilations instead
    # of one per distinct filtered length (which is O(N) compile-cache
    # memory — exactly what this path exists to avoid)
    sub = profile_from_distances_incremental(
        reuse_distance_windows(
            _rebatch(counted(), window_size), window_size=window_size
        )
    )
    ssq, wmax = _mass_moments(
        np.fromiter(mass.values(), dtype=np.int64, count=len(mass)), rate
    )
    return _rescale(sub, rate, sampling_error_bound(
        rate, n_refs, sq_line_mass=ssq, max_line_mass=wmax,
        kept_refs=sum(mass.values()),
    ))
