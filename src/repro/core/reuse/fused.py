"""Fused device-binned reuse profiles (ISSUE-5 tentpole, part 2).

The exact profile path materializes every window's distances host-side
and folds them through ``np.unique`` — fine as the oracle, wasteful as
the hot path.  Here the distance stream stays on device: each window's
distances (the int32 array the Fenwick scan produced) feed the
``kernels/reuse_hist`` Pallas histogram directly, accumulated in a
donated ``[2, NUM_BINS]`` buffer — row 0 the per-bin weighted counts,
row 1 the per-bin weighted distance mass.  Only the final 2x64 floats
ever cross back to the host, where they become a log2-binned
:class:`~repro.core.reuse.profile.ReuseProfile` whose bin
representative is the weighted-mean distance of the bin (the same
*representative convention* as
:func:`~repro.core.reuse.profile.log2_binned`; SDCM accuracy is
preserved — measured well under 1e-3 absolute on the validation
matrix).

Bin layout is the kernel's (:func:`repro.kernels.reuse_hist.reuse_hist
._bin_ids`), NOT ``log2_binned``'s: bin 0 holds the D = inf
(first-touch) mass, bin b >= 1 holds finite D with
``1 + floor(log2(max(D, 1))) == b``, clamped to ``NUM_BINS - 1`` — in
particular D = 0 and D = 1 share bin 1, where ``log2_binned`` gives
D = 0 its own bin.  The merge is SDCM-neutral for every
set-associative level (P(h|D) = 1 exactly for both D = 0 and D = 1
whenever assoc >= 2), so the two binnings agree at the hit-rate level
even though their histograms differ; don't diff them bin-for-bin.

On CPU containers the Pallas call runs in interpret mode (same kernel
body, traced into XLA); on TPU the identical code compiles natively.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.reuse_hist import reuse_histogram_moments
from repro.kernels.reuse_hist.reuse_hist import NUM_BINS

from .distance import (
    DEFAULT_WINDOW,
    INF_RD,
    reuse_distance_windows_device,
)
from .profile import ReuseProfile, profile_from_pairs

__all__ = [
    "FusedReuseHistogram",
    "binned_profile_from_distances",
    "binned_profile_windows",
    "profile_from_binned_hist",
]


def _interpret_default() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("interpret",))
def _accumulate(hist, d, w, *, interpret: bool):
    return hist + reuse_histogram_moments(d, w, interpret=interpret)


class FusedReuseHistogram:
    """Streaming device accumulator for binned reuse profiles.

    ``update`` takes any distance array (device or host) and folds it
    into the donated ``[2, NUM_BINS]`` device buffer; ``profile()``
    performs the only device->host transfer.
    """

    def __init__(self, *, interpret: bool | None = None):
        self.interpret = (
            _interpret_default() if interpret is None else interpret
        )
        self._hist = jnp.zeros((2, NUM_BINS), jnp.float32)

    def update(self, d, w=None) -> "FusedReuseHistogram":
        d = jnp.asarray(d)
        if d.size == 0:
            return self
        if w is None:
            w = jnp.ones(d.shape, jnp.float32)
        self._hist = _accumulate(
            self._hist, d, jnp.asarray(w), interpret=self.interpret
        )
        return self

    def histogram(self) -> np.ndarray:
        return np.asarray(self._hist, dtype=np.float64)

    def profile(self) -> ReuseProfile:
        return profile_from_binned_hist(self.histogram())


def _bin_bounds(b: int) -> tuple[int, int]:
    """Inclusive [lo, hi] finite-distance range of bin b >= 1."""
    if b == 1:
        return 0, 1
    lo = 1 << (b - 1)
    if b == NUM_BINS - 1:  # top bin is clamped open-ended
        return lo, np.iinfo(np.int64).max
    return lo, (1 << b) - 1


def profile_from_binned_hist(hist: np.ndarray) -> ReuseProfile:
    """[2, NUM_BINS] count/mass histogram -> log2-binned ReuseProfile.

    Bin representatives are the per-bin weighted-mean distances
    (rounded, clamped into the bin — ``log2_binned``'s representative
    convention, over the kernel's bin layout; see the module
    docstring); bin 0 becomes the ``INF_RD`` bucket.  Counts are
    rounded to integers — the pipeline's weights are unit reference
    counts, exact in f32 up to 2^24 per bin.
    """
    hist = np.asarray(hist, dtype=np.float64)
    counts = np.rint(hist[0]).astype(np.int64)
    mass = hist[1]
    out_d, out_c = [], []
    if counts[0] > 0:
        out_d.append(INF_RD)
        out_c.append(int(counts[0]))
    for b in range(1, NUM_BINS):
        c = int(counts[b])
        if c <= 0:
            continue
        lo, hi = _bin_bounds(b)
        rep = int(np.rint(mass[b] / c))
        out_d.append(int(np.clip(rep, lo, hi)))
        out_c.append(c)
    return profile_from_pairs(
        np.asarray(out_d, dtype=np.int64), np.asarray(out_c, dtype=np.int64)
    )


def binned_profile_from_distances(
    rds, weights=None, *, interpret: bool | None = None
) -> ReuseProfile:
    """One-shot device-binned profile of a distance array."""
    acc = FusedReuseHistogram(interpret=interpret)
    acc.update(jnp.asarray(np.asarray(rds)), weights)
    return acc.profile()


def binned_profile_windows(
    source,
    line_size: int = 1,
    *,
    window_size: int = DEFAULT_WINDOW,
    interpret: bool | None = None,
) -> ReuseProfile:
    """Streaming fused profile build: chunked Fenwick scan -> Pallas
    histogram, with every window's distances staying on device.

    The binned counterpart of ``profile_from_distances_incremental(
    reuse_distance_windows(...))`` — same trace windows, same scan
    state, but the O(N) distance stream is never copied to the host.
    """
    acc = FusedReuseHistogram(interpret=interpret)
    for rds in reuse_distance_windows_device(
        source, line_size, window_size=window_size
    ):
        acc.update(rds)
    return acc.profile()
