"""ECM-style in-core runtime model (instruction-aware stage 4).

The paper's Eq. 4–7 chain treats compute as one aggregate latency/
throughput pair per class and memory as a single average-cost stream.
The execution-cache-memory (ECM) family of models ("Bridging the
Architecture Gap", the OSACA throughput paper — PAPERS.md) is finer:

* **in-core**: every instruction class (int / fp / div / load / store)
  is issued onto a *port group* with its own dependent-issue latency δ
  and per-port reciprocal throughput β.  Port groups run concurrently,
  so the in-core compute time is the busiest port group, not the sum.
* **data**: the load/store units move every reference through L1, and
  each cache-level boundary adds *non-overlapping* transfer cycles for
  the traffic that misses its way down — the ECM sum
  ``T_data = T_L1 + T_L1L2 + T_L2L3 + T_L3Mem``, with per-level traffic
  from the cumulative hit rates the SDCM stage predicts.
* **combine**: throughput mode overlaps compute with the data chain
  (``max``); latency mode serializes a dependent chain (δ per
  instruction, Eq. 6 per access).
* **multicore**: per-core work divides, but traffic through the shared
  levels (LLC and RAM) serializes chip-wide — runtime saturates at the
  shared-bandwidth term once enough cores are throwing traffic at it.

Per-class tables live on the targets (``hw.targets`` — paper Table 5
sources plus OSACA-style port counts); this module only consumes them,
so the hw→core import direction is preserved (``hw.targets`` imports
the table schema from here).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, ClassVar

from repro.core.runtime_model import OpCounts, effective_latency_cy

if TYPE_CHECKING:  # break the hw<->core import cycle (annotations only)
    from repro.hw.targets import CPUTarget


# --- per-class timing tables -------------------------------------------------


@dataclass(frozen=True)
class ClassTiming:
    """One instruction class on one port group.

    ``delta`` — dependent-issue latency (cycles); ``beta`` — reciprocal
    throughput of ONE port (cycles/instr); ``ports`` — how many ports
    serve the class concurrently.  Effective class throughput is
    ``beta / ports`` cycles per instruction.
    """

    delta: float
    beta: float
    ports: int = 1

    @property
    def beta_effective(self) -> float:
        return self.beta / max(self.ports, 1)


@dataclass(frozen=True)
class InCoreTimings:
    """Per-class table: field names match :class:`OpCounts` fields, so
    mixes zip against timings without a translation layer."""

    int_ops: ClassTiming
    fp_ops: ClassTiming
    div_ops: ClassTiming
    loads: ClassTiming
    stores: ClassTiming

    COMPUTE_CLASSES: ClassVar[tuple[str, ...]] = ("int_ops", "fp_ops", "div_ops")
    MEM_CLASSES: ClassVar[tuple[str, ...]] = ("loads", "stores")
    CLASSES: ClassVar[tuple[str, ...]] = COMPUTE_CLASSES + MEM_CLASSES

    def timing(self, cls: str) -> ClassTiming:
        return getattr(self, cls)


def timings_of(target) -> InCoreTimings:
    """The target's per-class table, or a 1-port table derived from its
    aggregate Eq. 4–7 parameters (load/store inherit the L1 δ/β) so ECM
    still runs on a target that predates the per-class tables."""
    inc = getattr(target, "incore", None)
    if inc is not None:
        return inc
    instr = getattr(target, "instr", None)
    if instr is None:
        raise ValueError(
            f"target {getattr(target, 'name', target)!r} has neither "
            "per-class 'incore' timings nor aggregate 'instr' timings — "
            "the ECM model cannot run on it"
        )
    l1_delta = float(target.level_latency_cy[0])
    l1_beta = float(target.level_beta_cy[0])
    return InCoreTimings(
        int_ops=ClassTiming(instr.delta_int, instr.beta_int),
        fp_ops=ClassTiming(instr.delta_fp, instr.beta_fp),
        div_ops=ClassTiming(instr.delta_div, instr.beta_div),
        loads=ClassTiming(l1_delta, l1_beta),
        stores=ClassTiming(l1_delta, l1_beta),
    )


# --- model pieces (all in cycles) --------------------------------------------


def t_comp_cy(timings: InCoreTimings, counts: OpCounts,
              mode: str = "throughput") -> float:
    """In-core compute cycles.

    ``throughput`` — port groups drain concurrently: the busiest one
    bounds (``max`` over classes of n·β/ports); ``latency`` — one
    serialized dependency chain (Σ n·δ).
    """
    if mode == "throughput":
        return max(
            getattr(counts, cls) * timings.timing(cls).beta_effective
            for cls in InCoreTimings.COMPUTE_CLASSES
        )
    if mode == "latency":
        return sum(
            getattr(counts, cls) * timings.timing(cls).delta
            for cls in InCoreTimings.COMPUTE_CLASSES
        )
    raise ValueError(f"unknown in-core mode: {mode}")


def t_lsu_cy(timings: InCoreTimings, counts: OpCounts) -> float:
    """Load/store-unit issue cycles — every reference occupies an L1
    port regardless of where it eventually hits."""
    return (counts.loads * timings.loads.beta_effective
            + counts.stores * timings.stores.beta_effective)


def miss_fractions(hit_rates: list[float]) -> list[float]:
    """Fraction of references still unresolved after each level, from
    the paper's *cumulative* hit-rate convention (Table 6 metric):
    ``1 - P_i``, clamped into [0, 1] and made monotone non-increasing
    so a non-monotone input cannot create traffic out of thin air."""
    out: list[float] = []
    reach = 1.0
    for p in hit_rates:
        reach = min(reach, max(0.0, 1.0 - p))
        out.append(reach)
    return out


def transfer_cy(target: CPUTarget, hit_rates: list[float],
                mem_ops: float) -> list[float]:
    """Non-overlapping inter-level transfer cycles, one entry per
    boundary: ``out[i]`` is the cycles moving the traffic that missed
    level i across the level-(i+1) port (the last entry is the RAM
    boundary), using the target's per-level β."""
    if len(hit_rates) != len(target.levels):
        raise ValueError(
            f"{len(hit_rates)} hit rates for {len(target.levels)} levels "
            f"of {target.name}"
        )
    betas = list(target.level_beta_cy[1:]) + [target.ram_beta_cy]
    return [
        mem_ops * m * b
        for m, b in zip(miss_fractions(hit_rates), betas)
    ]


def shared_transfer_cy(target: CPUTarget, hit_rates: list[float],
                       counts: OpCounts) -> float:
    """Chip-wide serialized cycles: transfers crossing into the shared
    levels (LLC and beyond) and RAM contend across *all* cores, so
    they are computed on the undivided counts."""
    shared_idx = getattr(target, "shared_level", -1) % len(target.levels)
    transfers = transfer_cy(target, hit_rates, counts.mem_ops)
    # transfers[i] crosses the level-(i+1) port; it contends once the
    # destination is the shared level or deeper (i + 1 >= shared_idx)
    return sum(t for i, t in enumerate(transfers) if i + 1 >= shared_idx)


def ecm_cycles(target: CPUTarget, hit_rates: list[float], counts: OpCounts,
               *, mode: str = "throughput") -> dict[str, float]:
    """Single-core ECM decomposition for one core's share of work.

    ``throughput``: ``T = max(T_comp, T_LSU + Σ T_transfer)`` — compute
    overlaps the data chain, the data chain's pieces do not overlap
    each other (the ECM non-overlap assumption).
    ``latency``: fully serialized — the δ chain for compute plus the
    Eq. 6 per-access latency for every reference.
    """
    timings = timings_of(target)
    comp = t_comp_cy(timings, counts, mode)
    if mode == "throughput":
        data = t_lsu_cy(timings, counts) + sum(
            transfer_cy(target, hit_rates, counts.mem_ops)
        )
        core = max(comp, data)
    else:
        if len(hit_rates) != len(target.levels):
            raise ValueError(
                f"{len(hit_rates)} hit rates for {len(target.levels)} "
                f"levels of {target.name}"
            )
        data = counts.mem_ops * effective_latency_cy(target, hit_rates)
        core = comp + data
    return {"t_comp_cy": comp, "t_data_cy": data, "t_core_cy": core}


# --- stage-4 model -----------------------------------------------------------


class ECMRuntimeModel:
    """Instruction-aware stage 4: the ECM decomposition above, scaled
    to ``cores`` with chip-wide shared-bandwidth saturation.

    Per-core runtime uses each core's 1/cores share of the mix; the
    prediction is ``max(per-core ECM time, shared-transfer time of the
    FULL traffic)`` — so adding cores helps until the shared levels'
    ports saturate, then the curve goes flat (the classic ECM multicore
    scaling shape, and the behaviour Eq. 4–7 cannot express).

    ``gap_bytes`` is accepted for stage-interface compatibility and
    ignored: spatial locality lives in the line-granular reuse profiles
    whose hit rates this model consumes, not in a post-hoc block
    correction.
    """

    name = "ecm"

    def runtime(self, target, hit_rates: dict[str, float], counts: OpCounts,
                cores: int, *, mode: str = "throughput",
                gap_bytes: float = 0.0) -> dict[str, float]:
        ordered = [hit_rates[lvl.name] for lvl in target.levels]
        share = counts.scaled(1.0 / max(cores, 1))
        cyc = ecm_cycles(target, ordered, share, mode=mode)
        sat = shared_transfer_cy(target, ordered, counts)
        total_cy = max(cyc["t_core_cy"], sat)
        cs = target.cycle_s
        return {
            "t_pred_s": total_cy * cs,
            "t_cpu_s": cyc["t_comp_cy"] * cs,
            "t_mem_s": cyc["t_data_cy"] * cs,
            "t_shared_bw_s": sat * cs,
            "bound": "bandwidth" if sat > cyc["t_core_cy"] else (
                "compute" if cyc["t_comp_cy"] >= cyc["t_data_cy"]
                else "data"
            ),
        }


__all__ = [
    "ClassTiming",
    "ECMRuntimeModel",
    "InCoreTimings",
    "ecm_cycles",
    "miss_fractions",
    "shared_transfer_cy",
    "t_comp_cy",
    "t_lsu_cy",
    "timings_of",
    "transfer_cy",
]
