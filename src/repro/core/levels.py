"""Leaf module: cache-geometry dataclasses shared by every layer.

Depends on nothing inside ``repro`` — ``hw.targets`` (hardware specs),
``core.cachesim`` (exact simulation), and ``api`` (the prediction
pipeline) all import from here, so no import cycle can form around the
geometry types.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CacheLevelConfig:
    name: str
    size_bytes: int
    line_size: int
    assoc: int  # ways; >= num_lines means fully associative

    @property
    def num_lines(self) -> int:
        return max(1, self.size_bytes // self.line_size)

    @property
    def effective_assoc(self) -> int:
        return min(self.assoc, self.num_lines)

    @property
    def num_sets(self) -> int:
        return max(1, self.num_lines // self.effective_assoc)


@dataclass(frozen=True)
class LevelResult:
    name: str
    accesses: int          # references reaching this level
    hits: int              # hits at this level
    cumulative_hit_rate: float  # 1 - misses_here / total_trace_accesses
