"""Pallas TPU kernel for the SDCM conditional hit-rate (paper Eq. 1).

Evaluating P(h|D) for every reference of a multi-million-entry trace ×
several cache geometries is the compute hot spot of the prediction
pipeline (the paper re-implemented PPT-SASMM precisely because profile
math was slow).  The kernel evaluates the binomial CDF

    P(h|D) = sum_{k<A} C(D,k) p^k (1-p)^(D-k),   p = A/B

with the incremental log-space recurrence (log C(D,k) built by cumsum of
log((D-k+1)/k)), unrolled over k — A is a compile-time constant (<= 64
ways for every real cache), so the kernel is a fixed sequence of VPU
vector ops over an (8, 128) VMEM tile per grid step.

TPU adaptation notes: distances arrive as a flat f32 array reshaped to
(rows, 128) lanes; each grid step processes a (BLOCK_ROWS, 128) tile
held in VMEM.  No MXU use — this is a pure VPU kernel; the tile shape
is chosen to match the (8, 128) vreg layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8  # (8, 128) = one f32 vreg tile


def _sdcm_kernel(d_ref, out_ref, *, assoc: int, log_p: float, log_1mp: float):
    d = d_ref[...]
    neg = d < 0.0  # INF_RD sentinel -> miss
    dd = jnp.maximum(d, 0.0)
    # k = 0 term: (1-p)^D
    acc = jnp.exp(dd * log_1mp)
    log_comb = jnp.zeros_like(dd)
    for k in range(1, assoc):
        kf = float(k)
        log_comb = log_comb + jnp.log(jnp.maximum(dd - (kf - 1.0), 1e-30)) - jnp.log(kf)
        term = jnp.exp(log_comb + kf * log_p + (dd - kf) * log_1mp)
        acc = acc + jnp.where(dd >= kf, term, 0.0)
    out = jnp.minimum(acc, 1.0)
    out = jnp.where(dd <= float(assoc - 1), 1.0, out)
    out_ref[...] = jnp.where(neg, 0.0, out).astype(out_ref.dtype)


def sdcm_pallas_2d(
    d2: jax.Array, assoc: int, blocks: int, *, interpret: bool = False
) -> jax.Array:
    """P(h|D) over a (rows, 128) f32 distance array (rows % 8 == 0)."""
    import math

    rows, lanes = d2.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, d2.shape
    if not 1 <= assoc <= 64:
        raise ValueError("kernel supports 1 <= assoc <= 64 ways")
    p = assoc / blocks
    kernel = functools.partial(
        _sdcm_kernel,
        assoc=assoc,
        log_p=math.log(p),
        log_1mp=math.log1p(-p),
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(d2.shape, jnp.float32),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=interpret,
    )(d2)
