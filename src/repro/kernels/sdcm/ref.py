"""Pure-jnp oracle for the SDCM kernel (same math as core.sdcm)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sdcm import phit_given_d


def sdcm_ref(d: jnp.ndarray, assoc: int, blocks: int) -> jnp.ndarray:
    """P(h|D); d is float with -1.0 marking INF_RD."""
    d_int = jnp.where(d < 0, -1, d.astype(jnp.int32))
    return phit_given_d(d_int, assoc, blocks)
