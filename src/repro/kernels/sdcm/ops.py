"""Jitted public wrapper for the SDCM Pallas kernel.

Handles flat arrays of arbitrary length: pads to a whole number of
(8, 128) tiles, reshapes, dispatches the kernel, unpads.  On non-TPU
backends ``interpret=True`` executes the same kernel body in Python.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .sdcm import BLOCK_ROWS, LANES, sdcm_pallas_2d

_TILE = BLOCK_ROWS * LANES


@functools.partial(jax.jit, static_argnames=("assoc", "blocks", "interpret"))
def sdcm_hit_probs(
    d: jax.Array, *, assoc: int, blocks: int, interpret: bool = False
) -> jax.Array:
    """P(h|D) for a flat distance array (f32; -1 = first touch)."""
    d = d.astype(jnp.float32).ravel()
    if assoc >= blocks:
        # fully associative degenerates to the exact LRU rule — no
        # binomial math (and p = A/B = 1 would break the kernel's logs).
        return jnp.where((d >= 0) & (d < blocks), 1.0, 0.0)
    n = d.shape[0]
    padded = ((n + _TILE - 1) // _TILE) * _TILE
    d2 = jnp.pad(d, (0, padded - n), constant_values=-1.0).reshape(-1, LANES)
    out = sdcm_pallas_2d(d2, assoc, blocks, interpret=interpret)
    return out.ravel()[:n]


@functools.partial(jax.jit, static_argnames=("assoc", "blocks", "interpret"))
def sdcm_hit_rate(
    d: jax.Array,
    weights: jax.Array,
    *,
    assoc: int,
    blocks: int,
    interpret: bool = False,
) -> jax.Array:
    """Unconditional P(h) (Eq. 3): weighted fold of P(h|D)."""
    probs = sdcm_hit_probs(d, assoc=assoc, blocks=blocks, interpret=interpret)
    w = weights.astype(jnp.float32).ravel()
    return jnp.dot(probs, w) / jnp.maximum(jnp.sum(w), 1e-30)
