from .ops import sdcm_hit_probs, sdcm_hit_rate
from .ref import sdcm_ref

__all__ = ["sdcm_hit_probs", "sdcm_hit_rate", "sdcm_ref"]
