"""Pallas TPU flash-attention forward (blocked online softmax).

TPU adaptation of the memory-efficient attention insight: never
materialize the [Sq, Sk] score matrix in HBM.  Each grid step owns one
(BLK_Q, D) query tile in VMEM and streams K/V in (BLK_K, D) tiles,
maintaining the running max / normalizer / accumulator of the online
softmax.  Matmul tiles are 128-aligned for the MXU; accumulation is
f32 regardless of input dtype.

Supports GQA (kv_heads <= q_heads via the grid index map — no K/V
repeat is ever materialized) and causal masking (the KV stream stops at
the diagonal chunk; the diagonal chunk is mask-corrected).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, *, blk_q: int, blk_k: int, causal: bool, scale: float
):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale      # [BLK_Q, D]
    seq_k = k_ref.shape[2]
    num_chunks = seq_k // blk_k

    if causal:
        # stream K/V only up to (and including) the diagonal chunk
        last = jnp.minimum(((qi + 1) * blk_q + blk_k - 1) // blk_k, num_chunks)
    else:
        last = num_chunks

    def body(j, carry):
        acc, m, l = carry
        kj = k_ref[0, 0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        vj = v_ref[0, 0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        s = q @ kj.T                                  # [BLK_Q, BLK_K]
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ vj
        return acc, m_new, l

    acc0 = jnp.zeros((blk_q, q_ref.shape[3]), jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, last, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    blk_q: int = DEFAULT_BLK_Q,
    blk_k: int = DEFAULT_BLK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Sk, D] with H % Hkv == 0."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, sk, blk_q, blk_k)
    if scale is None:
        scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(b, h, sq // blk_q),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi // group, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        interpret=interpret,
    )(q, k, v)
