"""Pure-jnp oracle: dense softmax attention with GQA + causal mask."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if scale is None:
        scale = d ** -0.5
    group = h // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
