"""Jitted public wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "blk_q", "blk_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, blk_q=blk_q, blk_k=blk_k,
        interpret=interpret,
    )
