"""Pallas TPU kernel: log2-binned weighted reuse-profile histogram.

Builds the reuse profile P(D) (paper Table 2 / §3.3.1) from a raw
distance stream.  Scatter-adds are hostile to the TPU vector unit, so
the kernel turns binning into a dense one-hot contraction: each (8,128)
tile of distances becomes a (TILE, BINS) one-hot matrix folded into the
per-bin accumulator — an MXU-friendly reformulation of a histogram.

Bin layout: bin 0 <- INF_RD (first touch / D = inf);
            bin b <- finite D with floor(log2(max(D,1))) == b-1 ... i.e.
            b = 1 + ceil-log2 bucket, clamped to BINS-1.

The output block index_map pins every grid step to the same (1, BINS)
accumulator block; step 0 initializes it (the canonical Pallas
accumulation pattern over a sequential grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8
NUM_BINS = 64


def _bin_ids(d: jnp.ndarray) -> jnp.ndarray:
    """bin 0 for INF_RD; else 1 + floor(log2(D)) (D=0 -> bin 1)."""
    dd = jnp.maximum(d, 1.0)
    b = jnp.floor(jnp.log2(dd)).astype(jnp.int32) + 1
    b = jnp.where(d == 0.0, 1, b)
    b = jnp.clip(b, 1, NUM_BINS - 1)
    return jnp.where(d < 0.0, 0, b)


def _hist_kernel(d_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = d_ref[...].reshape(-1)        # [TILE]
    w = w_ref[...].reshape(-1)        # [TILE] (0 for padding)
    bins = _bin_ids(d)                # [TILE]
    onehot = (
        bins[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, NUM_BINS), 1)
    ).astype(jnp.float32)             # [TILE, BINS]
    partial = w[None, :] @ onehot     # [1, BINS] — MXU contraction
    out_ref[...] += partial


def reuse_hist_pallas_2d(
    d2: jax.Array, w2: jax.Array, *, interpret: bool = False
) -> jax.Array:
    rows, lanes = d2.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0
    return pl.pallas_call(
        _hist_kernel,
        out_shape=jax.ShapeDtypeStruct((1, NUM_BINS), jnp.float32),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, NUM_BINS), lambda i: (0, 0)),
        interpret=interpret,
    )(d2, w2)


def _moments_kernel(d_ref, w_ref, out_ref):
    """Count + distance-mass histograms in one pass.

    Row 0 of the accumulator is the weighted count per bin (identical
    to :func:`_hist_kernel`); row 1 is the weighted sum of (finite)
    distances per bin, from which the fused profile path derives each
    bin's weighted-mean representative distance without ever reading
    the raw stream back to the host.  Both rows fall out of ONE one-hot
    contraction: a [2, TILE] weight matrix against the [TILE, BINS]
    one-hot — still a single MXU op per tile.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = d_ref[...].reshape(-1)        # [TILE]
    w = w_ref[...].reshape(-1)        # [TILE] (0 for padding)
    bins = _bin_ids(d)                # [TILE]
    onehot = (
        bins[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, NUM_BINS), 1)
    ).astype(jnp.float32)             # [TILE, BINS]
    wd = w * jnp.maximum(d, 0.0)      # INF sentinel carries no mass
    stacked = jnp.stack([w, wd], axis=0)  # [2, TILE]
    out_ref[...] += stacked @ onehot      # [2, BINS]


def reuse_hist_moments_pallas_2d(
    d2: jax.Array, w2: jax.Array, *, interpret: bool = False
) -> jax.Array:
    rows, lanes = d2.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0
    return pl.pallas_call(
        _moments_kernel,
        out_shape=jax.ShapeDtypeStruct((2, NUM_BINS), jnp.float32),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((2, NUM_BINS), lambda i: (0, 0)),
        interpret=interpret,
    )(d2, w2)
