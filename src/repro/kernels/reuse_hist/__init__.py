from .ops import reuse_histogram
from .ref import reuse_hist_ref

__all__ = ["reuse_histogram", "reuse_hist_ref"]
