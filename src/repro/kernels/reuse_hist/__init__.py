from .ops import reuse_histogram, reuse_histogram_moments
from .ref import reuse_hist_moments_ref, reuse_hist_ref

__all__ = [
    "reuse_histogram",
    "reuse_histogram_moments",
    "reuse_hist_moments_ref",
    "reuse_hist_ref",
]
