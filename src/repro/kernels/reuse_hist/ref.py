"""Pure-jnp oracles for the reuse-histogram kernels."""
from __future__ import annotations

import jax.numpy as jnp

from .reuse_hist import NUM_BINS, _bin_ids


def reuse_hist_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    bins = _bin_ids(d.astype(jnp.float32).ravel())
    return jnp.zeros((NUM_BINS,), jnp.float32).at[bins].add(
        w.astype(jnp.float32).ravel()
    )


def reuse_hist_moments_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """[2, NUM_BINS]: weighted counts and weighted distance mass."""
    df = d.astype(jnp.float32).ravel()
    wf = w.astype(jnp.float32).ravel()
    bins = _bin_ids(df)
    zeros = jnp.zeros((NUM_BINS,), jnp.float32)
    counts = zeros.at[bins].add(wf)
    mass = zeros.at[bins].add(wf * jnp.maximum(df, 0.0))
    return jnp.stack([counts, mass])
