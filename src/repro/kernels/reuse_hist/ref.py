"""Pure-jnp oracle for the reuse-histogram kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .reuse_hist import NUM_BINS, _bin_ids


def reuse_hist_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    bins = _bin_ids(d.astype(jnp.float32).ravel())
    return jnp.zeros((NUM_BINS,), jnp.float32).at[bins].add(
        w.astype(jnp.float32).ravel()
    )
