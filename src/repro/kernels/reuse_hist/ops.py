"""Jitted public wrapper for the reuse-histogram Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .reuse_hist import BLOCK_ROWS, LANES, NUM_BINS, reuse_hist_pallas_2d

_TILE = BLOCK_ROWS * LANES


@functools.partial(jax.jit, static_argnames=("interpret",))
def reuse_histogram(
    d: jax.Array, w: jax.Array | None = None, *, interpret: bool = False
) -> jax.Array:
    """Weighted log2-binned histogram of a flat distance array.

    Returns [NUM_BINS] f32; bin 0 is the D = inf (first-touch) mass.
    """
    d = d.astype(jnp.float32).ravel()
    n = d.shape[0]
    if w is None:
        w = jnp.ones((n,), jnp.float32)
    w = w.astype(jnp.float32).ravel()
    padded = ((n + _TILE - 1) // _TILE) * _TILE
    d2 = jnp.pad(d, (0, padded - n), constant_values=-1.0).reshape(-1, LANES)
    w2 = jnp.pad(w, (0, padded - n)).reshape(-1, LANES)  # pad weight 0
    out = reuse_hist_pallas_2d(d2, w2, interpret=interpret)
    return out.reshape(NUM_BINS)


@functools.partial(jax.jit, static_argnames=("interpret",))
def reuse_histogram_moments(
    d: jax.Array, w: jax.Array | None = None, *, interpret: bool = False
) -> jax.Array:
    """[2, NUM_BINS] f32: per-bin weighted counts (row 0, identical to
    :func:`reuse_histogram`) and weighted finite-distance mass (row 1).

    One fused Pallas pass — the device side of the ``binned=True``
    profile mode: counts give P(D) per bin, the mass gives each bin's
    weighted-mean representative distance.
    """
    from .reuse_hist import reuse_hist_moments_pallas_2d

    d = d.astype(jnp.float32).ravel()
    n = d.shape[0]
    if w is None:
        w = jnp.ones((n,), jnp.float32)
    w = w.astype(jnp.float32).ravel()
    padded = ((n + _TILE - 1) // _TILE) * _TILE
    d2 = jnp.pad(d, (0, padded - n), constant_values=-1.0).reshape(-1, LANES)
    w2 = jnp.pad(w, (0, padded - n)).reshape(-1, LANES)  # pad weight 0
    return reuse_hist_moments_pallas_2d(d2, w2, interpret=interpret)
