"""Jitted public wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax

from .ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    la: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Chunked SSD scan.  x: [BH, S, P]; la: [BH, S]; b, c: [BH, S, N]."""
    return ssd_scan_pallas(x, la, b, c, chunk=chunk, interpret=interpret)
