"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

The SSD recurrence per head (state N, head dim P):

    h_t = a_t * h_{t-1} + b_t (x)  (outer product b_t x_t^T),  a_t in (0,1]
    y_t = c_t^T h_t

A sequential scan wastes the MXU.  The chunked (block-parallel) form —
the core of the SSD paper and the natural TPU mapping — splits the
sequence into chunks of L steps:

  intra-chunk:  scores[i,j] = (c_i . b_j) * exp(s_i - s_j)  for j <= i,
                y_intra = scores @ x          (two MXU matmuls)
  inter-chunk:  y_inter[i] = exp(s_i) * (c_i @ h_in)
  state carry:  h_out = exp(s_L) h_in + (b * exp(s_L - s))^T @ x

with s = cumsum(log a) inside the chunk (s_i - s_j <= 0, so every
exponential is <= 1: numerically safe).  The carried state lives in a
VMEM scratch buffer across the sequential chunk grid dimension.

Inputs are pre-fused by ops.py: la = log a  [BH, S],
b = dt-scaled B [BH, S, N], c [BH, S, N], x [BH, S, P].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)    # [L, P]
    la = la_ref[0].astype(jnp.float32)  # [L]
    b = b_ref[0].astype(jnp.float32)    # [L, N]
    c = c_ref[0].astype(jnp.float32)    # [L, N]

    s = jnp.cumsum(la)                  # [L]
    # intra-chunk (lower-triangular decay attention)
    scores = (c @ b.T) * jnp.exp(s[:, None] - s[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols <= rows, scores, 0.0)
    y = scores @ x                      # [L, P]
    # inter-chunk
    h_in = state_ref[...]               # [N, P]
    y = y + jnp.exp(s)[:, None] * (c @ h_in)
    # state carry
    w = jnp.exp(s[-1] - s)              # [L]
    state_ref[...] = jnp.exp(s[-1]) * h_in + (b * w[:, None]).T @ x
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,
    la: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """x: [BH, S, P]; la: [BH, S]; b, c: [BH, S, N] -> y: [BH, S, P]."""
    bh, seq, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, seq)
    assert seq % chunk == 0, (seq, chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(bh, seq // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, la, b, c)
