"""Pure-jnp oracle: sequential SSD recurrence via lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jnp.ndarray, la: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray
) -> jnp.ndarray:
    """x: [BH, S, P]; la: [BH, S] (log decay); b, c: [BH, S, N]."""

    def one(x1, la1, b1, c1):
        n, p = b1.shape[-1], x1.shape[-1]

        def step(h, inp):
            xt, lat, bt, ct = inp
            h = jnp.exp(lat) * h + jnp.outer(bt, xt)
            return h, ct @ h

        h0 = jnp.zeros((n, p), jnp.float32)
        _, y = jax.lax.scan(
            step,
            h0,
            (
                x1.astype(jnp.float32),
                la1.astype(jnp.float32),
                b1.astype(jnp.float32),
                c1.astype(jnp.float32),
            ),
        )
        return y

    return jax.vmap(one)(x, la, b, c).astype(x.dtype)
