"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage ships the kernel (``pl.pallas_call`` + BlockSpec VMEM
tiling), a jitted wrapper (``ops.py``) and a pure-jnp oracle
(``ref.py``).  On this CPU-only container kernels are validated with
``interpret=True``; on TPU the same calls compile natively.
"""
