"""Production meshes.  A FUNCTION, not a module constant — importing
this module never touches jax device state (the dry-run must set
XLA_FLAGS before the first device query)."""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across versions: ``axis_types`` appeared after
    0.4.x (and defaults to Auto there), so only pass it when it exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); 2 pods = 512 chips with a
    leading "pod" axis.  DP runs over ("pod","data"); TP over "model"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Whatever this host exposes, as a 1D ("data",) mesh — used by the
    runnable examples and smoke tests."""
    return make_mesh_compat((jax.device_count(),), ("data",))
