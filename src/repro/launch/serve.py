"""Batched serving driver: prefill + decode loop with KV-cache reuse.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Demonstrates the serving path end-to-end on host devices: one jitted
prefill over the batch of prompts, then token-by-token jitted decode
against the (sequence-shardable) cache.  The production mesh path uses
the same builders as the dry-run (launch.steps).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.dist.sharding import ShardingRules, use_sharding
from repro.launch.mesh import make_host_mesh
from repro.models.layers import unzip_params


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    if args.reduced:
        from repro.configs.reduced import reduced
        spec = reduced(spec)
    fam, cfg = spec.family, spec.config

    mesh = make_host_mesh()
    rules = ShardingRules(mesh, spec.rules_for("decode"))

    with use_sharding(rules):
        params = fam.init(jax.random.key(args.seed), cfg)
    values, _ = unzip_params(params)

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen
    batch = {"tokens": jnp.asarray(rng.integers(
        0, spec.vocab, (args.batch, args.prompt_len), dtype=np.int32))}
    if spec.family_name == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), cfg.dtype)
        caches = fam.init_caches(cfg, batch=args.batch, max_len=max_len,
                                 src_len=args.prompt_len)
    elif spec.family_name == "vlm":
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_patches, cfg.clip_dim)),
            cfg.backbone.dtype)
        caches = fam.init_caches(cfg, batch=args.batch,
                                 max_len=max_len + cfg.num_patches)
    else:
        caches = fam.init_caches(cfg, batch=args.batch, max_len=max_len)

    prefill = jax.jit(
        lambda p, b, c: _with(rules, fam.prefill, p, b, cfg, c),
        donate_argnums=(2,),
    )
    decode = jax.jit(
        lambda p, b, c, n: _with(rules, fam.decode_step, p, b, cfg, c, n),
        donate_argnums=(2,),
    )

    t0 = time.time()
    logits, caches = prefill(values, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    length = jnp.asarray(args.prompt_len, jnp.int32)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    key = jax.random.key(args.seed)
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(values, {"token": tok}, caches, length)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1
            ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
        length = length + 1
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    assert gen.max() < spec.vocab, "padded-vocab id sampled"
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
    print(f"decode : {args.gen - 1} steps, {tput:.1f} tok/s "
          f"({t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/step)")
    print("sample token ids:", gen[0, :12].tolist())
    return 0


def _with(rules, fn, *a):
    with use_sharding(rules):
        return fn(*a)


if __name__ == "__main__":
    raise SystemExit(main())
