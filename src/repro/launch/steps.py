"""Assemble jitted, sharded step functions for an (arch x shape x mesh)
cell.  Shared by the dry-run, the trainer, and the server.

Everything here works on ShapeDtypeStruct stand-ins (``abstract=True``
paths allocate nothing) — the paper's "collect the trace once, predict
every configuration" discipline applied to XLA: one lowering per cell,
analyzed offline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ArchSpec, Shape
from repro.dist.sharding import (
    ShardingRules, param_shardings, pspec_for, use_sharding,
)
from repro.models.layers import unzip_params
from repro.train.optimizer import Optimizer, adafactor, adamw
from repro.train.schedule import warmup_cosine
from repro.train.train_step import TrainState, build_train_step, init_state


def build_rules(mesh: Mesh, spec: ArchSpec, kind: str) -> ShardingRules:
    return ShardingRules(mesh, spec.rules_for(kind))


def make_optimizer(spec: ArchSpec, total_steps: int = 10000) -> Optimizer:
    sched = warmup_cosine(spec.peak_lr, min(500, total_steps // 10 + 1),
                          total_steps)
    if spec.optimizer_name == "adafactor":
        return adafactor(sched)
    return adamw(sched)


def abstract_params(spec: ArchSpec):
    """(abstract value tree, logical-axes tree) — no allocation."""
    pspec_tree = jax.eval_shape(
        lambda k: spec.family.init(k, spec.config), jax.random.key(0)
    )
    return unzip_params(pspec_tree)


def _tree_shardings(abstract, axes, rules):
    shardings, _ = param_shardings(abstract, axes, rules)
    return shardings


def batch_shardings(spec: ArchSpec, shape: Shape, rules: ShardingRules):
    specs = spec.input_specs(shape)
    axes = spec.batch_axes(shape)
    return {
        name: NamedSharding(
            rules.mesh, pspec_for(specs[name].shape, axes[name], rules)
        )
        for name in specs
    }


@dataclasses.dataclass
class CellArtifacts:
    """Everything needed to lower/compile/run one cell."""
    kind: str
    fn: Callable                 # the pure step function
    in_shardings: Any
    out_shardings: Any
    abstract_args: tuple         # ShapeDtypeStructs for .lower()
    donate_argnums: tuple
    rules: ShardingRules


# --- train --------------------------------------------------------------------


def build_train_cell(spec: ArchSpec, shape: Shape, mesh: Mesh,
                     *, grad_accum: int | None = None) -> CellArtifacts:
    rules = build_rules(mesh, spec, "train")
    cfg = spec.config
    fam = spec.family
    optimizer = make_optimizer(spec)
    accum = spec.grad_accum_for(shape) if grad_accum is None else grad_accum
    # the microbatch batch dim must stay divisible by the DP extent or
    # GSPMD replicates activations (observed: 289 GB/chip on multipod)
    dp = rules.axis_size(rules.dp_axes)
    while accum > 1 and (shape.global_batch % accum
                         or (shape.global_batch // accum) % dp):
        accum -= 1

    def loss(p, b):
        return fam.loss_fn(p, b, cfg)

    step_fn = build_train_step(
        loss, optimizer, grad_accum=accum, accum_dtype=spec.accum_dtype
    )

    aparams, paxes = abstract_params(spec)
    aopt = jax.eval_shape(optimizer.init, aparams)
    oaxes = optimizer.state_axes(paxes)
    astate = TrainState(jax.ShapeDtypeStruct((), jnp.int32), aparams, aopt)

    opt_rules = rules.with_overrides(**spec.opt_rules) if spec.opt_rules \
        else rules
    state_sh = TrainState(
        NamedSharding(mesh, PartitionSpec()),
        _tree_shardings(aparams, paxes, rules),
        _tree_shardings(aopt, oaxes, opt_rules),
    )
    batch_sh = batch_shardings(spec, shape, rules)
    metrics_sh = {
        k: NamedSharding(mesh, PartitionSpec())
        for k in ("loss", "grad_norm", "param_norm")
    }

    def traced(state, batch):
        with use_sharding(rules):
            return step_fn(state, batch)

    return CellArtifacts(
        kind="train",
        fn=traced,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        abstract_args=(astate, spec.input_specs(shape)),
        donate_argnums=(0,),
        rules=rules,
    )


# --- serve --------------------------------------------------------------------


def abstract_caches(spec: ArchSpec, shape: Shape):
    fam = spec.family
    kw = spec.cache_kwargs(shape)
    acaches = jax.eval_shape(lambda: fam.init_caches(spec.config, **kw))
    axes = fam.cache_axes(spec.config)
    return acaches, axes


def build_prefill_cell(spec: ArchSpec, shape: Shape, mesh: Mesh) -> CellArtifacts:
    rules = build_rules(mesh, spec, "prefill")
    cfg, fam = spec.config, spec.family

    acaches, caxes = abstract_caches(spec, shape)
    aparams, paxes = abstract_params(spec)
    cache_sh = _tree_shardings(acaches, caxes, rules)
    param_sh = _tree_shardings(aparams, paxes, rules)
    batch_sh = batch_shardings(spec, shape, rules)
    logits_sh = NamedSharding(
        mesh, pspec_for((shape.global_batch, spec.config.padded_vocab),
                        ("act_batch", "act_vocab"), rules)
    )

    def traced(params, batch, caches):
        with use_sharding(rules):
            return fam.prefill(params, batch, cfg, caches)

    return CellArtifacts(
        kind="prefill",
        fn=traced,
        in_shardings=(param_sh, batch_sh, cache_sh),
        out_shardings=(logits_sh, cache_sh),
        abstract_args=(aparams, spec.input_specs(shape), acaches),
        donate_argnums=(2,),
        rules=rules,
    )


def build_decode_cell(spec: ArchSpec, shape: Shape, mesh: Mesh) -> CellArtifacts:
    rules = build_rules(mesh, spec, "decode")
    cfg, fam = spec.config, spec.family

    acaches, caxes = abstract_caches(spec, shape)
    aparams, paxes = abstract_params(spec)
    cache_sh = _tree_shardings(acaches, caxes, rules)
    param_sh = _tree_shardings(aparams, paxes, rules)
    batch_sh = batch_shardings(spec, shape, rules)
    repl = NamedSharding(mesh, PartitionSpec())
    logits_sh = NamedSharding(
        mesh, pspec_for((shape.global_batch, spec.config.padded_vocab),
                        ("act_batch", "act_vocab"), rules)
    )

    def traced(params, batch, caches, length):
        with use_sharding(rules):
            return fam.decode_step(params, batch, cfg, caches, length)

    alength = jax.ShapeDtypeStruct((), jnp.int32)
    return CellArtifacts(
        kind="decode",
        fn=traced,
        in_shardings=(param_sh, batch_sh, cache_sh, repl),
        out_shardings=(logits_sh, cache_sh),
        abstract_args=(aparams, spec.input_specs(shape), acaches, alength),
        donate_argnums=(2,),
        rules=rules,
    )


def build_cell(spec: ArchSpec, shape: Shape, mesh: Mesh) -> CellArtifacts:
    if shape.kind == "train":
        return build_train_cell(spec, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(spec, shape, mesh)
    return build_decode_cell(spec, shape, mesh)


def lower_cell(cell: CellArtifacts):
    """jit + .lower() — the dry-run entry point."""
    fn = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    with cell.rules.mesh:
        return fn.lower(*cell.abstract_args)
