"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --reduced --steps 200 --checkpoint-dir /tmp/ckpt

Runs the full production loop on whatever devices the host exposes:
replayable data pipeline, jitted sharded train step, rolling async
checkpoints, PPT-deadline straggler monitor, and crash-safe resume
(--resume restarts bit-identically from the latest checkpoint — the
data stream is a pure function of (seed, step)).

``--reduced`` swaps in the smoke-scale config (the container path);
full-scale runs use the same code with the production mesh.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, list_archs
from repro.configs.base import Shape
from repro.dist.sharding import ShardingRules, use_sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_optimizer
from repro.models.layers import unzip_params
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor
from repro.train.data import SyntheticStream
from repro.train.train_step import build_train_step, init_state


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (container-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", type=Path, default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    if args.reduced:
        from repro.configs.reduced import reduced
        spec = reduced(spec)
    fam, cfg = spec.family, spec.config

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rules = ShardingRules(mesh, spec.rules_for("train"))
    shape = Shape("cli", args.seq, args.batch, "train")
    stream = SyntheticStream(spec.input_specs(shape), spec.vocab,
                             seed=args.seed)

    optimizer = make_optimizer(spec, total_steps=args.steps)
    step_fn = build_train_step(
        lambda p, b: fam.loss_fn(p, b, cfg), optimizer,
        grad_accum=1, accum_dtype=spec.accum_dtype,
    )

    with use_sharding(rules):
        params = fam.init(jax.random.key(args.seed), cfg)
    values, axes = unzip_params(params)
    state = init_state(values, optimizer)
    jit_step = jax.jit(
        lambda s, b: __step_with_rules(step_fn, rules, s, b),
        donate_argnums=(0,),
    )

    mgr = None
    start_step = 0
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir)
        if args.resume:
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            got = mgr.restore_latest(abstract, rules)
            if got[0] is not None:
                start_step, state = got
                print(f"resumed from step {start_step}")

    monitor = StragglerMonitor(
        num_workers=1, predicted_step_s=10.0, slack=5.0)

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
        state, metrics = jit_step(state, batch)
        monitor.heartbeat(0, step)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            dec = monitor.check()
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time() - t0):.1f}s, deadline "
                  f"{dec.deadline_s:.1f}s, stragglers {dec.stragglers})")
        if mgr and step and step % args.checkpoint_every == 0:
            mgr.save(step + 1, state, _state_axes(axes, optimizer))
    if mgr:
        mgr.save(args.steps, state, _state_axes(axes, optimizer))
        mgr.wait()

    if not np.isfinite(losses[-1]):
        print("FAIL: non-finite final loss")
        return 1
    if len(losses) > 3 and losses[-1] >= losses[0]:
        print("WARN: loss did not decrease "
              f"({losses[0]:.4f} -> {losses[-1]:.4f})")
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


def __step_with_rules(step_fn, rules, state, batch):
    with use_sharding(rules):
        return step_fn(state, batch)


def _state_axes(param_axes, optimizer):
    from repro.train.train_step import TrainState
    return TrainState((), param_axes, optimizer.state_axes(param_axes))


if __name__ == "__main__":
    raise SystemExit(main())
