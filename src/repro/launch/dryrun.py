import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init.  This flag lives ONLY here — smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on
the production meshes and record the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Success of ``.lower().compile()`` for the 16x16 pod mesh AND the
2x16x16 multi-pod mesh is the deliverable; ``memory_analysis()`` proves
the cell fits 16 GB/chip, ``cost_analysis()`` + the HLO collective
parse feed EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch_id: str, shape_name: str, mesh_name: str,
             out_dir: Path = OUT_DIR) -> dict:
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape_name in spec.skip:
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": spec.skip[shape_name]}
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    t0 = time.time()
    cell = build_cell(spec, shape, mesh)
    lowered = lower_cell(cell)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(f"[{arch_id} x {shape_name} x {mesh_name}] "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print("  memory_analysis:", _mem_dict(mem))
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (
        cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)))

    from repro.analysis.buffers import bf16_legalization_overhead
    from repro.analysis.hlo import collective_summary
    from repro.analysis.hlo_cost import loop_aware_cost

    hlo_text = compiled.as_text()
    coll = collective_summary(hlo_text)
    bf16_overhead = bf16_legalization_overhead(hlo_text)
    t0 = time.time()
    aware = loop_aware_cost(hlo_text)
    print("  loop-aware: flops=%.3e bytes=%.3e ici=%.3e (%.1fs)" % (
        aware["flops"], aware["bytes"], aware["ici_bytes"],
        time.time() - t0))

    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "bf16_legalization_overhead_bytes": int(bf16_overhead),
        "cost": {k: v for k, v in cost.items()
                 if isinstance(v, (int, float)) and abs(v) > 0},
        "loop_aware_cost": aware,
        "collectives": coll,
        "param_count": spec.config.param_count,
        "active_param_count": spec.config.active_param_count,
    }
    _write(rec, out_dir)
    return rec


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
    }


def _write(rec: dict, out_dir: Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=2))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--out", type=Path, default=OUT_DIR)
    args = ap.parse_args(argv)

    assert jax.device_count() == 512, (
        "dry-run requires the 512-device XLA host platform flag"
    )
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch_id, shape_name in cells:
        for mesh_name in meshes:
            try:
                run_cell(arch_id, shape_name, mesh_name, args.out)
            except Exception:
                failures.append((arch_id, shape_name, mesh_name))
                traceback.print_exc()
            finally:
                jax.clear_caches()  # bound host RAM across 80 cells
    if failures:
        print("FAILED cells:", failures)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
