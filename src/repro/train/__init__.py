from repro.train.optimizer import Optimizer, adamw, adafactor
from repro.train.train_step import TrainState, build_train_step, init_state
from repro.train.schedule import constant, warmup_cosine

__all__ = [
    "Optimizer", "adamw", "adafactor",
    "TrainState", "build_train_step", "init_state",
    "constant", "warmup_cosine",
]
