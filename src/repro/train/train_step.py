"""The jitted train step: loss -> grads (with microbatch accumulation)
-> global-norm clip -> optimizer update.

Data parallelism and ZeRO sharding are *not* hand-written here: params
are FSDP-sharded by the logical-axis rules, so GSPMD inserts the
reduce-scatter/all-gather schedule for grads and the sharded optimizer
update.  Gradient accumulation is a ``lax.scan`` over microbatches —
the memory knob that keeps 95-layer training shapes inside 16 GB HBM.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def init_state(params, optimizer: Optimizer) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=optimizer.init(params),
    )


def _split_microbatches(batch, n: int):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def build_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    optimizer: Optimizer,
    *,
    grad_accum: int = 1,
    grad_clip: float = 1.0,
    accum_dtype=jnp.float32,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """``loss_fn(params, batch) -> scalar``.  Returns ``step_fn(state,
    batch) -> (state, metrics)`` ready for ``jax.jit``.

    ``accum_dtype=bfloat16`` halves the accumulator footprint — the
    arctic-480b memory-fit knob (fp32 accumulators alone would be
    7.5 GB/chip there)."""

    grad_fn = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        if grad_accum == 1:
            return grad_fn(params, batch)
        micro = _split_microbatches(batch, grad_accum)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params
        )

        def body(carry, mb):
            loss_sum, acc = carry
            loss, grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + (g / grad_accum).astype(accum_dtype),
                acc, grads
            )
            return (loss_sum + loss / grad_accum, acc), None

        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero), micro
        )
        return loss, grads

    def step_fn(state: TrainState, batch):
        loss, grads = compute_grads(state.params, batch)
        gnorm = global_norm(grads)
        if grad_clip:
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "param_norm": global_norm(new_params),
        }
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return step_fn
