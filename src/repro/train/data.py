"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step) so restarts resume
bit-identically from a checkpointed step — the fault-tolerance story
requires a replayable pipeline, not stateful iterators.  On a real
cluster each host materializes only its addressable shard via
``jax.make_array_from_callback`` (the shape math is identical).
"""
from __future__ import annotations

from typing import Any

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synthetic_batch(specs: dict[str, Any], vocab: int, *, seed: int,
                    step: int) -> dict[str, np.ndarray]:
    """Materialize a batch matching ``specs`` (ShapeDtypeStructs).

    Integer specs become uniform token ids in [0, vocab); float specs
    become unit normals (the modality-frontend stand-in)."""
    rng = _rng(seed, step)
    out = {}
    for name, sds in specs.items():
        if np.issubdtype(np.dtype(sds.dtype), np.integer):
            out[name] = rng.integers(
                0, vocab, size=sds.shape, dtype=np.dtype(sds.dtype)
            )
        else:
            out[name] = rng.standard_normal(sds.shape).astype(sds.dtype)
    return out


class SyntheticStream:
    """Replayable stream: ``stream.batch(step)`` for any step, any order."""

    def __init__(self, specs: dict[str, Any], vocab: int, seed: int = 0):
        self.specs = specs
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int) -> dict[str, np.ndarray]:
        return synthetic_batch(self.specs, self.vocab, seed=self.seed,
                               step=step)
