"""Optimizers as pure pytree transforms (no external deps).

* AdamW — fp32 moments, decoupled weight decay.
* Adafactor — factored second moment; the memory-fit choice for
  arctic-480b where AdamW's fp32 moments would exceed per-chip HBM
  (DESIGN.md §6).

Optimizer state mirrors the parameter tree leaf-for-leaf, so the
parameters' NamedShardings map onto the state (ZeRO-style sharded
optimizer for free — params are already FSDP-sharded over "data").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_state)
    state_axes: Callable[[Any], Any] = None
    # state_axes(param_axes_tree) -> logical-axes tree matching init()


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


def adamw(
    lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = sched(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), m, v

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    def state_axes(param_axes):
        return {"m": param_axes, "v": param_axes}

    return Optimizer("adamw", init, update, state_axes)


def adafactor(
    lr, decay: float = 0.8, eps: float = 1e-30, clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), no first moment, factored second
    moment for rank>=2 leaves: O(n+m) state instead of O(n·m)."""
    sched = _as_schedule(lr)

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = sched(step)

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps)
                )
                upd = g / (denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(upd * upd) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_state = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, new_state

    def state_axes(param_axes):
        def leaf(ax):
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}

        return jax.tree.map(
            leaf, param_axes,
            is_leaf=lambda t: isinstance(t, tuple)
            and all(isinstance(e, (str, type(None))) for e in t),
        )

    return Optimizer("adafactor", init, update, state_axes)
