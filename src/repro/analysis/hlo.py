"""HLO-text analysis: collective traffic extraction.

``compiled.as_text()`` is the post-SPMD, per-partition module, so every
shape below is a per-device shard and the byte counts are per-chip —
exactly the quantity the roofline collective term wants.

Traffic model (ring algorithms, bytes crossing a chip's links):
    all-reduce        2·(n-1)/n · result_bytes
    all-gather          (n-1)/n · result_bytes   (result = gathered size)
    reduce-scatter      (n-1)   · result_bytes   (operand = n · result)
    all-to-all          (n-1)/n · result_bytes
    collective-permute            result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_OP_RE = re.compile(
    r"=\s+(\(?[a-z0-9]+\[[^=]*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)


def _shape_bytes(text: str) -> list[int]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def _group_size(line: str, num_partitions: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(len(ids), 1)
    return num_partitions


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)       # op -> #instances
    result_bytes: dict = field(default_factory=dict)  # op -> Σ result bytes
    ici_bytes: float = 0.0                            # per-chip traffic model

    def as_dict(self) -> dict:
        return {
            "counts": dict(self.counts),
            "result_bytes": {k: int(v) for k, v in self.result_bytes.items()},
            "ici_bytes": int(self.ici_bytes),
        }


def _traffic(op: str, result_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if op == "all-gather":
        return (n - 1) / n * result_bytes
    if op == "reduce-scatter":
        return (n - 1) * result_bytes
    if op == "all-to-all":
        return (n - 1) / n * result_bytes
    return result_bytes  # collective-permute


def num_partitions(hlo_text: str) -> int:
    m = re.search(r"num_partitions=(\d+)", hlo_text)
    return int(m.group(1)) if m else 1


def collective_stats(hlo_text: str) -> CollectiveStats:
    parts = num_partitions(hlo_text)
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes_txt, op, started = m.group(1), m.group(2), m.group(3)
        sizes = _shape_bytes(shapes_txt)
        if not sizes:
            continue
        # async -start ops return (operand, result) tuples for
        # all-gather/permute — take the output (largest); all-reduce
        # tuples are independent reductions — sum them.
        rb = sum(sizes) if op == "all-reduce" else (
            max(sizes) if started else sum(sizes)
        )
        n = _group_size(line, parts)
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.result_bytes[op] = stats.result_bytes.get(op, 0) + rb
        stats.ici_bytes += _traffic(op, rb, n)
    return stats


def collective_summary(hlo_text: str) -> dict:
    return collective_stats(hlo_text).as_dict()


def op_histogram(hlo_text: str) -> dict[str, int]:
    """Instruction-name histogram — remat/redundancy forensics for the
    perf loop (duplicate dot shapes betray recompute)."""
    hist: dict[str, int] = {}
    for m in re.finditer(r"=\s+\(?[a-z0-9]+\[[^ ]*\]?[^ ]* ([a-z][a-z0-9-]*)\(",
                         hlo_text):
        op = m.group(1)
        hist[op] = hist.get(op, 0) + 1
    return hist
