"""Buffer forensics: largest per-partition tensors in an HLO module.

The dry-run's ``memory_analysis()`` gives only totals; when a cell
busts the 16 GB/chip budget this ranks the individual instruction
results so the offending tensor (and the sharding rule that failed to
divide it) is identifiable.  Used by the §Perf memory iterations.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.analysis.hlo_cost import parse_computations, _shape_elems_bytes


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclass(frozen=True)
class BufferInfo:
    bytes: int
    op: str
    name: str
    shape: str
    computation: str
    op_name: str = ""


def largest_buffers(hlo_text: str, top: int = 20,
                    min_bytes: int = 64 * 2**20) -> list[BufferInfo]:
    comps = parse_computations(hlo_text)
    out: list[BufferInfo] = []
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in ("parameter", "get-tuple-element", "tuple",
                          "bitcast"):
                continue
            if ins.bytes >= min_bytes:
                m = _OPNAME_RE.search(ins.rest)
                out.append(BufferInfo(
                    ins.bytes, ins.op, ins.name,
                    ins.shape_txt.strip()[:70], comp.name[:28],
                    m.group(1)[-90:] if m else "",
                ))
    out.sort(key=lambda b: -b.bytes)
    return out[:top]


def format_buffers(buffers: list[BufferInfo]) -> str:
    lines = []
    for b in buffers:
        lines.append(f"{b.bytes / 2**30:8.2f} GiB  {b.op:<20} "
                     f"{b.shape:<60} {b.computation}\n"
                     f"            ~ {b.op_name}")
    return "\n".join(lines)


def bf16_legalization_overhead(hlo_text: str,
                               min_bytes: int = 8 * 2**20) -> int:
    """Bytes the CPU backend *adds* by legalizing bf16 compute to f32.

    xla:cpu emulates bf16: internal bf16 values are upcast to f32
    (convert pairs at fusion boundaries), so bf16 temporaries occupy 2x
    their TPU size in the dry-run's memory_analysis.  This estimates the
    overstatement as half the bytes of every f32 tensor that is a
    ``convert`` of a bf16 operand, or a fusion whose fused computation
    converts a same-shaped bf16 input (the DUS-stack pattern).  The
    dry-run records both raw and adjusted figures (EXPERIMENTS.md
    §Dry-run documents the artifact with the probe).
    """
    comps = parse_computations(hlo_text)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([^\s,)]+)", ins.rest)
                if m:
                    fusion_bodies.add(m.group(1))
    overhead = 0
    for comp in comps.values():
        if comp.name in fusion_bodies:
            continue  # fusion internals are not allocations
        for ins in comp.instrs:
            if ins.bytes < min_bytes or "f32[" not in ins.shape_txt:
                continue
            if ins.op == "convert":
                ops = re.findall(r"%([A-Za-z0-9_.\-]+)", ins.rest)
                if ops and "bf16[" in comp.shapes.get(ops[0], ""):
                    overhead += ins.bytes // 2
            elif ins.op == "fusion":
                m = re.search(r"calls=%?([^\s,)]+)", ins.rest)
                body = comps.get(m.group(1)) if m else None
                if body is None:
                    continue
                dims = ins.shape_txt.split("[")[-1].split("]")[0]
                for sub in body.instrs:
                    if (sub.op == "convert"
                            and f"f32[{dims}]" in sub.shape_txt):
                        ops = re.findall(r"%([A-Za-z0-9_.\-]+)", sub.rest)
                        if ops and "bf16[" in body.shapes.get(ops[0], ""):
                            overhead += ins.bytes // 2
                            break
    return overhead
