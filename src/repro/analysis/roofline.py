"""Three-term roofline from the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory_s     = HLO_bytes_per_chip / HBM_bw
    collective_s = ici_bytes_per_chip / link_bw

(cost_analysis of the SPMD executable is already per-partition —
probe-verified — so the brief's "global / chips" form is identical.)

The step-time lower bound is max(terms) (perfect overlap); the roofline
fraction reported in §Perf is useful model FLOPs over that bound:

    fraction = (MODEL_FLOPS / chips / peak) / max(terms)

MODEL_FLOPS uses 6·N_active·tokens for training and 2·N_active·tokens
for inference (fwd-only), the standard accounting.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.hw.targets import TPU_V5E, TPUTarget


@dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_chip: float       # useful FLOPs per chip per step
    hlo_flops_chip: float
    chips: int
    useful_bytes_chip: float = 0.0  # args (params+caches) read once/step

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def t_step_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_compute_s(self) -> float:
        return self.model_flops_chip / TPU_V5E.peak_flops_bf16

    @property
    def roofline_fraction(self) -> float:
        b = self.t_step_bound_s
        return self.useful_compute_s / b if b else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat/redundancy/padding waste)."""
        return (self.model_flops_chip / self.hlo_flops_chip
                if self.hlo_flops_chip else 0.0)

    @property
    def memory_fraction(self) -> float:
        """For memory-bound kinds (decode): ideal-stream fraction — the
        time to read params+caches once over the achieved bound.  The
        compute-centric roofline_fraction is ~0 for decode by design;
        this is the bandwidth-utilization analog."""
        if not self.useful_bytes_chip:
            return 0.0
        ideal = self.useful_bytes_chip / TPU_V5E.hbm_bandwidth
        b = self.t_step_bound_s
        return ideal / b if b else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "kind": self.kind,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "t_step_bound_s": self.t_step_bound_s,
            "roofline_fraction": self.roofline_fraction,
            "flops_ratio": self.flops_ratio,
        }


def model_flops(kind: str, active_params: int, seq_len: int,
                global_batch: int) -> float:
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * active_params * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * active_params * tokens
    # decode: one token per sequence
    return 2.0 * active_params * global_batch


def from_record(rec: dict, target: TPUTarget = TPU_V5E) -> Roofline:
    """Build roofline terms from one launch/dryrun JSON record."""
    from repro.configs import SHAPES

    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "multipod" else 256
    flops = float(rec["cost"].get("flops", 0.0))
    bytes_acc = float(rec["cost"].get("bytes accessed", 0.0))
    ici = float(rec["collectives"]["ici_bytes"])
    mf = model_flops(rec["kind"], rec["active_param_count"],
                     shape.seq_len, shape.global_batch) / chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"],
        compute_s=flops / target.peak_flops_bf16,
        memory_s=bytes_acc / target.hbm_bandwidth,
        collective_s=ici / target.ici_bandwidth,
        model_flops_chip=mf,
        hlo_flops_chip=flops,
        chips=chips,
    )


def format_table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<9} {'bound':<11} "
           f"{'compute_s':>10} {'memory_s':>10} {'collect_s':>10} "
           f"{'t_bound_s':>10} {'roofl%':>7} {'useful%':>8} {'membw%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<20} {r.shape:<12} {r.mesh:<9} {r.bottleneck:<11} "
            f"{r.compute_s:>10.4g} {r.memory_s:>10.4g} "
            f"{r.collective_s:>10.4g} {r.t_step_bound_s:>10.4g} "
            f"{100 * r.roofline_fraction:>6.1f}% "
            f"{100 * r.flops_ratio:>7.1f}% "
            f"{100 * r.memory_fraction:>6.1f}%"
        )
    return "\n".join(lines)
