"""HLO -> basic-block-labeled memory trace — the paper's pipeline
pointed at the compiled XLA program.

PPT-Multicore's front end turns a ROSE-translated binary into a
BB-labeled memory trace via Byfl, then predicts cache behaviour from
reuse profiles.  Here the "program" is the post-SPMD HLO module: every
instruction is a single-entry/single-exit block (the BB analog), its
operand/result buffers are the memory references, while-loop trip
counts are the BB execution counts, and the *shared vs private* label
maps to replicated (weights) vs partitioned (activations) buffers.

The trace feeds the same PRD/CRD -> SDCM machinery to estimate the
VMEM residency of the compiled step (VMEM modeled as the paper's LLC,
see hw.targets.TPUTarget.vmem_cache_config), giving a reuse-aware
refinement of the roofline memory term: HBM traffic ~= (1 - P(hit)) x
touched bytes.

Tractability knobs (documented approximations):
* buffers emit at most ``refs_cap`` strided references (granule grows
  with buffer size) — same spirit as the paper's sampled traces;
* loops emit ``loop_cap`` iterations and the profile is scaled by
  trips/loop_cap (iterations are periodic; the first is cold).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.analysis.hlo_cost import (
    HloCostModel, _BODY_RE, _CALLS_RE, _OPERANDS_RE, _TRIP_RE,
    _shape_elems_bytes,
)
from repro.core.trace.types import LabeledTrace, trace_from_blocks


@dataclass
class _Buffer:
    base: int
    nbytes: int
    shared: bool  # replicated/parameter-like = shared (paper semantics)


class _TraceState:
    def __init__(self, granule: int, refs_cap: int):
        self.granule = granule
        self.refs_cap = refs_cap
        self.buffers: dict[str, _Buffer] = {}
        self.next_base = 1 << 12
        self.blocks: list[tuple[str, np.ndarray, np.ndarray]] = []
        self.touched_bytes = 0.0

    def buffer(self, name: str, nbytes: int, shared: bool) -> _Buffer:
        buf = self.buffers.get(name)
        if buf is None:
            base = self.next_base
            self.next_base += max(
                self.granule,
                ((nbytes + self.granule - 1) // self.granule) * self.granule,
            )
            buf = _Buffer(base, nbytes, shared)
            self.buffers[name] = buf
        return buf

    def refs_for(self, buf: _Buffer) -> np.ndarray:
        lines = max(1, buf.nbytes // self.granule)
        take = min(lines, self.refs_cap)
        idx = np.linspace(0, lines - 1, take).astype(np.int64)
        return buf.base + idx * self.granule


def hlo_to_trace(
    hlo_text: str,
    granule: int = 512,
    refs_cap: int = 16,
    loop_cap: int = 2,
    max_refs: int = 400_000,
) -> tuple[LabeledTrace, dict]:
    """Build the labeled trace of one executable step.

    Returns (trace, info) where info holds touched_bytes, the loop
    scaling factor applied, and per-label counts."""
    model = HloCostModel(hlo_text)
    state = _TraceState(granule, refs_cap)
    total_scale = {"applied": 1.0}

    entry_comp = model.comps.get(model.entry)
    entry_params = {
        ins.name for ins in (entry_comp.instrs if entry_comp else [])
        if ins.op == "parameter"
    }

    def emit(comp_name: str, prefix: str, depth: int):
        comp = model.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if len(state.blocks) * refs_cap > max_refs:
                return
            if ins.op in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "after-all"):
                continue
            if ins.op == "while":
                body = _BODY_RE.search(ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                trips = int(mt.group(1)) if mt else 1
                reps = min(trips, loop_cap)
                if body:
                    for it in range(reps):
                        emit(body.group(1), f"{prefix}/{ins.name}@{it}",
                             depth + 1)
                    if reps:
                        total_scale["applied"] = max(
                            total_scale["applied"], trips / reps)
                continue
            if ins.op in ("fusion", "call"):
                pass  # boundary refs below; internals don't touch HBM
            addrs, shared_mask = [], []
            operands = _OPERANDS_RE.findall(ins.rest.split(")")[0])
            for opnd in operands[:6]:
                shape_txt = comp.shapes.get(opnd, "")
                _, nbytes = _shape_elems_bytes(shape_txt)
                if nbytes <= 0:
                    continue
                shared = opnd in entry_params
                buf = state.buffer(f"{comp_name}/{opnd}", nbytes, shared)
                r = state.refs_for(buf)
                addrs.append(r)
                shared_mask.append(np.full(len(r), shared))
                state.touched_bytes += nbytes
            if ins.bytes > 0:
                buf = state.buffer(f"{comp_name}/{ins.name}", ins.bytes,
                                   False)
                r = state.refs_for(buf)
                addrs.append(r)
                shared_mask.append(np.full(len(r), False))
                state.touched_bytes += ins.bytes
            if addrs:
                state.blocks.append((
                    f"{ins.op}:{prefix}",
                    np.concatenate(addrs),
                    np.concatenate(shared_mask),
                ))

    emit(model.entry, "main", 0)
    trace = trace_from_blocks(state.blocks)
    info = {
        "touched_bytes": state.touched_bytes,
        "loop_scale": total_scale["applied"],
        "num_buffers": len(state.buffers),
        "num_blocks": len(state.blocks),
        "granule": granule,
    }
    return trace, info


def vmem_hit_rate(trace: LabeledTrace, granule: int = 512) -> float:
    """SDCM hit rate of the step's trace against the VMEM-as-LLC model."""
    from repro.core import sdcm
    from repro.core.reuse.profile import profile_from_trace
    from repro.hw.targets import TPU_V5E

    cfg = TPU_V5E.vmem_cache_config()
    prof = profile_from_trace(trace.addresses, granule)
    blocks = max(1, TPU_V5E.vmem_bytes // granule)
    return sdcm.hit_rate(prof, blocks, blocks)  # fully associative


def refined_memory_term(
    hbm_bytes: float, trace: LabeledTrace, granule: int = 512,
) -> dict:
    """Reuse-aware memory term: the flat roofline charges every touched
    byte to HBM; the paper's model discounts VMEM-resident reuse."""
    from repro.hw.targets import TPU_V5E

    p_hit = vmem_hit_rate(trace, granule)
    effective = hbm_bytes * (1.0 - p_hit) + hbm_bytes * p_hit * (
        TPU_V5E.hbm_bandwidth / 1e13)  # VMEM-hit bytes ~free vs HBM
    return {
        "vmem_hit_rate": p_hit,
        "flat_memory_s": hbm_bytes / TPU_V5E.hbm_bandwidth,
        "refined_memory_s": effective / TPU_V5E.hbm_bandwidth,
    }
