"""Loop-aware static HLO cost analysis — the Byfl analog for XLA.

``compiled.cost_analysis()`` counts a ``while`` body ONCE
(probe-verified), so every scan-over-layers / grad-accumulation cell
under-reports FLOPs, bytes and collective traffic by the trip count.
PPT-Multicore's methodology is static instrumentation (Byfl) that
counts ops per basic block times the block's execution count — this
module does precisely that on the optimized HLO: parse computations
(basic blocks), extract while-loop trip counts (execution counts), and
accumulate dot-exact FLOPs, fusion-boundary bytes, and ring-model
collective traffic, each multiplied by the enclosing loops' trips.

Costs:
* dot: 2 · result_elems · Π contracting dims (exact).
* fusion: FLOPs of the fused computation; bytes = operands + result
  (the fusion boundary is what touches HBM — better than
  cost_analysis' per-op accounting).
* elementwise/reduce: 1 FLOP per result (resp. operand) element.
* while: (body + condition) × trip_count, trips from the condition's
  ``compare(induction, constant)``.
* collectives: ring-model per-chip traffic (see repro.analysis.hlo).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.analysis.hlo import _DTYPE_BYTES, _group_size, _traffic

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_OPCALL_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([^\s,)]+)")
_BODY_RE = re.compile(r"body=%?([^\s,)]+)")
_COND_RE = re.compile(r"condition=%?([^\s,)]+)")
_OPERANDS_RE = re.compile(r"%([A-Za-z0-9_.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT_RE = re.compile(r"constant\((\d+)\)")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "custom-call", "get-dimension-size", "iota", "broadcast",
    "reshape", "transpose", "slice", "dynamic-slice", "dynamic-update-slice",
    "concatenate", "pad", "reverse", "gather", "scatter", "select-and-scatter",
    "convert", "reduce-precision", "rng", "rng-bit-generator", "domain",
    "opt-barrier", "send", "send-done", "recv", "recv-done", "infeed",
    "outfeed",
}
# data-movement ops above cost bytes (via fusion boundaries) but ~0 FLOPs.


def _shape_elems_bytes(shape_txt: str) -> tuple[list[int], int]:
    elems, total = [], 0
    for dtype, dims in _SHAPE_TOKEN.findall(shape_txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems.append(n)
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


@dataclass
class Instr:
    name: str
    shape_txt: str
    op: str
    rest: str
    elems: int
    bytes: int


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> shape_txt


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    transcendental: float = 0.0
    coll_counts: dict = field(default_factory=dict)
    coll_bytes: dict = field(default_factory=dict)
    op_flops: dict = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        self.transcendental += other.transcendental * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + v * mult
        for k, v in other.op_flops.items():
            self.op_flops[k] = self.op_flops.get(k, 0) + v * mult

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "ici_bytes": self.ici_bytes,
            "transcendental": self.transcendental,
            "collective_counts": {k: float(v) for k, v in self.coll_counts.items()},
            "collective_bytes": {k: float(v) for k, v in self.coll_bytes.items()},
            "dominant_flop_ops": dict(sorted(
                self.op_flops.items(), key=lambda kv: -kv[1])[:8]),
        }


def parse_computations(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and stripped.endswith("{"):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
            continue
        if stripped.startswith("}"):
            continue
        if current is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, tail = m.groups()
        mo = _OPCALL_RE.search(tail)
        if not mo:
            continue
        shape_txt, op, rest = tail[: mo.start()], mo.group(1), tail[mo.end():]
        elems_list, nbytes = _shape_elems_bytes(shape_txt)
        instr = Instr(name, shape_txt, op, rest, sum(elems_list), nbytes)
        current.instrs.append(instr)
        current.shapes[name] = shape_txt
    return comps


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.text = hlo_text
        self.comps = parse_computations(hlo_text)
        m = re.search(r"num_partitions=(\d+)", hlo_text)
        self.num_partitions = int(m.group(1)) if m else 1
        self._memo: dict[str, CostTotals] = {}
        entry = re.search(r"ENTRY\s+%?([^\s(]+)", hlo_text)
        self.entry = entry.group(1) if entry else next(iter(self.comps), None)

    # --- helpers ---------------------------------------------------------

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        consts = []
        for ins in comp.instrs:
            if ins.op == "constant":
                mm = re.match(r"\s*(\d+)\s*\)", ins.rest)
                if mm:
                    consts.append(int(mm.group(1)))
            mm = _CONSTANT_RE.search(ins.rest)
            if mm:
                consts.append(int(mm.group(1)))
        return float(max(consts)) if consts else 1.0

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        ops = _OPERANDS_RE.findall(ins.rest)
        contract = 1
        m = _CONTRACT_RE.search(ins.rest)
        if m and ops:
            lhs_shape_txt = comp.shapes.get(ops[0], "")
            dims_m = _SHAPE_TOKEN.search(lhs_shape_txt)
            if dims_m:
                dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(dims):
                        contract *= dims[idx]
        return 2.0 * ins.elems * contract

    # --- main ------------------------------------------------------------

    def computation_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        total = CostTotals()
        self._memo[name] = total  # break cycles defensively
        comp = self.comps.get(name)
        if comp is None:
            return total
        for ins in comp.instrs:
            if ins.op.endswith("-done"):
                continue  # async completion: payload counted at -start
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in _COLLECTIVES:
                sizes, _ = _shape_elems_bytes(ins.shape_txt)
                dts = [d for d, _ in _SHAPE_TOKEN.findall(ins.shape_txt)
                       if d in _DTYPE_BYTES]
                per = [e * _DTYPE_BYTES[d] for e, d in zip(sizes, dts)]
                if not per:
                    continue
                rb = sum(per) if base_op == "all-reduce" else (
                    max(per) if ins.op.endswith("-start") else sum(per))
                n = _group_size(ins.rest, self.num_partitions)
                total.coll_counts[base_op] = total.coll_counts.get(base_op, 0) + 1
                total.coll_bytes[base_op] = total.coll_bytes.get(base_op, 0) + rb
                total.ici_bytes += _traffic(base_op, rb, n)
                total.bytes += rb
                continue
            if ins.op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                mt = _TRIP_RE.search(ins.rest)  # XLA's own trip analysis
                if mt:
                    trips = float(mt.group(1))
                else:
                    trips = self._trip_count(cond.group(1)) if cond else 1.0
                if body:
                    total.add(self.computation_cost(body.group(1)), trips)
                if cond:
                    total.add(self.computation_cost(cond.group(1)), trips)
                continue
            if ins.op in ("fusion", "call", "map", "async-start"):
                m = _CALLS_RE.search(ins.rest)
                sub_ops = set()
                if m:
                    sub = self.computation_cost(m.group(1))
                    sub_no_bytes = CostTotals(
                        flops=sub.flops, ici_bytes=sub.ici_bytes,
                        transcendental=sub.transcendental,
                        coll_counts=dict(sub.coll_counts),
                        coll_bytes=dict(sub.coll_bytes),
                        op_flops=dict(sub.op_flops),
                    )
                    total.add(sub_no_bytes)
                    subc = self.comps.get(m.group(1))
                    if subc is not None:
                        sub_ops = {i.op for i in subc.instrs}
                # fusion-boundary HBM traffic model:
                # * in-place update fusions (fused DUS) touch only the
                #   update payload, not the aliased carry;
                # * fused slice/gather reads touch <= result bytes per
                #   oversized operand;
                # * otherwise: write result once, read operands once.
                operands = []
                for opnd in _OPERANDS_RE.findall(ins.rest.split(")")[0]):
                    _, b = _shape_elems_bytes(comp.shapes.get(opnd, ""))
                    operands.append(b)
                if "dynamic-update-slice" in sub_ops:
                    payload = sum(b for b in operands if b < ins.bytes)
                    total.bytes += 2.0 * payload
                elif sub_ops & {"dynamic-slice", "slice", "gather"}:
                    total.bytes += ins.bytes + sum(
                        min(b, max(ins.bytes, 1)) for b in operands
                    )
                else:
                    total.bytes += ins.bytes + sum(operands)
                continue
            if ins.op == "conditional":
                branches = _OPERANDS_RE.findall(ins.rest)
                costs = [self.computation_cost(b) for b in branches
                         if b in self.comps]
                if costs:
                    total.add(max(costs, key=lambda c: c.flops))
                continue
            if ins.op == "dot":
                f = self._dot_flops(comp, ins)
                total.flops += f
                total.op_flops["dot"] = total.op_flops.get("dot", 0) + f
                operand_bytes = 0
                for opnd in _OPERANDS_RE.findall(ins.rest.split(")")[0]):
                    _, b = _shape_elems_bytes(comp.shapes.get(opnd, ""))
                    operand_bytes += b
                total.bytes += operand_bytes + ins.bytes
                continue
            if ins.op == "convolution":
                # not used by this framework's models; approximate dense
                total.flops += 2.0 * ins.elems
                total.bytes += ins.bytes
                continue
            if ins.op == "dynamic-update-slice":
                ops = _OPERANDS_RE.findall(ins.rest.split(")")[0])
                upd = 0
                if len(ops) >= 2:
                    _, upd = _shape_elems_bytes(comp.shapes.get(ops[1], ""))
                total.bytes += 2.0 * (upd or ins.bytes / 8.0)
                continue
            if ins.op in ("reduce", "reduce-window"):
                total.flops += ins.elems * 4.0  # window/accumulate estimate
                total.op_flops["reduce"] = (
                    total.op_flops.get("reduce", 0) + ins.elems * 4.0)
                total.bytes += ins.bytes
                continue
            if ins.op in ("slice", "dynamic-slice", "gather", "concatenate",
                          "pad", "reverse", "copy", "transpose"):
                total.bytes += 2.0 * ins.bytes  # read + write result-sized
                continue
            if ins.op in ("exponential", "log", "power", "tanh", "logistic",
                          "sine", "cosine", "sqrt", "rsqrt", "divide"):
                total.flops += ins.elems
                total.transcendental += ins.elems
                total.bytes += 2.0 * ins.bytes
                continue
            if ins.op in _FREE_OPS:
                continue
            # generic elementwise (add/multiply/select/compare/...)
            total.flops += ins.elems
            total.bytes += 2.0 * ins.bytes
            total.op_flops["elementwise"] = (
                total.op_flops.get("elementwise", 0) + ins.elems)
        return total

    def entry_cost(self) -> CostTotals:
        # fusions/whiles are walked from the entry; non-entry computations
        # are only counted via their call sites (with trip multipliers).
        return self.computation_cost(self.entry)


def loop_aware_cost(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).entry_cost().as_dict()


def op_class_mix(cost: dict, elem_bytes: float = 8.0) -> dict:
    """Per-class instruction mix from a :func:`loop_aware_cost` dict —
    the ``OpCounts`` kwargs the in-core runtime models consume.

    HLO has no load/store split or integer-op census, so the mix is a
    principled approximation over elements moved (``bytes`` /
    ``elem_bytes``):

    * loads:stores split 2:1 — an elementwise HLO op reads ~two
      operands per result element and writes one;
    * one integer op per element moved stands in for the address/index
      arithmetic the scalar loop nest would carry;
    * transcendentals map to the slow-op (division/SFU) port.
    """
    elems = float(cost["bytes"]) / elem_bytes
    return {
        "int_ops": elems,
        "fp_ops": float(cost["flops"]),
        "div_ops": float(cost["transcendental"]),
        "loads": elems * 2.0 / 3.0,
        "stores": elems / 3.0,
        "total_bytes": float(cost["bytes"]),
    }
