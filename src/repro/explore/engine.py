"""Fused fitness evaluation for config search.

One `FusedSweepEvaluator` owns a `Session` plus device-resident packed
reuse profiles and scores arbitrary batches of `CandidateConfig`s
through `repro.api.batched.sweep_grid`: candidates are grouped by the
axes that change the *profile* (line size, cores, interleave strategy)
and everything else — geometry, latencies, betas — rides as traced
device arrays, so a whole agent round is a handful of jitted dispatches
regardless of how many configs it proposes.

Scores are "smaller is better":

* ``runtime``  — ECM-predicted seconds (needs `OpCounts`), chained on
  device from the same dispatch that produced the hit rates.
* ``llc_miss`` — the swept hierarchy's last-level miss fraction
  ``1 - P(hit at LLC)`` (cumulative convention), for workloads without
  operation counts.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import batched
from repro.api.session import Session
from repro.api.stages import shared_level_index
from repro.core.incore import timings_of
from repro.hw.targets import resolve_target

from .space import CandidateConfig, SearchSpace

OBJECTIVES = ("runtime", "llc_miss")


@dataclasses.dataclass
class SweepStats:
    """What the evaluator actually did — the ledger behind the
    "one fused invocation per row shape" benchmark claim."""

    sweeps: int = 0               # evaluate() calls
    configs_scored: int = 0       # rows evaluated (incl. re-proposals)
    fused_dispatches: int = 0     # jitted grid invocations issued
    kernel_compiles: int = 0      # NEW compile-cache entries triggered
    profile_groups: int = 0       # distinct (line, cores, strategy) packs

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    scores: np.ndarray            # [C] smaller is better
    rates: np.ndarray             # [C, L] per-level cumulative hit rates
    t_pred_s: np.ndarray | None   # [C] ECM runtime (None for llc_miss)


class FusedSweepEvaluator:
    """Score candidate configs for one workload via the fused sweep."""

    def __init__(self, source, space: SearchSpace, *, session=None,
                 counts=None, mode: str = "throughput",
                 objective: str | None = None, inner: str = "vmap",
                 seed: int = 0, window_size: int | None = None,
                 sampled: float | None = None):
        self.session = session if session is not None else Session(
            cache_model="batched"
        )
        self.source = source
        self.space = space
        self.base = resolve_target(space.target)
        self.level_idx = space.level_index(self.base)
        self.shared_idx = shared_level_index(self.base)
        self.mode = mode
        self.inner = inner
        self.seed = seed
        self.window_size = window_size
        self.sampled = sampled
        self.counts = (counts if counts is not None
                       else getattr(source, "op_counts", None))
        if objective is None:
            objective = "runtime" if self.counts is not None else "llc_miss"
        if objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {objective!r} (known: {OBJECTIVES})"
            )
        if objective == "runtime" and self.counts is None:
            raise ValueError(
                "objective 'runtime' needs op counts; this source has "
                "none — pass counts= or use objective='llc_miss'"
            )
        self.objective = objective
        self.timings = (timings_of(self.base)
                        if objective == "runtime" else None)
        self.stats = SweepStats()
        # (line_size, cores, strategy) -> (prd DeviceProfile, crd ...)
        self._packs: dict[tuple, tuple] = {}

    # --- profile packs -------------------------------------------------------

    def _pack(self, line_size: int, cores: int, strategy: str):
        key = (line_size, cores, strategy)
        hit = self._packs.get(key)
        if hit is not None:
            return hit
        art = self.session.artifacts(
            self.source, cores, strategy=strategy, seed=self.seed,
            line_size=line_size, window_size=self.window_size,
            sampled=self.sampled,
        )
        pack = (
            batched.pack_profile_device(art.prd),
            batched.pack_profile_device(art.crd),
        )
        self._packs[key] = pack
        self.stats.profile_groups += 1
        return pack

    # --- geometry staging ----------------------------------------------------

    def _geometry(self, configs: list[CandidateConfig],
                  line_size: int, cores: int) -> batched.SweepGeometry:
        base, li = self.base, self.level_idx
        c = len(configs)
        n_levels = len(base.levels)
        assoc = np.zeros((c, n_levels), np.float32)
        blocks = np.zeros((c, n_levels), np.float32)
        delta = np.zeros((c, n_levels), np.float32)
        tbeta = np.zeros((c, n_levels), np.float32)
        # non-swept columns depend only on the (fixed) group line size
        for lv, lvl in enumerate(base.levels):
            if lv == li:
                continue
            lines = max(lvl.size_bytes // line_size, 1)
            assoc[:, lv] = min(lvl.assoc, lines)
            blocks[:, lv] = lines
            delta[:, lv] = base.level_latency_cy[lv]
        # transfer beta of boundary i is the port INTO level i+1
        # (RAM for the last boundary) — `core/incore.py` convention
        for bi in range(n_levels):
            if bi == n_levels - 1:
                tbeta[:, bi] = base.ram_beta_cy
            else:
                tbeta[:, bi] = base.level_beta_cy[bi + 1]
        for ci, cfg in enumerate(configs):
            assoc[ci, li] = cfg.ways
            blocks[ci, li] = cfg.sets * cfg.ways
            delta[ci, li] = cfg.latency_cy
            if li >= 1:
                tbeta[ci, li - 1] = cfg.beta_cy
        return batched.SweepGeometry(
            assoc=assoc, blocks=blocks, trans_beta=tbeta, delta=delta,
            cores=np.full(c, float(cores), np.float32),
        )

    # --- evaluation ----------------------------------------------------------

    def evaluate(self, configs: list[CandidateConfig]) -> EvalResult:
        """Score a batch; results are order-aligned with ``configs``."""
        c = len(configs)
        n_levels = len(self.base.levels)
        rates = np.zeros((c, n_levels), np.float64)
        with_runtime = self.objective == "runtime"
        t_pred = np.zeros(c, np.float64) if with_runtime else None

        groups: dict[tuple, list[int]] = {}
        for ci, cfg in enumerate(configs):
            groups.setdefault(
                (cfg.line_size, cfg.cores, cfg.strategy), []
            ).append(ci)

        for (line, cores, strategy), idxs in groups.items():
            prd, crd = self._pack(line, cores, strategy)
            geom = self._geometry(
                [configs[i] for i in idxs], line, cores
            )
            res = batched.sweep_grid(
                prd, crd, geom,
                shared_idx=self.shared_idx,
                counts=self.counts if with_runtime else None,
                timings=self.timings,
                cycle_s=self.base.cycle_s,
                ram_delta=self.base.ram_latency_cy,
                mode=self.mode,
                inner=self.inner,
            )
            sel = np.asarray(idxs)
            rates[sel] = res.rates
            if with_runtime:
                t_pred[sel] = res.t_pred_s
            self.stats.fused_dispatches += res.dispatches
            self.stats.kernel_compiles += res.compiles
            self.session.stats.kernel_compiles += res.compiles

        self.stats.sweeps += 1
        self.stats.configs_scored += c
        scores = t_pred.copy() if with_runtime else 1.0 - rates[:, -1]
        return EvalResult(scores=scores, rates=rates, t_pred_s=t_pred)

    def scores(self, configs: list[CandidateConfig]) -> np.ndarray:
        return self.evaluate(configs).scores


__all__ = ["OBJECTIVES", "EvalResult", "FusedSweepEvaluator", "SweepStats"]
