"""Search agents over a `SearchSpace` — the ArchGym pattern with the
analytical model as the fitness function.

Agents are batch-oriented: each round proposes a LIST of candidate
configs and scores them through one fused-sweep call, so the device
amortizes an entire generation/neighborhood at once.  All agents run
against a `ScoreCache`, which dedups re-proposed configs (an evaluation
budget counts *unique* configs), enforces the budget, and logs every
round into the `Trajectory` that `repro.explore` persists.

* `RandomAgent`    — uniform search without replacement; the unbiased
  baseline and the exhaustive oracle when the budget covers the space.
* `HillClimbAgent` — automates `benchmarks/hillclimb.py`'s manual
  hypothesis->change->measure loop: score all single-axis neighbor
  moves of the incumbent in one batch, take the best strict improvement,
  random-restart at local optima.
* `GAAgent`        — generational GA (elitism + tournament selection +
  uniform crossover + per-axis mutation) over the axis index vectors.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .space import CandidateConfig, SearchSpace


@dataclasses.dataclass
class Trajectory:
    """Round-by-round search log (persisted via the ArtifactStore)."""

    agent: str
    seed: int
    rounds: list[dict] = dataclasses.field(default_factory=list)
    evaluations: int = 0
    best_score: float = math.inf
    best_config: CandidateConfig | None = None

    def to_json(self) -> dict:
        return {
            "agent": self.agent,
            "seed": self.seed,
            "evaluations": self.evaluations,
            "best_score": self.best_score,
            "best_config": (self.best_config.to_json()
                            if self.best_config else None),
            "rounds": self.rounds,
        }


class ScoreCache:
    """Budgeted, deduping front end to the fused evaluator."""

    def __init__(self, evaluate: Callable[[list[CandidateConfig]], np.ndarray],
                 budget: int, trajectory: Trajectory):
        self._evaluate = evaluate
        self.budget = int(budget)
        self.trajectory = trajectory
        self._scores: dict[tuple, float] = {}

    @property
    def remaining(self) -> int:
        return max(self.budget - self.trajectory.evaluations, 0)

    @property
    def exhausted(self) -> bool:
        return self.remaining == 0

    def known(self, cfg: CandidateConfig) -> bool:
        return cfg.key() in self._scores

    def score_of(self, cfg: CandidateConfig) -> float:
        return self._scores[cfg.key()]

    def top(self, k: int) -> list[tuple[tuple, float]]:
        return sorted(self._scores.items(), key=lambda kv: kv[1])[:k]

    def score(self, configs: list[CandidateConfig],
              tag: str) -> dict[tuple, float]:
        """Score a proposal batch; unseen configs beyond the remaining
        budget are silently dropped (the round records how many ran).
        Returns scores for every *scored* config in the proposal."""
        fresh: list[CandidateConfig] = []
        seen_keys: set[tuple] = set()
        for cfg in configs:
            k = cfg.key()
            if k in self._scores or k in seen_keys:
                continue
            seen_keys.add(k)
            fresh.append(cfg)
        fresh = fresh[: self.remaining]
        if fresh:
            scores = np.asarray(self._evaluate(fresh), dtype=np.float64)
            traj = self.trajectory
            for cfg, s in zip(fresh, scores):
                self._scores[cfg.key()] = float(s)
                traj.evaluations += 1
                if float(s) < traj.best_score:
                    traj.best_score = float(s)
                    traj.best_config = cfg
        self.trajectory.rounds.append({
            "tag": tag,
            "proposed": len(configs),
            "evaluated": len(fresh),
            "best_score": (None if math.isinf(self.trajectory.best_score)
                           else self.trajectory.best_score),
        })
        return {
            cfg.key(): self._scores[cfg.key()]
            for cfg in configs if cfg.key() in self._scores
        }


class Agent:
    """Base: subclasses drive `cache.score` until the budget is spent."""

    name = "agent"

    def params(self) -> dict:
        return {}

    def search(self, space: SearchSpace, cache: ScoreCache,
               rng: np.random.Generator) -> None:
        raise NotImplementedError


class RandomAgent(Agent):
    name = "random"

    def __init__(self, batch_size: int = 64):
        self.batch_size = int(batch_size)

    def params(self) -> dict:
        return {"batch_size": self.batch_size}

    def search(self, space, cache, rng) -> None:
        pool = space.configs()
        order = rng.permutation(len(pool))
        for lo in range(0, len(order), self.batch_size):
            if cache.exhausted:
                return
            batch = [pool[i] for i in order[lo:lo + self.batch_size]]
            cache.score(batch, tag=f"random[{lo // self.batch_size}]")


def _random_indices(space: SearchSpace,
                    rng: np.random.Generator) -> tuple[int, ...]:
    """One VALID index vector, rejection-sampled (spaces guarantee at
    least one valid config, and ways<=sets rejects at most a corner)."""
    sizes = space.axis_sizes()
    while True:
        idx = tuple(int(rng.integers(n)) for n in sizes)
        if space.config_from_indices(idx) is not None:
            return idx


def _neighbors(space: SearchSpace, idx: tuple[int, ...]) -> list[tuple]:
    out = []
    sizes = space.axis_sizes()
    for ax, n in enumerate(sizes):
        for step in (-1, 1):
            j = idx[ax] + step
            if 0 <= j < n:
                out.append(idx[:ax] + (j,) + idx[ax + 1:])
    return out


class HillClimbAgent(Agent):
    name = "hillclimb"

    def __init__(self, max_rounds: int = 1000):
        self.max_rounds = int(max_rounds)

    def params(self) -> dict:
        return {"max_rounds": self.max_rounds}

    def search(self, space, cache, rng) -> None:
        current = _random_indices(space, rng)
        restarts = 0
        for rnd in range(self.max_rounds):
            if cache.exhausted:
                return
            cur_cfg = space.config_from_indices(current)
            moves = [
                (idx, space.config_from_indices(idx))
                for idx in _neighbors(space, current)
            ]
            moves = [(idx, cfg) for idx, cfg in moves if cfg is not None]
            cache.score(
                [cur_cfg] + [cfg for _idx, cfg in moves],
                tag=f"climb[{rnd}]r{restarts}",
            )
            scored = [
                (cache.score_of(cfg), idx)
                for idx, cfg in moves if cache.known(cfg)
            ]
            here = (cache.score_of(cur_cfg)
                    if cache.known(cur_cfg) else math.inf)
            better = [(s, idx) for s, idx in scored if s < here]
            if better:
                current = min(better)[1]
            else:
                current = _random_indices(space, rng)
                restarts += 1


class GAAgent(Agent):
    name = "ga"

    def __init__(self, population: int = 24, elite: int = 4,
                 mutation: float = 0.2, tournament: int = 3,
                 max_generations: int = 1000):
        self.population = int(population)
        self.elite = int(elite)
        self.mutation = float(mutation)
        self.tournament = int(tournament)
        self.max_generations = int(max_generations)

    def params(self) -> dict:
        return {
            "population": self.population, "elite": self.elite,
            "mutation": self.mutation, "tournament": self.tournament,
            "max_generations": self.max_generations,
        }

    def _select(self, pop, fitness, rng) -> tuple[int, ...]:
        picks = rng.integers(len(pop), size=self.tournament)
        return pop[min(picks, key=lambda i: fitness[i])]

    def search(self, space, cache, rng) -> None:
        sizes = space.axis_sizes()
        pop = [_random_indices(space, rng) for _ in range(self.population)]
        for gen in range(self.max_generations):
            if cache.exhausted:
                return
            cfgs = [space.config_from_indices(i) for i in pop]
            cache.score([c for c in cfgs if c is not None],
                        tag=f"ga[{gen}]")
            fitness = [
                cache.score_of(c) if c is not None and cache.known(c)
                else math.inf
                for c in cfgs
            ]
            ranked = sorted(range(len(pop)), key=lambda i: fitness[i])
            nxt = [pop[i] for i in ranked[: self.elite]]
            while len(nxt) < self.population:
                pa = self._select(pop, fitness, rng)
                pb = self._select(pop, fitness, rng)
                child = tuple(
                    (pa if rng.random() < 0.5 else pb)[ax]
                    for ax in range(len(sizes))
                )
                child = tuple(
                    int(rng.integers(n)) if rng.random() < self.mutation
                    else child[ax]
                    for ax, n in enumerate(sizes)
                )
                if space.config_from_indices(child) is None:
                    child = _random_indices(space, rng)
                nxt.append(child)
            pop = nxt


AGENTS: dict[str, type[Agent]] = {
    RandomAgent.name: RandomAgent,
    HillClimbAgent.name: HillClimbAgent,
    GAAgent.name: GAAgent,
}


def make_agent(name: str, params: dict | None = None) -> Agent:
    if name not in AGENTS:
        raise ValueError(f"unknown agent {name!r} (known: {sorted(AGENTS)})")
    return AGENTS[name](**(params or {}))


__all__ = [
    "AGENTS",
    "Agent",
    "GAAgent",
    "HillClimbAgent",
    "RandomAgent",
    "ScoreCache",
    "Trajectory",
    "make_agent",
]
