"""Discrete hardware search space for `repro.explore`.

A `SearchSpace` names one swept cache level of a base target and the
discrete axes a candidate config can take.  The axis fields ARE the
schema: `SearchSpace.AXES` drives payload validation, the agents'
index-vector encoding, and the `tools/docs_check.py` check that
`docs/explore.md` documents exactly these axes.

Axes follow the paper's hardware-side knobs (Table 5 geometry plus the
Eq. 4–7 / ECM timing parameters):

* ``sets`` / ``ways`` — geometry of the swept level (capacity =
  sets x ways x line size; associativity = ways).
* ``line_sizes`` — the hierarchy-wide line size.  Reuse profiles are
  line-granular, so this axis changes the profile, not just the model:
  candidates are grouped per line size and each group amortizes one
  profile build.
* ``latency_cy`` / ``beta_cy`` — the swept level's access latency and
  the transfer beta of the boundary feeding it (`core/incore.py`
  convention; the beta axis is inert when sweeping L1 because LSU issue
  cost comes from the per-class port table).
* ``cores`` / ``strategies`` — OpenMP thread count and interleave
  strategy; these select which PRD/CRD profile pair scores the config.

Constraints: ``ways <= sets`` always (a way per set is the textbook
set-associative shape), ``ways <= A_MAX_LIMIT`` (the batched kernel's
lane cap), and optional ``min_size_bytes``/``max_size_bytes`` capacity
bounds on the swept level.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import ClassVar

from repro.api.batched import A_MAX_LIMIT
from repro.core.levels import CacheLevelConfig
from repro.hw.targets import resolve_target

INTERLEAVE_STRATEGIES = ("round_robin", "chunked", "uniform")


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    """One point of a `SearchSpace` — a concrete hardware config."""

    sets: int
    ways: int
    line_size: int
    latency_cy: float
    beta_cy: float
    cores: int
    strategy: str

    @property
    def size_bytes(self) -> int:
        return self.sets * self.ways * self.line_size

    def key(self) -> tuple:
        return dataclasses.astuple(self)

    def to_json(self) -> dict:
        out = dataclasses.asdict(self)
        out["size_bytes"] = self.size_bytes
        return out

    def levels(self, base, level_idx: int) -> tuple[CacheLevelConfig, ...]:
        """The candidate's cache hierarchy: the swept level takes this
        config's geometry, every level takes its line size."""
        out = []
        for li, lvl in enumerate(base.levels):
            if li == level_idx:
                out.append(CacheLevelConfig(
                    lvl.name, self.size_bytes, self.line_size, self.ways
                ))
            else:
                out.append(CacheLevelConfig(
                    lvl.name, lvl.size_bytes, self.line_size, lvl.assoc
                ))
        return tuple(out)

    def apply(self, base, level_idx: int):
        """A concrete target with this config substituted in — the
        sequential-oracle path (`Session.predict` on the result must
        score the config identically to the fused sweep)."""
        lats = list(base.level_latency_cy)
        lats[level_idx] = self.latency_cy
        betas = list(base.level_beta_cy)
        betas[level_idx] = self.beta_cy
        slug = (f"{self.sets}s{self.ways}w{self.line_size}b"
                f"{self.latency_cy:g}d{self.beta_cy:g}t")
        return dataclasses.replace(
            base,
            name=f"{base.name}~{base.levels[level_idx].name}={slug}",
            levels=self.levels(base, level_idx),
            level_latency_cy=tuple(lats),
            level_beta_cy=tuple(betas),
        )


def _tuple(values, cast) -> tuple:
    return tuple(cast(v) for v in values)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Discrete axes + constraints over one swept level of a target."""

    AXES: ClassVar[tuple[str, ...]] = (
        "sets", "ways", "line_sizes", "latency_cy", "beta_cy",
        "cores", "strategies",
    )

    target: str = "i7-5960X"
    level: str = "L3"
    sets: tuple[int, ...] = (1024, 4096, 16384)
    ways: tuple[int, ...] = (4, 8, 16)
    line_sizes: tuple[int, ...] = (64,)
    latency_cy: tuple[float, ...] = ()   # () -> base target's value
    beta_cy: tuple[float, ...] = ()      # () -> base target's value
    cores: tuple[int, ...] = (1,)
    strategies: tuple[str, ...] = ("round_robin",)
    min_size_bytes: int | None = None
    max_size_bytes: int | None = None

    def __post_init__(self):
        base = resolve_target(self.target)  # raises on unknown target
        li = self.level_index(base)
        object.__setattr__(self, "sets", _tuple(self.sets, int))
        object.__setattr__(self, "ways", _tuple(self.ways, int))
        object.__setattr__(self, "line_sizes", _tuple(self.line_sizes, int))
        object.__setattr__(
            self, "latency_cy",
            _tuple(self.latency_cy, float)
            or (float(base.level_latency_cy[li]),),
        )
        object.__setattr__(
            self, "beta_cy",
            _tuple(self.beta_cy, float) or (float(base.level_beta_cy[li]),),
        )
        object.__setattr__(self, "cores", _tuple(self.cores, int))
        object.__setattr__(self, "strategies", _tuple(self.strategies, str))
        self._validate(base)

    def _validate(self, base) -> None:
        for name in self.AXES:
            if not getattr(self, name):
                raise ValueError(f"search-space axis {name!r} is empty")
        for name in ("sets", "ways", "line_sizes", "cores"):
            bad = [v for v in getattr(self, name) if v < 1]
            if bad:
                raise ValueError(f"axis {name!r} has non-positive {bad}")
        if any(w > A_MAX_LIMIT for w in self.ways):
            raise ValueError(
                f"ways axis exceeds the batched kernel's "
                f"A_MAX={A_MAX_LIMIT}: {self.ways}"
            )
        for s in self.strategies:
            if s not in INTERLEAVE_STRATEGIES:
                raise ValueError(
                    f"unknown interleave strategy {s!r} "
                    f"(known: {INTERLEAVE_STRATEGIES})"
                )
        if any(c > base.cores for c in self.cores):
            raise ValueError(
                f"cores axis exceeds target {base.name!r}'s "
                f"{base.cores} cores: {self.cores}"
            )
        if not self.configs():
            raise ValueError(
                "search space has no valid configs (constraints "
                "eliminated every axis combination)"
            )

    # --- structure -----------------------------------------------------------

    def level_index(self, base=None) -> int:
        base = base if base is not None else resolve_target(self.target)
        for li, lvl in enumerate(base.levels):
            if lvl.name == self.level:
                return li
        raise ValueError(
            f"target {base.name!r} has no level {self.level!r} "
            f"(levels: {[lvl.name for lvl in base.levels]})"
        )

    def axes(self) -> dict[str, tuple]:
        return {name: getattr(self, name) for name in self.AXES}

    def axis_sizes(self) -> tuple[int, ...]:
        return tuple(len(v) for v in self.axes().values())

    @property
    def raw_size(self) -> int:
        n = 1
        for s in self.axis_sizes():
            n *= s
        return n

    def config_from_indices(self, idx) -> CandidateConfig | None:
        """The config at one index vector, or None where constraints
        reject it.  ``cores == 1`` canonicalizes the strategy axis (a
        single core has nothing to interleave), so distinct index
        vectors may alias one config — agents dedup on `key()`."""
        vals = {
            name: axis[i]
            for (name, axis), i in zip(self.axes().items(), idx)
        }
        sets, ways = vals["sets"], vals["ways"]
        if ways > sets:
            return None
        size = sets * ways * vals["line_sizes"]
        if self.min_size_bytes is not None and size < self.min_size_bytes:
            return None
        if self.max_size_bytes is not None and size > self.max_size_bytes:
            return None
        cores = vals["cores"]
        strategy = vals["strategies"] if cores > 1 else self.strategies[0]
        return CandidateConfig(
            sets=sets, ways=ways, line_size=vals["line_sizes"],
            latency_cy=vals["latency_cy"], beta_cy=vals["beta_cy"],
            cores=cores, strategy=strategy,
        )

    def configs(self) -> list[CandidateConfig]:
        """Every valid config, deterministic order, aliases deduped."""
        seen: set[tuple] = set()
        out: list[CandidateConfig] = []
        for idx in itertools.product(
            *(range(n) for n in self.axis_sizes())
        ):
            cfg = self.config_from_indices(idx)
            if cfg is None or cfg.key() in seen:
                continue
            seen.add(cfg.key())
            out.append(cfg)
        return out

    @property
    def size(self) -> int:
        return len(self.configs())

    # --- (de)serialization ---------------------------------------------------

    def to_json(self) -> dict:
        out = {"target": self.target, "level": self.level}
        out.update({k: list(v) for k, v in self.axes().items()})
        if self.min_size_bytes is not None:
            out["min_size_bytes"] = self.min_size_bytes
        if self.max_size_bytes is not None:
            out["max_size_bytes"] = self.max_size_bytes
        return out

    @classmethod
    def from_json(cls, payload: dict) -> "SearchSpace":
        if not isinstance(payload, dict):
            raise ValueError("search space payload must be an object")
        known = set(cls.AXES) | {
            "target", "level", "min_size_bytes", "max_size_bytes",
        }
        unknown = sorted(set(payload) - known)
        if unknown:
            raise ValueError(
                f"unknown search-space keys {unknown} "
                f"(known: {sorted(known)})"
            )
        return cls(**payload)


__all__ = [
    "INTERLEAVE_STRATEGIES",
    "CandidateConfig",
    "SearchSpace",
]
