"""Top-level explore driver: search + trajectory persistence.

`run_explore` keys each (workload fingerprint, space, agent, budget,
seed, objective) search by a stable hash and persists the full result —
best config, top-k table, round-by-round trajectory, sweep stats —
under the ArtifactStore's ``explore`` kind.  A warm re-run with the
same key returns the stored result with ZERO recomputation: no profile
builds, no kernel dispatches, no agent rounds.
"""
from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.api.session import Session

from .agents import ScoreCache, Trajectory, make_agent
from .engine import FusedSweepEvaluator
from .space import CandidateConfig, SearchSpace

TOP_K = 10


def explore_key(fingerprint: str, space: SearchSpace, agent: str,
                agent_params: dict, budget: int, seed: int,
                objective: str, mode: str, inner: str) -> str:
    """Stable store key over everything that determines the result."""
    blob = json.dumps({
        "fingerprint": fingerprint,
        "space": space.to_json(),
        "agent": agent,
        "agent_params": agent_params,
        "budget": budget,
        "seed": seed,
        "objective": objective,
        "mode": mode,
        "inner": inner,
    }, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


def run_explore(source, space: SearchSpace, *, agent: str = "hillclimb",
                agent_params: dict | None = None, budget: int = 128,
                seed: int = 0, session=None, counts=None,
                mode: str = "throughput", objective: str | None = None,
                inner: str = "vmap", workload: str | None = None,
                refresh: bool = False) -> dict:
    """Search ``space`` for the best config of ``source``.

    Returns a JSON-serializable result dict; ``result["cached"]`` says
    whether it came straight from the ArtifactStore.
    """
    if session is None:
        session = Session(cache_model="batched")
    agent_obj = make_agent(agent, agent_params)
    fingerprint = session.identify(source)
    evaluator = FusedSweepEvaluator(
        source, space, session=session, counts=counts, mode=mode,
        objective=objective, inner=inner, seed=seed,
    )
    key = explore_key(
        fingerprint, space, agent_obj.name, agent_obj.params(),
        budget, seed, evaluator.objective, mode, inner,
    )
    store = session.store
    if store is not None and not refresh:
        cached = store.get_json("explore", key)
        if cached is not None:
            return {**cached, "cached": True}

    trajectory = Trajectory(agent=agent_obj.name, seed=seed)
    cache = ScoreCache(evaluator.scores, budget, trajectory)
    agent_obj.search(space, cache, np.random.default_rng(seed))

    best = trajectory.best_config
    if best is None:
        raise RuntimeError("explore finished without scoring any config")
    detail = evaluator.evaluate([best])
    level_names = [lvl.name for lvl in evaluator.base.levels]
    result = {
        "key": key,
        "workload": workload or getattr(source, "name", type(source).__name__),
        "fingerprint": fingerprint,
        "space": space.to_json(),
        "space_size": space.size,
        "agent": agent_obj.name,
        "agent_params": agent_obj.params(),
        "budget": budget,
        "seed": seed,
        "objective": evaluator.objective,
        "mode": mode,
        "inner": inner,
        "best": {
            "config": best.to_json(),
            "score": trajectory.best_score,
            "hit_rates": dict(zip(level_names, detail.rates[0].tolist())),
            "t_pred_s": (float(detail.t_pred_s[0])
                         if detail.t_pred_s is not None else None),
        },
        "top": [
            {"config": CandidateConfig(*k).to_json(), "score": s}
            for k, s in cache.top(TOP_K)
        ],
        "trajectory": trajectory.to_json(),
        "stats": evaluator.stats.to_json(),
    }
    if store is not None:
        store.put_json("explore", key, result)
    return {**result, "cached": False}


__all__ = ["TOP_K", "explore_key", "run_explore"]
