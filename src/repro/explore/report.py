"""Best-configs report generation (ArchGym-style viz, markdown/JSON).

`write_result` lands each search under ``experiments/results/
explore_<workload>__<agent>__<key>.json``; `render_markdown` turns a
list of results into the table that `python -m repro.explore
--update-doc` splices into `docs/explore.md` between the GENERATED
markers.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

GENERATED_BEGIN = "<!-- explore:generated:begin -->"
GENERATED_END = "<!-- explore:generated:end -->"

_SLUG_RE = re.compile(r"[^A-Za-z0-9_.-]+")


def _slug(name: str) -> str:
    return _SLUG_RE.sub("-", name).strip("-")


def result_path(result: dict, out_dir: Path) -> Path:
    tag = (f"explore_{_slug(result['workload'])}"
           f"__{result['agent']}__{result['key'][:8]}")
    return Path(out_dir) / f"{tag}.json"


def write_result(result: dict, out_dir) -> Path:
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = result_path(result, out_dir)
    path.write_text(json.dumps(result, indent=2, default=float) + "\n")
    return path


def _fmt_config(cfg: dict) -> str:
    kib = cfg["size_bytes"] / 1024
    cap = f"{kib / 1024:g} MiB" if kib >= 1024 else f"{kib:g} KiB"
    return (f"{cfg['sets']}x{cfg['ways']}w/{cfg['line_size']}B ({cap}), "
            f"d={cfg['latency_cy']:g}cy b={cfg['beta_cy']:g}cy, "
            f"{cfg['cores']}c {cfg['strategy']}")


def _fmt_score(result: dict, score: float) -> str:
    if result["objective"] == "runtime":
        return f"{score:.3e} s"
    return f"{score:.4f} miss"


def render_markdown(results: list[dict]) -> str:
    """One summary row per search plus a top-configs table each."""
    lines = [
        "| workload | agent | space | evals | best config | best score |",
        "|---|---|---|---|---|---|",
    ]
    for r in results:
        traj = r["trajectory"]
        lines.append(
            f"| `{r['workload']}` | {r['agent']} | {r['space_size']} "
            f"configs | {traj['evaluations']}/{r['budget']} "
            f"| {_fmt_config(r['best']['config'])} "
            f"| {_fmt_score(r, r['best']['score'])} |"
        )
    for r in results:
        lines += [
            "",
            f"### `{r['workload']}` — {r['agent']} "
            f"(objective: {r['objective']})",
            "",
            "| rank | config | score |",
            "|---|---|---|",
        ]
        for rank, row in enumerate(r["top"][:5], start=1):
            lines.append(
                f"| {rank} | {_fmt_config(row['config'])} "
                f"| {_fmt_score(r, row['score'])} |"
            )
        stats = r["stats"]
        lines += [
            "",
            f"{stats['configs_scored']} configs scored in "
            f"{stats['fused_dispatches']} fused dispatches "
            f"({stats['kernel_compiles']} new kernel compilations, "
            f"{stats['profile_groups']} profile packs).",
        ]
    return "\n".join(lines) + "\n"


def update_doc(doc_path, results: list[dict]) -> None:
    """Replace the GENERATED section of ``docs/explore.md`` in place."""
    doc_path = Path(doc_path)
    text = doc_path.read_text()
    if GENERATED_BEGIN not in text or GENERATED_END not in text:
        raise ValueError(
            f"{doc_path} is missing the explore:generated markers"
        )
    head, rest = text.split(GENERATED_BEGIN, 1)
    _old, tail = rest.split(GENERATED_END, 1)
    body = render_markdown(results)
    doc_path.write_text(
        head + GENERATED_BEGIN + "\n" + body + GENERATED_END + tail
    )


__all__ = [
    "GENERATED_BEGIN",
    "GENERATED_END",
    "render_markdown",
    "result_path",
    "update_doc",
    "write_result",
]
