"""`repro.explore` — design-space autotuning over the fused SDCM+ECM
sweep (`repro.api.batched.sweep_grid`).

    from repro.explore import SearchSpace, run_explore
    result = run_explore(workload, SearchSpace(sets=(1024, 4096)),
                         agent="hillclimb", budget=256)

CLI: ``python -m repro.explore --workload polybench/atax ...``
Service: ``POST /explore`` (see `repro.service`).
"""
from .agents import AGENTS, GAAgent, HillClimbAgent, RandomAgent, make_agent
from .engine import OBJECTIVES, FusedSweepEvaluator, SweepStats
from .runner import explore_key, run_explore
from .space import INTERLEAVE_STRATEGIES, CandidateConfig, SearchSpace

__all__ = [
    "AGENTS",
    "CandidateConfig",
    "FusedSweepEvaluator",
    "GAAgent",
    "HillClimbAgent",
    "INTERLEAVE_STRATEGIES",
    "OBJECTIVES",
    "RandomAgent",
    "SearchSpace",
    "SweepStats",
    "explore_key",
    "make_agent",
    "run_explore",
]
