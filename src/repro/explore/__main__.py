"""CLI for the config-space autotuner.

    python -m repro.explore --workload polybench/atx --budget 256 \
        --agent hillclimb --artifact-dir .explore-cache
    python -m repro.explore --workload polybench/atx --agent all \
        --space '{"sets": [512, 2048, 8192], "ways": [4, 8, 16]}' \
        --update-doc
    python -m repro.explore --smoke --artifact-dir .explore-cache

Results land in ``experiments/results/explore_*.json``; ``--update-doc``
splices the best-configs report into ``docs/explore.md``.  Smoke mode
is the CI gate: on a seeded space it asserts that the random and
hill-climb agents recover the exhaustively-verified best config and
that a warm re-run serves the whole search from the ArtifactStore with
zero recomputation.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.api.session import Session
from repro.workloads import registry

from .agents import AGENTS
from .report import render_markdown, update_doc, write_result
from .runner import run_explore
from .space import SearchSpace

SMOKE_SPACE = {
    "sets": [256, 1024, 4096, 16384],
    "ways": [2, 4, 8],
    "latency_cy": [20.0, 36.0, 60.0],
    "cores": [1, 2],
}


def _session(artifact_dir: str | None) -> Session:
    if artifact_dir and artifact_dir.lower() != "none":
        return Session(cache_model="batched", artifact_dir=artifact_dir)
    return Session(cache_model="batched")


def run_smoke(artifact_dir: str, seed: int) -> int:
    """The CI assertion: agents recover the known best; warm re-runs
    recompute nothing."""
    name = "polybench/atx"
    space = SearchSpace.from_json(SMOKE_SPACE)
    workload = registry.resolve(name, "smoke")
    session = _session(artifact_dir)

    # exhaustive oracle: the random agent with the full space as budget
    n = space.size
    oracle = run_explore(
        workload, space, agent="random", budget=n, seed=seed,
        session=session, workload=name, refresh=True,
    )
    assert oracle["trajectory"]["evaluations"] == n, oracle["trajectory"]
    best_score = oracle["best"]["score"]
    print(f"smoke: exhaustive best over {n} configs: "
          f"{best_score:.4e} ({oracle['best']['config']})")

    failures = []
    for agent, budget in (("random", n), ("hillclimb", max(n // 2, 16))):
        res = run_explore(
            workload, space, agent=agent, budget=budget, seed=seed,
            session=session, workload=name, refresh=True,
        )
        got = res["best"]["score"]
        ok = got <= best_score * (1 + 1e-12)
        print(f"smoke: {agent} (budget {budget}) best {got:.4e} "
              f"after {res['trajectory']['evaluations']} evals — "
              f"{'OK' if ok else 'MISSED'}")
        if not ok:
            failures.append(
                f"{agent} missed the known-best config "
                f"({got:.6e} > {best_score:.6e})"
            )

    # warm re-run: a FRESH session must answer from the store alone
    warm = _session(artifact_dir)
    res = run_explore(
        workload, space, agent="hillclimb",
        budget=max(n // 2, 16), seed=seed,
        session=warm, workload=name,
    )
    stats = warm.stats
    recomputed = (stats.profile_builds + stats.rd_builds
                  + stats.kernel_compiles)
    if not res.get("cached"):
        failures.append("warm re-run was not served from the store")
    if recomputed:
        failures.append(
            f"warm re-run recomputed work: {stats}"
        )
    print(f"smoke: warm re-run cached={res.get('cached')} "
          f"session stats {stats}")

    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if not failures:
        print("OK: agents recover the known best and warm re-runs "
              "recompute nothing")
    return 1 if failures else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.explore")
    ap.add_argument("--workload", default="polybench/atx",
                    help="registry workload name (polybench/atx, "
                         "model/llama3_8b/decode, ...)")
    ap.add_argument("--sizes", default=None,
                    help="workload size preset (registry presets; "
                         "default: the workload's default sizes)")
    ap.add_argument("--agent", default="hillclimb",
                    help=f"search agent: {', '.join(sorted(AGENTS))}, "
                         "or 'all'")
    ap.add_argument("--budget", type=int, default=256,
                    help="max unique configs to evaluate (default 256)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--space", default=None,
                    help="search-space JSON (inline or @file); axes "
                         "default to the built-in L3 sweep")
    ap.add_argument("--objective", default=None,
                    choices=["runtime", "llc_miss"],
                    help="fitness (default: runtime when the workload "
                         "declares op counts, else llc_miss)")
    ap.add_argument("--mode", default="throughput",
                    choices=["throughput", "latency"],
                    help="ECM combination mode for the runtime objective")
    ap.add_argument("--inner", default="vmap", choices=["vmap", "pallas"],
                    help="sweep inner evaluator (pallas = the "
                         "repro.kernels.sdcm kernel; TPU-oriented)")
    ap.add_argument("--artifact-dir", default=".explore-cache",
                    help="ArtifactStore dir for profiles + trajectories "
                         "('none' disables persistence)")
    ap.add_argument("--out", default="experiments/results",
                    help="directory for explore_*.json results")
    ap.add_argument("--update-doc", action="store_true",
                    help="splice the best-configs report into "
                         "docs/explore.md")
    ap.add_argument("--doc", default="docs/explore.md")
    ap.add_argument("--refresh", action="store_true",
                    help="ignore stored trajectories and re-search")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: known-best recovery + warm-store "
                         "zero-recompute assertions")
    args = ap.parse_args(argv)

    if args.smoke:
        if not args.artifact_dir or args.artifact_dir.lower() == "none":
            ap.error("--smoke needs --artifact-dir (the zero-recompute "
                     "assertion is about the shared store)")
        return run_smoke(args.artifact_dir, args.seed)

    if args.space:
        raw = args.space
        if raw.startswith("@"):
            with open(raw[1:]) as fh:
                raw = fh.read()
        space = SearchSpace.from_json(json.loads(raw))
    else:
        space = SearchSpace()

    agents = sorted(AGENTS) if args.agent == "all" else [args.agent]
    for a in agents:
        if a not in AGENTS:
            ap.error(f"unknown agent {a!r} (known: {sorted(AGENTS)})")

    try:
        name = registry.canonical_name(args.workload)
    except KeyError as exc:
        ap.error(str(exc.args[0] if exc.args else exc))
    session = _session(args.artifact_dir)
    workload = registry.resolve(name, args.sizes, store=session.store)

    results = []
    for agent in agents:
        res = run_explore(
            workload, space, agent=agent, budget=args.budget,
            seed=args.seed, session=session, mode=args.mode,
            objective=args.objective, inner=args.inner,
            workload=name, refresh=args.refresh,
        )
        path = write_result(res, args.out)
        print(f"[{agent}] cached={res['cached']} "
              f"evals={res['trajectory']['evaluations']}/{args.budget} "
              f"best={res['best']['score']:.4e} -> {path}")
        results.append(res)

    if args.update_doc:
        update_doc(args.doc, results)
        print(f"updated {args.doc}")
    else:
        print(render_markdown(results))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
