"""``model/<arch>/<step>`` workloads: HLO-derived labeled traces.

``ModelTraceSource`` lowers one model step (prefill / decode / train of
a ``configs/`` architecture at its reduced smoke shape) to optimized
HLO with plain ``jax.jit`` on abstract operands — no mesh, no device
allocation — then feeds the text through the existing offline
analyzers: ``analysis/hlo_trace`` emits the granule-labeled memory
trace (entry parameters = weights = shared across mimicked cores),
``analysis/hlo_cost`` supplies Byfl-style OpCounts for the runtime
model, and ``analysis/buffers`` records the liveness-dominating
buffers for provenance.

Lowering is the expensive step (~2s per cell), so everything derived
from it is persisted in the ArtifactStore's ``workload`` kind keyed by
the declared fingerprint: a warm store answers ``op_counts`` and
``refs`` without ever invoking XLA, and the Session only materializes
the trace on a profile-store miss.

XLA's scheduling is deterministic for a fixed (jaxlib, config, shape)
tuple — the same cell lowers to bit-identical HLO across processes —
which is what lets a *declared* fingerprint stand in for the trace
content hash.  ``jax.__version__`` is folded into the fingerprint so a
toolchain upgrade invalidates cleanly, and
``Session(verify_fingerprints=True)`` cross-checks the recorded
``trace_content_id`` whenever the trace is rebuilt.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.trace.types import LabeledTrace

# Bump when lowering or trace extraction changes trace content for the
# same (arch, step) — declared fingerprints hash this.  "2": op_counts
# now carry the per-class op_class_mix (int/load/store split), and the
# store's workload meta must not serve the old fp/loads-only counts.
MODEL_TRACE_VERSION = "2"

STEPS = ("prefill", "decode", "train")

# HLO granule / cap defaults — chosen so smoke-shape steps stay in the
# few-thousand-reference regime the validation harness expects.
GRANULE = 512
REFS_CAP = 16
LOOP_CAP = 2


def arch_slug(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


class ModelTraceSource:
    """TraceSource for one (arch, step) cell.

    Satisfies the stage-1 protocol (``trace()``) plus the registry's
    declared-source extensions (``workload_name`` /
    ``declared_fingerprint`` attrs set by ``resolve()``,
    ``attach_store`` for warm-path metadata).
    """

    def __init__(self, arch_id: str, step: str, *, granule: int = GRANULE,
                 refs_cap: int = REFS_CAP, loop_cap: int = LOOP_CAP):
        if step not in STEPS:
            raise ValueError(f"unknown model step {step!r} (one of {STEPS})")
        self.arch_id = arch_id
        self.step = step
        self.granule = granule
        self.refs_cap = refs_cap
        self.loop_cap = loop_cap
        self.workload_name = f"model/{arch_slug(arch_id)}/{step}"
        self.declared_fingerprint: str | None = None
        self._store = None
        self._trace = None
        self._op_counts = None
        self._info: dict | None = None

    # --- registry/store integration ---------------------------------------

    def attach_store(self, store) -> None:
        self._store = store

    def _store_meta(self) -> dict | None:
        if self._store is None or not self.declared_fingerprint:
            return None
        return self._store.get_json("workload", self.declared_fingerprint)

    def _put_store_meta(self, meta: dict) -> None:
        if self._store is None or not self.declared_fingerprint:
            return
        merged = dict(self._store_meta() or {})
        merged.update(meta)
        self._store.put_json("workload", self.declared_fingerprint, merged)

    # --- lowering ----------------------------------------------------------

    def lowered_hlo(self) -> str:
        """Optimized HLO text of the step (compiles the cell)."""
        import jax
        import jax.numpy as jnp

        from repro.configs.reduced import (
            SMOKE_DECODE, SMOKE_PREFILL, SMOKE_SHAPE, reduced_arch,
        )
        from repro.models.layers import unzip_params

        spec = reduced_arch(self.arch_id)
        fam, cfg = spec.family, spec.config
        shape = {"train": SMOKE_SHAPE, "prefill": SMOKE_PREFILL,
                 "decode": SMOKE_DECODE}[self.step]
        aparams, _ = unzip_params(jax.eval_shape(
            lambda k: fam.init(k, cfg), jax.random.key(0)
        ))
        batch = spec.input_specs(shape)

        if self.step == "train":
            def fn(p, b):
                return jax.value_and_grad(lambda q: fam.loss_fn(q, b, cfg))(p)
            args = (aparams, batch)
        else:
            acaches = jax.eval_shape(
                lambda: fam.init_caches(cfg, **spec.cache_kwargs(shape))
            )
            if self.step == "prefill":
                def fn(p, b, c):
                    return fam.prefill(p, b, cfg, c)
                args = (aparams, batch, acaches)
            else:
                def fn(p, b, c, n):
                    return fam.decode_step(p, b, cfg, c, n)
                args = (aparams, batch, acaches,
                        jax.ShapeDtypeStruct((), jnp.int32))
        return jax.jit(fn).lower(*args).compile().as_text()

    def _lower(self) -> None:
        from repro.analysis.buffers import largest_buffers
        from repro.analysis.hlo_cost import loop_aware_cost, op_class_mix
        from repro.analysis.hlo_trace import hlo_to_trace
        from repro.core.runtime_model import OpCounts
        from repro.workloads.tracegen import ELEM

        hlo = self.lowered_hlo()
        trace, info = hlo_to_trace(
            hlo, granule=self.granule, refs_cap=self.refs_cap,
            loop_cap=self.loop_cap,
        )
        cost = loop_aware_cost(hlo)
        # per-class mix (loads/stores split, addressing int ops,
        # transcendental -> div port) — the instruction-aware runtime
        # models need every class populated, not just fp/loads
        self._op_counts = OpCounts(**op_class_mix(cost, elem_bytes=ELEM))
        buffers = largest_buffers(hlo, top=8, min_bytes=0)
        self._info = {
            "touched_bytes": info.get("touched_bytes"),
            "loop_scale": info.get("loop_scale"),
            "num_buffers": info.get("num_buffers"),
            "num_blocks": info.get("num_blocks"),
            "granule": self.granule,
            "top_buffers": [
                {"bytes": b.bytes, "op": b.op, "name": b.name}
                for b in buffers
            ],
        }
        self._trace = trace
        self._put_store_meta({
            "workload": self.workload_name,
            "arch": self.arch_id,
            "step": self.step,
            "refs": len(trace),
            "op_counts": {
                "int_ops": self._op_counts.int_ops,
                "fp_ops": self._op_counts.fp_ops,
                "div_ops": self._op_counts.div_ops,
                "loads": self._op_counts.loads,
                "stores": self._op_counts.stores,
                "total_bytes": self._op_counts.total_bytes,
            },
            **self._info,
        })

    # --- stage-1 protocol ---------------------------------------------------

    def trace(self) -> "LabeledTrace":
        if self._trace is None:
            self._lower()
        return self._trace

    @property
    def op_counts(self):
        """OpCounts for the runtime model; served from the store's
        workload meta when warm (no lowering)."""
        if self._op_counts is None:
            meta = self._store_meta()
            if meta and "op_counts" in meta:
                from repro.core.runtime_model import OpCounts
                self._op_counts = OpCounts(**meta["op_counts"])
            else:
                self._lower()
        return self._op_counts

    @property
    def info(self) -> dict:
        if self._info is None:
            meta = self._store_meta()
            if meta and "touched_bytes" in meta:
                self._info = {k: meta.get(k) for k in (
                    "touched_bytes", "loop_scale", "num_buffers",
                    "num_blocks", "granule", "top_buffers")}
            else:
                self._lower()
        return self._info


def fingerprint_kwargs(arch_id: str, step: str, *, granule: int = GRANULE,
                       refs_cap: int = REFS_CAP,
                       loop_cap: int = LOOP_CAP) -> dict:
    """Everything that pins the trace bytes of a model cell."""
    import jax

    return {
        "arch": arch_id,
        "step": step,
        "granule": granule,
        "refs_cap": refs_cap,
        "loop_cap": loop_cap,
        "model_trace_version": MODEL_TRACE_VERSION,
        "jax": jax.__version__,
    }


def register_model_workloads(registry) -> None:
    """Register model/<slug>/<step> for every configured architecture.

    All size presets resolve to the reduced smoke shapes (the full
    shapes' traces are the dry-run's job), so every preset shares one
    fingerprint and one artifact set per cell.  The raw arch id
    (``model/llama3-8b/decode``) stays routable as an alias wherever
    it differs from the slug.
    """
    from repro.configs import list_archs
    from repro.workloads.registry import WorkloadSpec

    for arch_id in list_archs():
        slug = arch_slug(arch_id)
        for step in STEPS:
            def build(sizes, _arch=arch_id, _step=step):
                return ModelTraceSource(_arch, _step)

            def size_kwargs(sizes, _arch=arch_id, _step=step):
                return fingerprint_kwargs(_arch, _step)

            aliases = ()
            if slug != arch_id:
                aliases = (f"model/{arch_id}/{step}",)
            registry.register(WorkloadSpec(
                name=f"model/{slug}/{step}",
                build=build,
                size_kwargs=size_kwargs,
                presets=("smoke", "validation", "validation-xl",
                         "validation-xxl"),
                aliases=aliases,
                version=MODEL_TRACE_VERSION,
                description=f"{arch_id} {step} step via HLO lowering",
            ))
