"""First-class workload registry — the stage-1 fan-in.

Every trace source the pipeline can consume registers here under a
namespaced name:

    polybench/<abbr>      Table-4 analytic generators (polybench.py)
    synthetic/<kind>      tracegen-built parametric access patterns
    model/<arch>/<step>   HLO-derived model-step traces (model_trace.py)

and a resolved workload carries a **declared fingerprint** — a stable
content key computed from (name, generator version, resolved size
kwargs) WITHOUT materializing the trace.  ``Session``/``ArtifactStore``
key every derived artifact on that fingerprint, so a warm store serves
a registered workload's whole grid with zero trace builds (and, for
model workloads, zero XLA lowerings).  The fingerprint's honesty is
checked two ways: the Session records each materialized trace's
``trace_content_id`` in the store's ``workload`` meta (and cross-checks
it under ``Session(verify_fingerprints=True)``), and the CI
validation-smoke job runs a matrix twice across processes asserting
zero rebuilds on run 2.

Legacy spellings stay routable: every polybench entry aliases its bare
Table-4 abbreviation (``"atx"`` -> ``polybench/atx``), so existing
service payloads and CLI invocations keep working.

Registration is lazy: ``polybench`` registers on its own import (the
``MAKERS`` shim), and the first ``resolve()``/``names()`` call pulls in
the remaining namespaces.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Callable

# Bump when a generator's trace content changes for the same resolved
# kwargs — declared fingerprints are only honest while (name, version,
# kwargs) pins the trace bytes.
GENERATOR_VERSION = "1"


@dataclass(frozen=True)
class WorkloadSpec:
    """One registry entry: how to name, fingerprint, and build a source.

    ``build(sizes)`` returns the trace source (anything ``Session``
    accepts); ``size_kwargs(sizes)`` returns the canonical kwargs that
    preset resolves to — the fingerprint hashes those, so two presets
    resolving to the same kwargs share one fingerprint (and therefore
    one artifact set).
    """

    name: str
    build: Callable[[str | None], object]
    size_kwargs: Callable[[str | None], dict]
    presets: tuple[str, ...] = ()
    aliases: tuple[str, ...] = ()
    version: str = GENERATOR_VERSION
    description: str = ""

    @property
    def namespace(self) -> str:
        return self.name.split("/", 1)[0]

    def fingerprint(self, sizes: str | None) -> str:
        blob = json.dumps(
            {"name": self.name, "version": self.version,
             "kwargs": self.size_kwargs(sizes)},
            sort_keys=True, default=str,
        )
        return hashlib.sha1(blob.encode()).hexdigest()[:16]


class WorkloadRegistry:
    """Name -> WorkloadSpec map with alias routing.

    Not thread-locked: registration happens at import time and lookups
    are dict reads; concurrent resolvers (the service) wrap their own
    cache in a lock.
    """

    def __init__(self):
        self._specs: dict[str, WorkloadSpec] = {}
        self._aliases: dict[str, str] = {}

    # --- registration ------------------------------------------------------

    def register(self, spec: WorkloadSpec) -> WorkloadSpec:
        if "/" not in spec.name:
            raise ValueError(
                f"workload name {spec.name!r} must be namespaced "
                "(<namespace>/<name>)"
            )
        if spec.name in self._specs or spec.name in self._aliases:
            raise ValueError(f"workload {spec.name!r} already registered")
        for alias in spec.aliases:
            taken = self._aliases.get(alias)
            if (alias in self._specs) or (taken and taken != spec.name):
                raise ValueError(
                    f"alias {alias!r} for {spec.name!r} already taken"
                )
        self._specs[spec.name] = spec
        for alias in spec.aliases:
            self._aliases[alias] = spec.name
        return spec

    # --- lookup ------------------------------------------------------------

    def canonical(self, name: str) -> str:
        """Canonical registry name for ``name`` (which may be an alias);
        KeyError with the roster if unknown."""
        if name in self._specs:
            return name
        if name in self._aliases:
            return self._aliases[name]
        raise KeyError(
            f"unknown workload {name!r} (choose from {self.names()} "
            f"or a legacy alias {sorted(self._aliases)})"
        )

    def spec(self, name: str) -> WorkloadSpec:
        return self._specs[self.canonical(name)]

    def names(self, namespace: str | None = None) -> list[str]:
        out = sorted(self._specs)
        if namespace:
            out = [n for n in out if n.startswith(namespace + "/")]
        return out

    def aliases(self) -> dict[str, str]:
        return dict(self._aliases)

    def resolve(self, name: str, sizes: str | None = None, *,
                store=None):
        """Build one workload source with its declared fingerprint set.

        ``sizes`` must be one of the spec's declared presets (or None
        for defaults).  ``store`` is forwarded to sources that cache
        derived metadata on disk (``ModelTraceSource.attach_store``) so
        warm resolutions need zero trace materializations.
        """
        spec = self.spec(name)
        if sizes is not None and sizes not in spec.presets:
            raise ValueError(
                f"unknown size preset {sizes!r} for {spec.name!r} "
                f"(choose from {sorted(spec.presets)} or omit for "
                "defaults)"
            )
        source = spec.build(sizes)
        source.workload_name = spec.name
        source.declared_fingerprint = spec.fingerprint(sizes)
        if store is not None and hasattr(source, "attach_store"):
            source.attach_store(store)
        return source


REGISTRY = WorkloadRegistry()

_POPULATED = False


def _ensure_populated() -> None:
    """Import the registering modules once (idempotent)."""
    global _POPULATED
    if _POPULATED:
        return
    _POPULATED = True
    from repro.workloads import polybench  # noqa: F401  registers on import
    from repro.workloads import model_trace

    _register_synthetics(REGISTRY)
    model_trace.register_model_workloads(REGISTRY)


def register(spec: WorkloadSpec) -> WorkloadSpec:
    return REGISTRY.register(spec)


def resolve(name: str, sizes: str | None = None, *, store=None):
    _ensure_populated()
    return REGISTRY.resolve(name, sizes, store=store)


def canonical_name(name: str) -> str:
    _ensure_populated()
    return REGISTRY.canonical(name)


def workload_names(namespace: str | None = None) -> list[str]:
    _ensure_populated()
    return REGISTRY.names(namespace)


def workload_aliases() -> dict[str, str]:
    _ensure_populated()
    return REGISTRY.aliases()


def declared_fingerprint(name: str, sizes: str | None = None) -> str:
    """Fingerprint without building the source at all."""
    _ensure_populated()
    return REGISTRY.spec(name).fingerprint(sizes)


# --- synthetic namespace -----------------------------------------------------
#
# Parametric tracegen patterns: not paper workloads, but the reference
# inputs for cache-model sanity checks (a stream has no reuse inside
# the footprint; a stride-loop has exact periodic reuse).  They share
# the polybench preset names so matrix specs can mix namespaces.

_SYNTH_SIZES = {
    "stream": {None: dict(elems=8192, passes=2),
               "validation-xxl": dict(elems=524288, passes=2),
               "validation-xl": dict(elems=65536, passes=2),
               "validation": dict(elems=4096, passes=2),
               "smoke": dict(elems=1024, passes=2)},
    "stride": {None: dict(elems=4096, stride=8, passes=4),
               "validation-xxl": dict(elems=262144, stride=8, passes=4),
               "validation-xl": dict(elems=32768, stride=8, passes=4),
               "validation": dict(elems=2048, stride=8, passes=4),
               "smoke": dict(elems=512, stride=8, passes=4)},
}


def _make_synthetic(kind: str, **kw):
    import numpy as np

    from repro.core.runtime_model import OpCounts
    from repro.workloads.polybench import ELEM, Workload
    from repro.workloads.tracegen import AddressSpace, TraceBuilder

    elems, passes = kw["elems"], kw["passes"]
    sp = AddressSpace()
    A = sp.array("A", elems)

    def build():
        tb = TraceBuilder()
        if kind == "stream":
            idx = np.arange(elems)
        else:
            stride = kw["stride"]
            idx = (np.arange(elems) * stride) % elems
        for _ in range(passes):
            for lo in range(0, elems, 64):
                tb.instance(f"synth.{kind}", [(A.addr(idx[lo:lo + 64]), True)])
        return tb.build()

    n = elems * passes
    counts = OpCounts(fp_ops=n, int_ops=n, loads=n, total_bytes=n * ELEM)
    return Workload(f"SYNTH-{kind.upper()}", kind, "Synthetic", build, counts)


def _register_synthetics(registry: WorkloadRegistry) -> None:
    for kind, presets in _SYNTH_SIZES.items():
        def build(sizes, _kind=kind, _presets=presets):
            return _make_synthetic(_kind, **_presets.get(sizes, _presets[None]))

        def size_kwargs(sizes, _kind=kind, _presets=presets):
            return dict(_presets.get(sizes, _presets[None]), kind=_kind)

        registry.register(WorkloadSpec(
            name=f"synthetic/{kind}",
            build=build,
            size_kwargs=size_kwargs,
            presets=("smoke", "validation", "validation-xl",
                     "validation-xxl"),
            description=f"tracegen {kind} pattern",
        ))
