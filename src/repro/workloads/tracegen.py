"""Synthetic-trace construction helpers — the ROSE/Byfl stand-in.

The paper instruments the ROSE-translated binary with a modified Byfl
to capture the BB-labeled trace of the ``OUT__*`` parallel functions.
This container has no LLVM toolchain, so each workload ships an
*analytic* trace generator that emits exactly the address stream its
parallel section's loop nest performs (same program order, same 8-byte
element granularity, one BB instance per parallelized-loop iteration).
DESIGN.md §7 records this substitution.

Shared labeling: OpenMP shared variables (scalars AND shared arrays —
they are accessed through the translated ``shared_struct`` pointers)
keep their addresses across mimicked cores; everything else is
per-core-offset by Algorithm 1.
"""
from __future__ import annotations

import numpy as np

from repro.core.trace.types import LabeledTrace, trace_from_blocks

ELEM = 8  # sizeof(double)


class ArrayHandle:
    def __init__(self, name: str, base: int, shape: tuple[int, ...], shared: bool):
        self.name = name
        self.base = base
        self.shape = shape
        self.shared = shared
        self.strides = np.array(
            [int(np.prod(shape[i + 1 :], dtype=np.int64)) for i in range(len(shape))],
            dtype=np.int64,
        )

    def addr(self, *idx) -> np.ndarray:
        """Vectorized address computation; idx components broadcast."""
        idx = [np.asarray(i, dtype=np.int64) for i in idx]
        assert len(idx) == len(self.shape), (self.name, len(idx), self.shape)
        off = np.zeros((), dtype=np.int64)
        for i, s in zip(idx, self.strides):
            off = off + i * s
        return self.base + off * ELEM

    @property
    def size_bytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * ELEM


class AddressSpace:
    """Lays arrays out contiguously with page-aligned bases."""

    def __init__(self, align: int = 4096):
        self.align = align
        self._next = align
        self.arrays: dict[str, ArrayHandle] = {}

    def array(self, name: str, *shape: int, shared: bool = True) -> ArrayHandle:
        h = ArrayHandle(name, self._next, shape, shared)
        self.arrays[name] = h
        self._next += ((h.size_bytes + self.align - 1) // self.align) * self.align
        return h

    @property
    def total_bytes(self) -> int:
        return sum(h.size_bytes for h in self.arrays.values())


class TraceBuilder:
    """Collects (bb_name, addresses, shared_mask) instances."""

    def __init__(self):
        self.blocks: list[tuple[str, np.ndarray, np.ndarray]] = []

    def instance(self, name: str, refs: list[tuple[np.ndarray, bool]]) -> None:
        """One dynamic BB instance; refs = [(addresses, shared), ...] in
        program order (each addresses entry may be scalar or vector)."""
        addr_parts, shared_parts = [], []
        for addrs, shared in refs:
            a = np.atleast_1d(np.asarray(addrs, dtype=np.int64)).ravel()
            addr_parts.append(a)
            shared_parts.append(np.full(len(a), shared, dtype=bool))
        self.blocks.append(
            (name, np.concatenate(addr_parts), np.concatenate(shared_parts))
        )

    def interleaved_instance(
        self, name: str, ref_groups: list[tuple[np.ndarray, bool]]
    ) -> None:
        """Like ``instance`` but round-robins the groups element-wise —
        models ``for j: load A[i][j]; load x[j]`` inner-loop ordering."""
        arrays = [np.atleast_1d(np.asarray(a, np.int64)).ravel() for a, _ in ref_groups]
        shareds = [s for _, s in ref_groups]
        L = max(len(a) for a in arrays)
        addr_cols, shared_cols = [], []
        for a, s in zip(arrays, shareds):
            pad = np.full(L, -1, dtype=np.int64)
            pad[: len(a)] = a
            addr_cols.append(pad)
            shared_cols.append(np.full(L, s, dtype=bool))
        addrs = np.stack(addr_cols, axis=1).ravel()
        mask = np.stack(shared_cols, axis=1).ravel()
        keep = addrs >= 0
        self.blocks.append((name, addrs[keep], mask[keep]))

    def build(self) -> LabeledTrace:
        return trace_from_blocks(self.blocks)
