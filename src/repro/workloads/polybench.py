"""Table-4 benchmark suite (PolyBench/OpenMP + PARSEC blackscholes),
re-implemented as (a) analytic trace generators for the parallel
sections — the ROSE/Byfl stand-in (see tracegen.py), (b) Byfl-style
OpCounts, and (c) JAX reference kernels.

Input sizes are scaled down from the paper's standard inputs (their
traces run 7–335 GB; DESIGN.md §7 records the substitution) but keep
the exact loop structure, shared/private labeling, and per-iteration
BB instances of the Grauer-Gray OpenMP implementations, so reuse
behaviour per-set is faithful.

Each parallel-for iteration is one dynamic BB instance — Algorithm 1
splits instances across cores (static schedule) and offsets private
references; arrays accessed through the shared struct stay shared.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.runtime_model import OpCounts
from repro.core.trace.types import LabeledTrace
from repro.workloads.tracegen import AddressSpace, TraceBuilder

ELEM = 8


@dataclass
class Workload:
    name: str
    abbr: str
    domain: str
    build_trace: Callable[[], LabeledTrace]
    op_counts: OpCounts
    jax_fn: Callable | None = None
    jax_args: Callable | None = None

    def trace(self) -> LabeledTrace:
        return self.build_trace()


def _counts(fp=0.0, ints=0.0, divs=0.0, loads=0.0, stores=0.0) -> OpCounts:
    return OpCounts(
        int_ops=ints, fp_ops=fp, div_ops=divs, loads=loads, stores=stores,
        total_bytes=(loads + stores) * ELEM,
    )


# --- linear algebra ------------------------------------------------------------


def make_atax(n: int = 96) -> Workload:
    """A^T·(A·x): two parallel-for sections over rows."""
    sp = AddressSpace()
    A = sp.array("A", n, n)
    x = sp.array("x", n)
    tmp = sp.array("tmp", n)
    y = sp.array("y", n)

    def build():
        tb = TraceBuilder()
        j = np.arange(n)
        for i in range(n):
            tb.interleaved_instance(
                f"atax.tmp.{0}", [(A.addr(i, j), True), (x.addr(j), True)]
            )
            tb.instance("atax.tmp_w", [(tmp.addr(i), True)])
        for i in range(n):
            tb.interleaved_instance(
                "atax.y", [(A.addr(i, j), True), (tmp.addr(np.full(n, i)), True)]
            )
            tb.instance("atax.y_w", [(y.addr(i), True)])
        return tb.build()

    counts = _counts(fp=4 * n * n, ints=2 * n * n,
                     loads=4 * n * n, stores=2 * n)

    def jax_fn(A, x):
        return A.T @ (A @ x)

    def jax_args(key):
        import jax
        kA, kx = jax.random.split(key)
        return (jax.random.normal(kA, (n, n)), jax.random.normal(kx, (n,)))

    return Workload("ATAX", "atx", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_bicg(n: int = 96) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)
    p = sp.array("p", n)
    r = sp.array("r", n)
    q = sp.array("q", n)
    s = sp.array("s", n)

    def build():
        tb = TraceBuilder()
        j = np.arange(n)
        for i in range(n):
            tb.interleaved_instance(
                "bicg.q", [(A.addr(i, j), True), (p.addr(j), True)]
            )
            tb.instance("bicg.q_w", [(q.addr(i), True)])
        for jj in range(n):
            tb.interleaved_instance(
                "bicg.s", [(A.addr(np.arange(n), jj), True), (r.addr(np.arange(n)), True)]
            )
            tb.instance("bicg.s_w", [(s.addr(jj), True)])
        return tb.build()

    counts = _counts(fp=4 * n * n, ints=2 * n * n,
                     loads=4 * n * n, stores=2 * n)

    def jax_fn(A, p, r):
        return A @ p, A.T @ r

    def jax_args(key):
        import jax
        k1, k2, k3 = jax.random.split(key, 3)
        return (jax.random.normal(k1, (n, n)), jax.random.normal(k2, (n,)),
                jax.random.normal(k3, (n,)))

    return Workload("BICG", "bcg", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_mvt(n: int = 128) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)
    x1 = sp.array("x1", n)
    x2 = sp.array("x2", n)
    y1 = sp.array("y1", n)
    y2 = sp.array("y2", n)

    def build():
        tb = TraceBuilder()
        j = np.arange(n)
        for i in range(n):
            tb.instance("mvt.x1r", [(x1.addr(i), True)])
            tb.interleaved_instance(
                "mvt.x1", [(A.addr(i, j), True), (y1.addr(j), True)]
            )
            tb.instance("mvt.x1w", [(x1.addr(i), True)])
        for i in range(n):
            tb.instance("mvt.x2r", [(x2.addr(i), True)])
            tb.interleaved_instance(
                "mvt.x2", [(A.addr(j, i), True), (y2.addr(j), True)]
            )
            tb.instance("mvt.x2w", [(x2.addr(i), True)])
        return tb.build()

    counts = _counts(fp=4 * n * n, ints=2 * n * n,
                     loads=4 * n * n + 2 * n, stores=2 * n)

    def jax_fn(A, x1, x2, y1, y2):
        return x1 + A @ y1, x2 + A.T @ y2

    def jax_args(key):
        import jax
        ks = jax.random.split(key, 5)
        return (jax.random.normal(ks[0], (n, n)),) + tuple(
            jax.random.normal(k, (n,)) for k in ks[1:]
        )

    return Workload("MVT", "mvt", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_2mm(n: int = 40) -> Workload:
    """D = alpha*A*B*C + beta*D (two matrix multiplies)."""
    sp = AddressSpace()
    A = sp.array("A", n, n)
    B = sp.array("B", n, n)
    C = sp.array("C", n, n)
    D = sp.array("D", n, n)
    tmp = sp.array("tmp", n, n)

    def build():
        tb = TraceBuilder()
        k = np.arange(n)
        for i in range(n):
            for j in range(n):
                tb.interleaved_instance(
                    "2mm.tmp", [(A.addr(i, k), True), (B.addr(k, j), True)]
                )
                tb.instance("2mm.tmp_w", [(tmp.addr(i, j), True)])
        for i in range(n):
            for j in range(n):
                tb.interleaved_instance(
                    "2mm.D", [(tmp.addr(i, k), True), (C.addr(k, j), True)]
                )
                tb.instance("2mm.D_w", [(D.addr(i, j), True)])
        return tb.build()

    counts = _counts(fp=4 * n ** 3 + 3 * n * n, ints=2 * n ** 3,
                     loads=4 * n ** 3, stores=2 * n * n)

    def jax_fn(A, B, C, D):
        return 1.5 * (A @ B) @ C + 1.2 * D

    def jax_args(key):
        import jax
        ks = jax.random.split(key, 4)
        return tuple(jax.random.normal(k, (n, n)) for k in ks)

    return Workload("2MM", "2mm", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_symm(n: int = 48) -> Workload:
    """Symmetric matrix multiply C = alpha·A·B + beta·C (A symmetric)."""
    sp = AddressSpace()
    A = sp.array("A", n, n)
    B = sp.array("B", n, n)
    C = sp.array("C", n, n)

    def build():
        tb = TraceBuilder()
        for i in range(n):
            for j in range(n):
                k = np.arange(i)
                if len(k):
                    tb.interleaved_instance(
                        "symm.acc",
                        [(A.addr(i, k), True), (B.addr(k, j), True),
                         (C.addr(k, j), True)],
                    )
                tb.instance("symm.w", [
                    (A.addr(i, i), True), (B.addr(i, j), True),
                    (C.addr(i, j), True),
                ])
        return tb.build()

    counts = _counts(fp=3 * n * n * n / 2 + 4 * n * n,
                     ints=n * n * n, loads=1.5 * n ** 3, stores=n * n)

    def jax_fn(A, B, C):
        sym = jnp_tril_sym(A)
        return 1.5 * sym @ B + 1.2 * C

    def jax_args(key):
        import jax
        ks = jax.random.split(key, 3)
        return tuple(jax.random.normal(k, (n, n)) for k in ks)

    return Workload("SYMM", "smm", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def jnp_tril_sym(A):
    import jax.numpy as jnp

    L = jnp.tril(A)
    return L + L.T - jnp.diag(jnp.diag(A))


def make_doitgen(nq: int = 16, nr: int = 16, npp: int = 16) -> Workload:
    """Multi-resolution analysis kernel: sum[r,q,p] = A[r,q,s]·C4[s,p]."""
    sp = AddressSpace()
    A = sp.array("A", nr, nq, npp)
    C4 = sp.array("C4", npp, npp)
    s = sp.array("sum", nr, nq, npp)

    def build():
        tb = TraceBuilder()
        ss = np.arange(npp)
        for r in range(nr):
            for q in range(nq):
                for p in range(npp):
                    tb.interleaved_instance(
                        "doitgen.acc",
                        [(A.addr(r, q, ss), True), (C4.addr(ss, p), True)],
                    )
                    tb.instance("doitgen.w", [(s.addr(r, q, p), True)])
                tb.instance("doitgen.copy", [
                    (s.addr(r, q, np.arange(npp)), True),
                    (A.addr(r, q, np.arange(npp)), True),
                ])
        return tb.build()

    total = nr * nq * npp * npp
    counts = _counts(fp=2 * total, ints=total,
                     loads=2 * total + nr * nq * npp,
                     stores=nr * nq * npp * 2)

    def jax_fn(A, C4):
        import jax.numpy as jnp
        return jnp.einsum("rqs,sp->rqp", A, C4)

    def jax_args(key):
        import jax
        k1, k2 = jax.random.split(key)
        return (jax.random.normal(k1, (nr, nq, npp)),
                jax.random.normal(k2, (npp, npp)))

    return Workload("Doitgen", "dgn", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_durbin(n: int = 256) -> Workload:
    """Toeplitz solver — mostly sequential with a parallelizable inner
    loop; the paper traces the parallel section (the z-updates)."""
    sp = AddressSpace()
    r = sp.array("r", n)
    y = sp.array("y", n)
    z = sp.array("z", n)

    def build():
        tb = TraceBuilder()
        for k in range(1, n):
            i = np.arange(k)
            tb.interleaved_instance(
                "durbin.z", [(r.addr(k - 1 - i), True), (y.addr(i), True)]
            )
            tb.instance("durbin.zw", [(z.addr(i), True), (y.addr(i), True)])
            tb.instance("durbin.yk", [(y.addr(k), True), (r.addr(k), True)])
        return tb.build()

    counts = _counts(fp=2 * n * n, ints=n * n, divs=n,
                     loads=1.5 * n * n, stores=n * n)
    return Workload("Durbin", "dbn", "Linear Algebra", build, counts)


def make_gramschmidt(n: int = 40) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)
    R = sp.array("R", n, n)
    Q = sp.array("Q", n, n)

    def build():
        tb = TraceBuilder()
        rows = np.arange(n)
        for k in range(n):
            tb.instance("gs.norm", [(A.addr(rows, k), True)])
            tb.instance("gs.rkk", [(R.addr(k, k), True)])
            tb.instance("gs.q", [(A.addr(rows, k), True), (Q.addr(rows, k), True)])
            for j in range(k + 1, n):
                tb.interleaved_instance(
                    "gs.rkj", [(Q.addr(rows, k), True), (A.addr(rows, j), True)]
                )
                tb.instance("gs.rkj_w", [(R.addr(k, j), True)])
                tb.interleaved_instance(
                    "gs.update", [(A.addr(rows, j), True), (Q.addr(rows, k), True),
                                  (R.addr(k, np.full(n, j)), True)]
                )
        return tb.build()

    counts = _counts(fp=4 * n * n * n / 2 + 4 * n * n, ints=n ** 3 / 2,
                     divs=n * n, loads=2.5 * n ** 3 / 2, stores=n ** 3 / 2)

    def jax_fn(A):
        import jax.numpy as jnp
        q, r = jnp.linalg.qr(A)
        return q, r

    def jax_args(key):
        import jax
        return (jax.random.normal(key, (n, n)),)

    return Workload("Gramschmidt", "grm", "Linear Algebra", build, counts,
                    jax_fn, jax_args)


def make_lu(n: int = 64) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)

    def build():
        tb = TraceBuilder()
        for k in range(n):
            j = np.arange(k + 1, n)
            if len(j) == 0:
                continue
            tb.instance("lu.div", [(A.addr(k, k), True), (A.addr(j, k), True)])
            for i in range(k + 1, n):
                tb.interleaved_instance(
                    "lu.update",
                    [(A.addr(np.full(n - k - 1, i), k), True),
                     (A.addr(k, j), True), (A.addr(i, j), True)],
                )
        return tb.build()

    counts = _counts(fp=2 * n ** 3 / 3, ints=n ** 3 / 3, divs=n * n / 2,
                     loads=n ** 3, stores=n ** 3 / 3)
    return Workload("LU", "lu", "Linear Algebra", build, counts)


# --- stencils ------------------------------------------------------------------


def make_jacobi2d(n: int = 64, tsteps: int = 2) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)
    B = sp.array("B", n, n)

    def build():
        tb = TraceBuilder()
        j = np.arange(1, n - 1)
        for _ in range(tsteps):
            for i in range(1, n - 1):
                tb.interleaved_instance(
                    "jacobi.b",
                    [(A.addr(i, j), True), (A.addr(i, j - 1), True),
                     (A.addr(i, j + 1), True), (A.addr(i - 1, j), True),
                     (A.addr(i + 1, j), True)],
                )
                tb.instance("jacobi.bw", [(B.addr(i, j), True)])
            for i in range(1, n - 1):
                tb.instance("jacobi.copy", [(B.addr(i, j), True),
                                            (A.addr(i, j), True)])
        return tb.build()

    inner = (n - 2) * (n - 2) * tsteps
    counts = _counts(fp=5 * inner, ints=2 * inner,
                     loads=6 * inner, stores=2 * inner)

    def jax_fn(A):
        import jax.numpy as jnp
        B = 0.2 * (A[1:-1, 1:-1] + A[1:-1, :-2] + A[1:-1, 2:]
                   + A[:-2, 1:-1] + A[2:, 1:-1])
        return B

    def jax_args(key):
        import jax
        return (jax.random.normal(key, (n, n)),)

    return Workload("Jacobi-2D", "jcb", "Stencils", build, counts,
                    jax_fn, jax_args)


def make_conv2d(n: int = 96) -> Workload:
    sp = AddressSpace()
    A = sp.array("A", n, n)
    B = sp.array("B", n, n)

    def build():
        tb = TraceBuilder()
        j = np.arange(1, n - 1)
        for i in range(1, n - 1):
            tb.interleaved_instance(
                "c2d.row",
                [(A.addr(i - 1, j - 1), True), (A.addr(i - 1, j), True),
                 (A.addr(i - 1, j + 1), True), (A.addr(i, j - 1), True),
                 (A.addr(i, j), True), (A.addr(i, j + 1), True),
                 (A.addr(i + 1, j - 1), True), (A.addr(i + 1, j), True),
                 (A.addr(i + 1, j + 1), True)],
            )
            tb.instance("c2d.w", [(B.addr(i, j), True)])
        return tb.build()

    inner = (n - 2) * (n - 2)
    counts = _counts(fp=17 * inner, ints=2 * inner,
                     loads=9 * inner, stores=inner)

    def jax_fn(A):
        import jax.numpy as jnp
        k = jnp.asarray([[0.2, 0.5, -0.8], [-0.3, 0.6, -0.9],
                         [0.4, 0.7, 0.1]])
        from jax import lax
        return lax.conv_general_dilated(
            A[None, None], k[None, None], (1, 1), "VALID")[0, 0]

    def jax_args(key):
        import jax
        return (jax.random.normal(key, (n, n)),)

    return Workload("Convolution-2D", "c2d", "Stencils", build, counts,
                    jax_fn, jax_args)


def make_adi(n: int = 48, tsteps: int = 2) -> Workload:
    """Alternating-direction implicit 2D heat: row sweeps then column
    sweeps, both parallelized over the other axis."""
    sp = AddressSpace()
    X = sp.array("X", n, n)
    A = sp.array("A", n, n)
    B = sp.array("B", n, n)

    def build():
        tb = TraceBuilder()
        for _ in range(tsteps):
            for i in range(n):
                j = np.arange(1, n)
                tb.interleaved_instance(
                    "adi.row",
                    [(X.addr(i, j), True), (X.addr(i, j - 1), True),
                     (A.addr(i, j), True), (B.addr(i, j), True),
                     (B.addr(i, j - 1), True)],
                )
            for j_col in range(n):
                i = np.arange(1, n)
                tb.interleaved_instance(
                    "adi.col",
                    [(X.addr(i, j_col), True), (X.addr(i - 1, j_col), True),
                     (A.addr(i, j_col), True), (B.addr(i, j_col), True),
                     (B.addr(i - 1, j_col), True)],
                )
        return tb.build()

    inner = 2 * n * (n - 1) * tsteps
    counts = _counts(fp=6 * inner, ints=2 * inner, divs=2 * inner,
                     loads=5 * inner, stores=2 * inner)
    return Workload("ADI", "adi", "Stencils", build, counts)


# --- data mining / RMS ----------------------------------------------------------


def make_covariance(n: int = 64) -> Workload:
    sp = AddressSpace()
    data = sp.array("data", n, n)
    cov = sp.array("cov", n, n)
    mean = sp.array("mean", n)

    def build():
        tb = TraceBuilder()
        rows = np.arange(n)
        for j in range(n):
            tb.instance("cov.mean", [(data.addr(rows, j), True),
                                     (mean.addr(j), True)])
        for i in range(n):
            tb.instance("cov.center", [(data.addr(i, rows), True),
                                       (mean.addr(rows), True)])
        for i in range(n):
            for j in range(i, n):
                tb.interleaved_instance(
                    "cov.acc",
                    [(data.addr(rows, i), True), (data.addr(rows, j), True)],
                )
                tb.instance("cov.w", [(cov.addr(i, j), True),
                                      (cov.addr(j, i), True)])
        return tb.build()

    counts = _counts(fp=n ** 3 + 4 * n * n, ints=n ** 3 / 2, divs=n + n * n / 2,
                     loads=n ** 3 + 3 * n * n, stores=n * n + n)

    def jax_fn(data):
        import jax.numpy as jnp
        c = data - data.mean(axis=0)
        return c.T @ c / (data.shape[0] - 1)

    def jax_args(key):
        import jax
        return (jax.random.normal(key, (n, n)),)

    return Workload("Covariance", "cov", "Datamining", build, counts,
                    jax_fn, jax_args)


def make_blackscholes(num_options: int = 2048) -> Workload:
    """PARSEC blackscholes: embarrassingly parallel over options; each
    option reads a 6-field struct and writes a price (AoS layout)."""
    sp = AddressSpace()
    opt = sp.array("options", num_options, 6)
    price = sp.array("prices", num_options)

    def build():
        tb = TraceBuilder()
        f = np.arange(6)
        # 100 runs in the paper; 4 here (trace size), same reuse pattern
        for _ in range(4):
            for i in range(num_options):
                tb.instance("blk.opt", [(opt.addr(i, f), True)])
                tb.instance("blk.w", [(price.addr(i), True)])
        return tb.build()

    runs = 4
    counts = _counts(fp=120 * num_options * runs, ints=10 * num_options * runs,
                     divs=6 * num_options * runs,
                     loads=6 * num_options * runs, stores=num_options * runs)

    def jax_fn(s, k, t, r, v):
        import jax
        import jax.numpy as jnp
        d1 = (jnp.log(s / k) + (r + 0.5 * v * v) * t) / (v * jnp.sqrt(t))
        d2 = d1 - v * jnp.sqrt(t)
        cnd = lambda x: 0.5 * (1 + jax.scipy.special.erf(x / jnp.sqrt(2.0)))
        return s * cnd(d1) - k * jnp.exp(-r * t) * cnd(d2)

    def jax_args(key):
        import jax
        ks = jax.random.split(key, 5)
        u = lambda k, lo, hi: lo + (hi - lo) * jax.random.uniform(
            k, (num_options,))
        return (u(ks[0], 10, 100), u(ks[1], 10, 100), u(ks[2], 0.1, 2),
                u(ks[3], 0.01, 0.1), u(ks[4], 0.1, 0.6))

    return Workload("Blackscholes", "blk", "RMS", build, counts,
                    jax_fn, jax_args)


# --- registry -------------------------------------------------------------------

MAKERS = {
    "adi": make_adi,
    "atx": make_atax,
    "bcg": make_bicg,
    "blk": make_blackscholes,
    "c2d": make_conv2d,
    "cov": make_covariance,
    "dgn": make_doitgen,
    "dbn": make_durbin,
    "grm": make_gramschmidt,
    "jcb": make_jacobi2d,
    "lu": make_lu,
    "2mm": make_2mm,
    "mvt": make_mvt,
    "smm": make_symm,
}


def all_workloads(subset: list[str] | None = None) -> list[Workload]:
    keys = subset or list(MAKERS)
    return [MAKERS[k]() for k in keys]


# --- validation size presets ----------------------------------------------
#
# The paper traces standard inputs (7-335 GB of references); the
# validation harness (repro.validate) runs the full matrix at reduced
# sizes that keep each trace's loop structure and shared labeling
# intact.  "validation" targets ~8-12k references per workload (the
# committed experiments/results/validation_full.json run); "smoke"
# targets ~1-3k (the CI validation-smoke job).  "validation-xl"
# targets ~100-200k references per workload — infeasible under the old
# monolithic Fenwick scan (O(N)-per-step timeline), feasible now that
# reuse_distances routes large traces through the batched/offline
# engines and the exact-LRU baselines run per-set batched scans
# (core/reuse/batched.py).  "validation-xxl" targets >= 1M references
# per workload (every entry verified >= 1e6), the scale the
# SHARDS-sampled profile path (core/reuse/sampled.py) exists for —
# exact full-matrix passes remain possible but slow, sampled passes
# stay constant-memory.  Default maker sizes (no preset) are the
# quickstart/benchmark sizes.

SIZE_PRESETS: dict[str, dict[str, dict]] = {
    "validation-xxl": {
        "adi": dict(n=230, tsteps=2),
        "atx": dict(n=520),
        "bcg": dict(n=520),
        "blk": dict(num_options=36000),
        "c2d": dict(n=320),
        "cov": dict(n=99),
        "dgn": dict(nq=27, nr=27, npp=27),
        "dbn": dict(n=720),
        "grm": dict(n=74),
        "jcb": dict(n=254, tsteps=2),
        "lu": dict(n=102),
        "2mm": dict(n=64),
        "mvt": dict(n=520),
        "smm": dict(n=88),
    },
    "validation-xl": {
        "adi": dict(n=56, tsteps=2),
        "atx": dict(n=190),
        "bcg": dict(n=190),
        "blk": dict(num_options=5000),
        "c2d": dict(n=128),
        "cov": dict(n=54),
        "dgn": dict(nq=16, nr=16, npp=16),
        "dbn": dict(n=256),
        "grm": dict(n=36),
        "jcb": dict(n=90, tsteps=2),
        "lu": dict(n=48),
        "2mm": dict(n=33),
        "mvt": dict(n=190),
        "smm": dict(n=44),
    },
    "validation": {
        "adi": dict(n=20, tsteps=2),
        "atx": dict(n=48),
        "bcg": dict(n=48),
        "blk": dict(num_options=320),
        "c2d": dict(n=32),
        "cov": dict(n=20),
        "dgn": dict(nq=8, nr=8, npp=8),
        "dbn": dict(n=64),
        "grm": dict(n=15),
        "jcb": dict(n=24, tsteps=2),
        "lu": dict(n=21),
        "2mm": dict(n=14),
        "mvt": dict(n=48),
        "smm": dict(n=18),
    },
    "smoke": {
        "adi": dict(n=10, tsteps=1),
        "atx": dict(n=24),
        "bcg": dict(n=24),
        "blk": dict(num_options=96),
        "c2d": dict(n=16),
        "cov": dict(n=10),
        "dgn": dict(nq=5, nr=5, npp=5),
        "dbn": dict(n=32),
        "grm": dict(n=8),
        "jcb": dict(n=12, tsteps=1),
        "lu": dict(n=12),
        "2mm": dict(n=8),
        "mvt": dict(n=24),
        "smm": dict(n=10),
    },
}


def make_workload(abbr: str, sizes: str | None = None) -> Workload:
    """Build one workload at a named size preset (None = defaults)."""
    kwargs = SIZE_PRESETS[sizes].get(abbr, {}) if sizes else {}
    return MAKERS[abbr](**kwargs)


# --- registry shim ---------------------------------------------------------
#
# MAKERS/SIZE_PRESETS stay as the implementation detail; the public
# roster is repro.workloads.registry, where each maker registers as
# "polybench/<abbr>" with its bare abbr kept as a legacy alias.  The
# declared fingerprint hashes the maker's *resolved* kwargs (preset
# entries merged over signature defaults), so a preset that happens to
# equal the defaults shares the defaults' artifact set.


def _resolved_kwargs(abbr: str, sizes: str | None) -> dict:
    import inspect

    defaults = {
        k: p.default
        for k, p in inspect.signature(MAKERS[abbr]).parameters.items()
        if p.default is not inspect.Parameter.empty
    }
    preset = SIZE_PRESETS[sizes].get(abbr, {}) if sizes else {}
    return {**defaults, **preset}


def _register_polybench() -> None:
    from repro.workloads.registry import WorkloadSpec, register

    for abbr in MAKERS:
        def build(sizes, _abbr=abbr):
            return make_workload(_abbr, sizes)

        def size_kwargs(sizes, _abbr=abbr):
            return _resolved_kwargs(_abbr, sizes)

        register(WorkloadSpec(
            name=f"polybench/{abbr}",
            build=build,
            size_kwargs=size_kwargs,
            presets=tuple(sorted(SIZE_PRESETS)),
            aliases=(abbr,),
            description=f"Table-4 {abbr} analytic trace generator",
        ))


_register_polybench()
