"""VLM wrapper (phi-3-vision).  The CLIP frontend is a STUB per the
brief: ``input_specs()`` provides precomputed patch embeddings
[B, P, clip_dim]; this module owns the projection into the backbone
embedding space and delegates everything else to the phi-3 transformer
backbone (image prefix tokens + causal text, loss on text positions).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    backbone: tfm.TransformerConfig
    clip_dim: int = 1024
    num_patches: int = 1024

    @property
    def param_count(self) -> int:
        return self.backbone.param_count + self.clip_dim * self.backbone.d_model

    active_param_count = param_count

    @property
    def padded_vocab(self) -> int:
        return self.backbone.padded_vocab


def init(key, cfg: VLMConfig):
    kb, kp = jax.random.split(key)
    return {
        "backbone": tfm.init(kb, cfg.backbone),
        "patch_proj": L.linear_init(
            kp, cfg.clip_dim, cfg.backbone.d_model, ("embed", None),
            cfg.backbone.dtype,
        ),
    }


def _project(params, patches):
    return L.linear(params["patch_proj"], patches)


def loss_fn(params, batch, cfg: VLMConfig):
    """batch: {"patches": [B,P,clip_dim], "tokens": [B,S_text],
    "labels": [B,S_text]} — loss on text positions only."""
    prefix = _project(params, batch["patches"])
    b = dict(batch)
    b["patch_embeds"] = prefix
    return tfm.loss_fn(params["backbone"], b, cfg.backbone)


def init_caches(cfg: VLMConfig, batch: int, max_len: int):
    return tfm.init_caches(cfg.backbone, batch, max_len)


def prefill(params, patches, tokens, cfg: VLMConfig, caches):
    prefix = _project(params, patches)
    return tfm.prefill(params["backbone"], tokens, cfg.backbone, caches,
                       prefix_embeds=prefix)


def decode_step(params, token, cfg: VLMConfig, caches, length):
    return tfm.decode_step(params["backbone"], token, cfg.backbone, caches,
                           length)
