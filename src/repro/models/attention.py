"""GQA attention: train/prefill (causal), decode (KV cache), cross-attn,
optional sliding window (mixtral), optional sequence-parallel mode.

Sharding layouts (logical axes; see repro.dist.sharding):

* head-TP (default): q/k/v heads sharded over "model"; KV heads are
  repeated up to the query head count *after* sharding so each chip
  only materializes its own head group (GQA repeat is local).
* sequence-parallel (``sp=True`` — archs whose 56 heads don't divide
  the 16-way model axis): queries are sharded over the sequence dim,
  K/V all-gathered; scores stay seq-sharded.
* decode: the KV cache is sharded over its *sequence* dim on "model"
  (probe-verified: dynamic_update_slice on a seq-sharded cache lowers
  with zero all-gathers); softmax over the sharded key axis costs two
  small all-reduces.

The pure-jnp paths here are what the CPU dry-run lowers; the Pallas
flash kernel replaces the blocked path on real TPUs (``use_pallas``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import PSpec, apply_rope, fan_in_normal

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Append cache: [batch, max_len, kv_heads, head_dim]."""

    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray  # scalar int32 — tokens currently valid


def attn_init(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    dtype=jnp.float32,
):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": PSpec(
            fan_in_normal(kq, (d_model, num_heads, head_dim), d_model, dtype),
            ("embed", "heads", "head_dim"),
        ),
        "wk": PSpec(
            fan_in_normal(kk, (d_model, num_kv_heads, head_dim), d_model, dtype),
            ("embed", "kv_heads", "head_dim"),
        ),
        "wv": PSpec(
            fan_in_normal(kv, (d_model, num_kv_heads, head_dim), d_model, dtype),
            ("embed", "kv_heads", "head_dim"),
        ),
        "wo": PSpec(
            fan_in_normal(ko, (num_heads, head_dim, d_model),
                          num_heads * head_dim, dtype),
            ("heads", "head_dim", "embed"),
        ),
    }


def _repeat_kv(k: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """[B,S,Hkv,hd] -> [B,S,H,hd]; local per shard under head-TP."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    return jnp.repeat(k, num_heads // hkv, axis=2)


def _mask(q_pos, kv_pos, kv_valid, causal, window):
    """[B,1,Sq,Sk] boolean attention mask from absolute positions."""
    m = kv_valid[:, None, None, :]
    if causal:
        m = m & (kv_pos[:, None, None, :] <= q_pos[:, None, :, None])
    if window is not None:
        m = m & (kv_pos[:, None, None, :] > q_pos[:, None, :, None] - window)
    return m


def _sdpa_dense(q, k, v, *, q_positions, kv_positions, kv_valid, causal, window):
    """Materialized-scores attention.  q:[B,Sq,H,hd] k/v:[B,Sk,H,hd].

    Inputs stay bf16 with fp32 *accumulation* via preferred_element_type
    — the MXU-native semantics.  Never ``astype(f32)`` the K/V cache:
    XLA hoists that convert out of the layer scan and materializes an
    f32 copy of the entire stacked cache (observed: +16 GB/chip)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    m = _mask(q_positions, kv_positions, kv_valid, causal, window)
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _sdpa_blocked(
    q, k, v, *, q_positions, kv_positions, kv_valid, causal, window,
    block_q: int,
):
    """lax.scan over query blocks — bounds peak scores memory at
    [B,H,block_q,Sk] (the flash-attention memory shape, fwd only).

    With a sliding window, each q block only attends to a static-width
    key band [q_start - window, q_start + block_q), so HLO FLOPs are
    O(S·(window+block_q)) rather than O(S²)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    nb = sq // block_q
    qb = q.reshape(b, nb, block_q, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(b, nb, block_q).transpose(1, 0, 2)

    banded = window is not None and sq == sk and window + block_q < sk
    band = (window + block_q) if banded else sk

    def body(i, blk):
        qi, qpi = blk
        if banded:
            start = jnp.maximum(i * block_q - window, 0)
            ki = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(kv_positions, start, band, axis=1)
            kvi = jax.lax.dynamic_slice_in_dim(kv_valid, start, band, axis=1)
        else:
            ki, vi, kpi, kvi = k, v, kv_positions, kv_valid
        out = _sdpa_dense(
            qi, ki, vi, q_positions=qpi, kv_positions=kpi, kv_valid=kvi,
            causal=causal, window=window,
        )
        return i + 1, out

    _, outs = jax.lax.scan(body, 0, (qb, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def sdpa(
    q, k, v, *, q_positions, kv_positions, kv_valid, causal, window=None,
    block_q: int = 1024, impl: str = "blocked",
):
    """Dispatch between dense and q-blocked attention (full-head layout)."""
    if impl == "dense" or q.shape[1] <= block_q or q.shape[1] % block_q:
        return _sdpa_dense(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            kv_valid=kv_valid, causal=causal, window=window,
        )
    return _sdpa_blocked(
        q, k, v, q_positions=q_positions, kv_positions=kv_positions,
        kv_valid=kv_valid, causal=causal, window=window, block_q=block_q,
    )


def gqa_attention(
    params,
    x: jnp.ndarray,                  # [B, S, D]
    *,
    positions: jnp.ndarray,          # [B, S]
    rope_theta: float,
    causal: bool = True,
    window: int | None = None,
    cache: KVCache | None = None,
    kv_override: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    kv_positions: jnp.ndarray | None = None,
    sp: bool = False,
    attn_impl: str = "blocked",
    block_q: int = 1024,
) -> tuple[jnp.ndarray, KVCache | None]:
    """Full GQA attention.

    * train/prefill: ``cache=None`` — keys/values from ``x`` itself.
    * decode: ``cache`` holds past KV; ``x`` is the new token(s); the
      cache is updated at ``cache.length`` and returned.
    * cross-attention: ``kv_override=(k_src, v_src)`` (already projected
      encoder memory) — no cache update, no causal mask.
    """
    h = params["wq"].shape[1]
    # SP (seq-sharded dense scores) is the *training* memory fix for
    # non-divisible head counts; with a cache (prefill/decode) there are
    # no saved activations, so q-blocked attention with unsharded seq is
    # both legal and far smaller (SP-dense at 32k prefill would
    # materialize a 30 GB/chip score tensor).
    sp = sp and cache is None and kv_override is None
    seq_ax = "act_sp_seq" if sp else "act_seq"
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = apply_rope(q, positions, rope_theta)
    q = shard(q, "act_batch", seq_ax, "act_heads", None)
    impl = "dense" if sp else attn_impl

    if kv_override is not None:
        k, v = kv_override
        if kv_positions is None:
            kv_positions = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], k.shape[:2]
            )
        kv_valid = jnp.ones(k.shape[:2], bool)
        out = sdpa(q, _repeat_kv(k, h), _repeat_kv(v, h),
                   q_positions=positions, kv_positions=kv_positions,
                   kv_valid=kv_valid, causal=False, window=None,
                   impl=impl, block_q=block_q)
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        k = apply_rope(k, positions, rope_theta)
        k = shard(k, "act_batch", None, "act_kv_heads", None)
        v = shard(v, "act_batch", None, "act_kv_heads", None)
        if cache is None:
            kv_valid = jnp.ones(k.shape[:2], bool)
            out = sdpa(q, _repeat_kv(k, h), _repeat_kv(v, h),
                       q_positions=positions, kv_positions=positions,
                       kv_valid=kv_valid, causal=causal, window=window,
                       impl=impl, block_q=block_q)
            new_cache = None
        else:
            # decode/prefill-into-cache: append new token(s) at
            # cache.length.  dynamic_update_slice on the seq-sharded
            # cache keeps HBM traffic at O(new tokens).
            b, s_new = positions.shape
            max_len = cache.k.shape[1]
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache.length, axis=1
            )
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache.length, axis=1
            )
            k_cache = shard(k_cache, "act_batch", "act_kv_seq",
                            "act_kv_heads", None)
            v_cache = shard(v_cache, "act_batch", "act_kv_seq",
                            "act_kv_heads", None)
            new_len = cache.length + s_new
            kv_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (b, max_len)
            )
            kv_valid = kv_pos < new_len
            out = sdpa(q, _repeat_kv(k_cache, h), _repeat_kv(v_cache, h),
                       q_positions=positions, kv_positions=kv_pos,
                       kv_valid=kv_valid, causal=causal, window=window,
                       impl=impl, block_q=block_q)
            new_cache = KVCache(k_cache, v_cache, new_len)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def project_kv(params, memory: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Encoder-memory K/V for cross-attention (computed once per sequence)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    return k, v


def init_kv_cache(
    batch: int, max_len: int, kv_heads: int, head_dim: int, dtype
) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
