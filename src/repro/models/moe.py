"""Capacity-based top-k MoE (mixtral / arctic) — GShard-style grouped dispatch.

Dispatch/combine are one-hot einsums over [group, tokens, experts,
capacity] masks, evaluated per token *group* so the mask cost stays at
``g·k·cf/(6·d_ff)`` of the expert FLOPs (<5% at g=1024).  Expert
parallelism falls out of the sharding rules: arctic shards ``experts``
over "model" (true EP — dispatch lowers to all-to-all style
collectives), mixtral keeps its 8 experts replicated and shards the
expert FFN dim over "model" (TP-MoE) since 8 < 16 devices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import PSpec, fan_in_normal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    tokens_per_group: int = 1024


def moe_init(key, d: int, d_ff: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, ki, kg, ko = jax.random.split(key, 4)
    e = cfg.num_experts
    return {
        "router": PSpec(
            fan_in_normal(kr, (d, e), d, jnp.float32), ("embed", "experts")
        ),
        "wi": PSpec(
            fan_in_normal(ki, (e, d, d_ff), d, dtype), ("experts", "embed", "mlp")
        ),
        "wg": PSpec(
            fan_in_normal(kg, (e, d, d_ff), d, dtype), ("experts", "embed", "mlp")
        ),
        "wo": PSpec(
            fan_in_normal(ko, (e, d_ff, d), d_ff, dtype), ("experts", "mlp", "embed")
        ),
    }


def _capacity(group_tokens: int, cfg: MoEConfig) -> int:
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4, floor 4


def moe_apply(params, x: jnp.ndarray, cfg: MoEConfig, *, drop: bool = True):
    """x: [B, S, D] -> (y, aux_loss).

    Top-k routing with per-expert, per-group capacity; overflowing
    tokens are dropped (Switch/GShard semantics).  Aux load-balance loss
    follows Switch Transformer eq. 4.

    ``drop=False`` sizes the buffers so no token can overflow (a
    token's top-k experts are distinct, so <= g_tok tokens land on any
    expert) — inference paths use it to make prefill and stepwise
    decode route identically regardless of group/capacity arithmetic.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    g_tok = min(cfg.tokens_per_group, t)
    while t % g_tok:
        g_tok -= 1  # largest divisor <= tokens_per_group
    n_groups = t // g_tok
    cap = _capacity(g_tok, cfg) if drop else -(-g_tok // 4) * 4

    xt = shard(x.reshape(n_groups, g_tok, d), "act_batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xt, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [G, t, E]
    gate, idx = jax.lax.top_k(probs, k)                          # [G, t, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)           # [G, t, k, E]
    # position of each (token, choice) in its expert buffer; choice-major
    # cumsum so first choices win capacity slots.
    flat = onehot.transpose(0, 2, 1, 3).reshape(n_groups, k * g_tok, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat
    pos = pos_flat.reshape(n_groups, k, g_tok, e).transpose(0, 2, 1, 3)
    keep = (pos < cap) * onehot                                  # [G, t, k, E]
    pos_idx = jnp.sum(pos * keep, axis=-1).astype(jnp.int32)     # [G, t, k]
    pos_oh = jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)     # [G, t, k, C]

    # dispatch/combine masks in model dtype with fp32 accumulation —
    # f32 [G,t,E,C] masks would be the layer's largest tensors
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, pos_oh).astype(x.dtype)
    combine = jnp.einsum(
        "gtke,gtk,gtkc->gtec", keep, gate, pos_oh
    ).astype(x.dtype)

    # the group dim stays batch(dp)-sharded — constraining it to None
    # would force a full all-gather of the dispatched activations
    # (observed: 40 GB/chip on mixtral prefill)
    xe = jnp.einsum("gtd,gtec->gecd", xt, dispatch,
                    preferred_element_type=jnp.float32)
    xe = shard(xe.astype(x.dtype), "act_batch", "act_experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    gte = jnp.einsum("gecd,edf->gecf", xe, params["wg"])
    h = jax.nn.silu(gte) * h
    h = shard(h, "act_batch", "act_experts", None, "act_mlp")
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    y = jnp.einsum("gecd,gtec->gtd", ye, combine,
                   preferred_element_type=jnp.float32)

    # Switch aux loss: E * sum_e fraction_routed_e * mean_router_prob_e
    frac = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))             # top-1 routing
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(frac * mean_prob)
    return y.reshape(b, s, d).astype(x.dtype), aux
