"""Parameter machinery + common layers (RMSNorm, linear, MLP, RoPE).

Every ``init`` returns a pytree whose leaves are :class:`PSpec`
(array + logical axis names).  ``unzip_params`` splits that into the
value tree (what the step functions consume) and the axes tree (what
``repro.dist.sharding`` turns into ``NamedSharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple  # tuple[str | None, ...]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PSpec:
    """A parameter leaf: value + logical axis names (one per dim)."""

    value: Any
    axes: Axes

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def unzip_params(tree):
    """(values, axes) from a tree of PSpec leaves."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_pspec)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_pspec)
    return values, axes


def zip_params(values, axes):
    return jax.tree.map(PSpec, values, axes)


# --- initializers ------------------------------------------------------------

def normal(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_normal(key, shape, fan_in: int, dtype) -> jnp.ndarray:
    return normal(key, shape, fan_in ** -0.5, dtype)


# --- norms -------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": PSpec(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # variance via a dot with fp32 *accumulation* — never materializes
    # convert(x): an f32 copy of the residual otherwise becomes the
    # saved tensor of the layer scan (observed: +12 GB on 95L configs)
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / x.shape[-1] + eps)
    return x * inv.astype(x.dtype) * params["scale"].astype(x.dtype)


# --- linear ------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, axes: Axes, dtype=jnp.float32):
    return {"w": PSpec(fan_in_normal(key, (d_in, d_out), d_in, dtype), axes)}


def linear(params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, params["w"])


# --- embedding ---------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": PSpec(normal(key, (vocab, d), 1.0, dtype), ("vocab", "embed"))}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-weights logits head: (..., d) @ (vocab, d)^T."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


# --- rotary position embeddings ----------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2, np.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = jnp.asarray(rope_frequencies(x.shape[-1], theta))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- gated MLP (SwiGLU — the llama/qwen/mixtral family) ------------------------

def mlp_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": PSpec(fan_in_normal(k1, (d, d_ff), d, dtype), ("embed", "mlp")),
        "wg": PSpec(fan_in_normal(k2, (d, d_ff), d, dtype), ("embed", "mlp")),
        "wo": PSpec(fan_in_normal(k3, (d_ff, d), d_ff, dtype), ("mlp", "embed")),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"])
