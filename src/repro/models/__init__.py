"""Model substrate for the 10 assigned architectures.

Pure-functional JAX: params are pytrees of arrays, each leaf paired
with a tuple of *logical axis names* (MaxText-style) that
``repro.dist.sharding`` maps onto the production mesh.
"""
