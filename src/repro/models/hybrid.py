"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The single shared transformer block (one set of weights) is applied
every ``attn_every`` Mamba2 layers; its input is a learned projection
of concat(hidden, original embedding) — the Zamba2 "global memory"
pattern.  Weights are shared across applications; KV caches are not
(one cache per application site).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.models.layers import PSpec, fan_in_normal


@dataclasses.dataclass(frozen=True)
class Zamba2Config:
    layers: int
    d_model: int
    vocab: int
    heads: int = 32
    kv_heads: int = 32
    d_ff: int = 8192
    ssm_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    attn_every: int = 6
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 128
    tie_embeddings: bool = True
    attn_impl: str = "blocked"
    block_q: int = 1024
    remat: bool = True
    scan_layers: bool = True
    norm_eps: float = 1e-6
    zloss: float = 1e-4

    @property
    def num_groups(self) -> int:
        return self.layers // self.attn_every

    @property
    def trailing(self) -> int:
        return self.layers - self.num_groups * self.attn_every

    def mamba_cfg(self) -> ssm.Mamba2Config:
        return ssm.Mamba2Config(
            layers=self.layers, d_model=self.d_model, vocab=self.vocab,
            ssm_state=self.ssm_state, head_dim=self.head_dim,
            expand=self.expand, conv_width=self.conv_width, chunk=self.chunk,
            dtype=self.dtype, vocab_pad_multiple=self.vocab_pad_multiple,
            tie_embeddings=self.tie_embeddings, remat=self.remat,
            scan_layers=self.scan_layers, norm_eps=self.norm_eps,
            zloss=self.zloss,
        )

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        mcfg = self.mamba_cfg()
        per_mamba = (mcfg.param_count - self.padded_vocab * d - d) // self.layers
        shared = (
            2 * d * d                                   # w_cat
            + d * (self.heads + 2 * self.kv_heads) * hd
            + self.heads * hd * d
            + 3 * d * self.d_ff + 3 * d
        )
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.layers * per_mamba + shared + emb + d

    active_param_count = param_count


class HybridCache(NamedTuple):
    groups: ssm.SSMCache        # [G, P, ...] per-group mamba states
    trailing: ssm.SSMCache | None   # [T, ...]
    attn: attn.KVCache          # [G, B, max_len, kv, hd]
    length: jnp.ndarray


def init(key, cfg: Zamba2Config):
    from repro.models.transformer import stack_layer_params

    ke, kg, kt, ka, kc = jax.random.split(key, 5)
    mcfg = cfg.mamba_cfg()
    g, p, t = cfg.num_groups, cfg.attn_every, cfg.trailing

    flat_keys = jax.random.split(kg, g * p)
    gkeys = flat_keys.reshape((g, p) + flat_keys.shape[1:])
    group_blocks = stack_layer_params(stack_layer_params(
        jax.vmap(jax.vmap(lambda k: ssm.block_init(k, mcfg)))(gkeys)
    ))
    params = {
        "embed": L.embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "groups": group_blocks,
        "shared": {
            "w_cat": PSpec(
                fan_in_normal(kc, (2 * cfg.d_model, cfg.d_model),
                              2 * cfg.d_model, cfg.dtype),
                ("embed", None),
            ),
            "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "attn": attn.attn_init(ka, cfg.d_model, cfg.heads, cfg.kv_heads,
                                   cfg.head_dim, cfg.dtype),
            "ln_mlp": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "mlp": L.mlp_init(ka, cfg.d_model, cfg.d_ff, cfg.dtype),
        },
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if t:
        tkeys = jax.random.split(kt, t)
        params["trailing"] = stack_layer_params(
            jax.vmap(lambda k: ssm.block_init(k, mcfg))(tkeys)
        )
    if not cfg.tie_embeddings:
        params["unembed"] = L.linear_init(
            key, cfg.d_model, cfg.padded_vocab, ("embed", "vocab"), cfg.dtype
        )
    return params


def _shared_attn(cfg, sp, x, x0, positions, kv_cache):
    """One application of the shared global block."""
    cat = jnp.concatenate([x, x0], axis=-1)
    h = jnp.einsum("bsd,dk->bsk", cat, sp["w_cat"])
    h = L.rmsnorm(sp["ln_attn"], h, cfg.norm_eps)
    a, new_cache = attn.gqa_attention(
        sp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, cache=kv_cache, attn_impl=cfg.attn_impl,
        block_q=cfg.block_q,
    )
    x = x + a
    m = L.mlp(sp["mlp"], L.rmsnorm(sp["ln_mlp"], x, cfg.norm_eps))
    return x + shard(m, "act_batch", "act_seq", "act_embed"), new_cache


def forward(params, tokens, cfg: Zamba2Config, *, caches: HybridCache | None = None,
            positions=None):
    mcfg = cfg.mamba_cfg()
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x0 = x
    b, s, _ = x.shape
    if positions is None:
        base = caches.length if caches is not None else 0
        positions = jnp.broadcast_to(
            base + jnp.arange(s, dtype=jnp.int32)[None], (b, s)
        ).astype(jnp.int32)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def mamba_body(xc, layer):
        lp, cache = layer
        if cache is not None:
            cache = jax.lax.optimization_barrier(cache)
        xc, nc = ssm.block_apply(mcfg, lp, xc, cache=cache)
        return xc, nc

    mamba_fn = (
        jax.checkpoint(mamba_body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.remat else mamba_body
    )

    def group_body(xc, grp):
        gp, gcache, acache = grp
        xc, new_attn = _shared_attn(cfg, params["shared"], xc, x0,
                                    positions, acache)
        xc, new_g = jax.lax.scan(mamba_fn, xc, (gp, gcache))
        return xc, (new_g, new_attn)

    gcaches = caches.groups if caches is not None else None
    acaches = caches.attn if caches is not None else None
    x, (new_groups, new_attn) = jax.lax.scan(
        group_body, x, (params["groups"], gcaches, acaches)
    )

    new_trailing = None
    if cfg.trailing:
        tcaches = caches.trailing if caches is not None else None
        x, new_trailing = jax.lax.scan(
            mamba_fn, x, (params["trailing"], tcaches)
        )

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = ssm._logits(params, x, cfg)
    new_caches = None
    if caches is not None:
        new_caches = HybridCache(new_groups, new_trailing, new_attn,
                                 caches.length + s)
    return logits, new_caches


def loss_fn(params, batch, cfg: Zamba2Config):
    from repro.models.transformer import softmax_xent

    logits, _ = forward(params, batch["tokens"], cfg)
    return softmax_xent(logits, batch["labels"], cfg.zloss)


def init_caches(cfg: Zamba2Config, batch: int, max_len: int):
    mcfg = cfg.mamba_cfg()
    g, p, t = cfg.num_groups, cfg.attn_every, cfg.trailing

    def ssm_caches(n_outer, n_inner=None):
        shape = (n_outer,) if n_inner is None else (n_outer, n_inner)
        w, di, n = cfg.conv_width, mcfg.d_inner, cfg.ssm_state
        return ssm.SSMCache(
            conv_x=jnp.zeros((*shape, batch, w - 1, di), cfg.dtype),
            conv_b=jnp.zeros((*shape, batch, w - 1, n), cfg.dtype),
            conv_c=jnp.zeros((*shape, batch, w - 1, n), cfg.dtype),
            state=jnp.zeros((*shape, batch, mcfg.heads, n, cfg.head_dim),
                            jnp.float32),
            length=jnp.zeros(shape, jnp.int32),
        )

    return HybridCache(
        groups=ssm_caches(g, p),
        trailing=ssm_caches(t) if t else None,
        attn=attn.KVCache(
            k=jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.head_dim),
                        cfg.dtype),
            v=jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.head_dim),
                        cfg.dtype),
            length=jnp.zeros((g,), jnp.int32),
        ),
        length=jnp.zeros((), jnp.int32),
    )


def prefill(params, tokens, cfg: Zamba2Config, caches):
    logits, caches = forward(params, tokens, cfg, caches=caches)
    return logits[:, -1, :], caches


def decode_step(params, token, cfg: Zamba2Config, caches, length):
    b = token.shape[0]
    positions = jnp.broadcast_to(length[None, None], (b, 1)).astype(jnp.int32)
    logits, caches = forward(params, token, cfg, caches=caches,
                             positions=positions)
    return logits[:, -1, :], caches
