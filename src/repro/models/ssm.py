"""Mamba2 (SSD — state-space duality) blocks and the mamba2 LM stack.

Full-sequence path uses the *chunked* SSD decomposition (intra-chunk
quadratic attention-like matmuls + inter-chunk state scan) — the
matmul-heavy formulation the paper's SSD kernel targets, MXU-friendly
and O(S·Q) rather than O(S²).  Decode carries a constant-size recurrent
state, which is why ``long_500k`` runs for this family.

The depthwise causal conv is applied *separately* to x/B/C streams
(mathematically identical to the fused grouped conv, but keeps every
stream's channel dim cleanly shardable over "model").
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models.layers import PSpec, fan_in_normal


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    layers: int
    d_model: int
    vocab: int
    ssm_state: int = 128            # N
    head_dim: int = 64              # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 128
    tie_embeddings: bool = True
    remat: bool = True
    scan_layers: bool = True
    norm_eps: float = 1e-6
    zloss: float = 1e-4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def param_count(self) -> int:
        d, di, n, h = self.d_model, self.d_inner, self.ssm_state, self.heads
        per_layer = (
            d * (2 * di + 2 * n + h)        # wz, wx, wB, wC, wdt
            + self.conv_width * (di + 2 * n)
            + 3 * h + di + di * d + d       # A_log/D/dt_bias, ln_gate, wo, ln
        )
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.layers * per_layer + emb + d

    active_param_count = param_count


class SSMCache(NamedTuple):
    """Constant-size decode state per layer (stacked [L, ...] in the LM)."""

    conv_x: jnp.ndarray   # [B, W-1, di]
    conv_b: jnp.ndarray   # [B, W-1, N]
    conv_c: jnp.ndarray   # [B, W-1, N]
    state: jnp.ndarray    # [B, H, N, P] fp32
    length: jnp.ndarray   # scalar int32


# --- block params -------------------------------------------------------------


def block_init(key, cfg: Mamba2Config):
    kz, kx, kb, kc, kd, ko = jax.random.split(key, 6)
    d, di, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.heads,
                      cfg.conv_width)
    return {
        "ln": L.rmsnorm_init(d, cfg.dtype),
        "wz": PSpec(fan_in_normal(kz, (d, di), d, cfg.dtype), ("embed", "inner")),
        "wx": PSpec(fan_in_normal(kx, (d, di), d, cfg.dtype), ("embed", "inner")),
        "wB": PSpec(fan_in_normal(kb, (d, n), d, cfg.dtype), ("embed", "ssm_state")),
        "wC": PSpec(fan_in_normal(kc, (d, n), d, cfg.dtype), ("embed", "ssm_state")),
        "wdt": PSpec(fan_in_normal(kd, (d, h), d, jnp.float32), ("embed", "ssm_heads")),
        "conv_x": PSpec(jnp.full((w, di), 1.0 / w, cfg.dtype), (None, "inner")),
        "conv_b": PSpec(jnp.full((w, n), 1.0 / w, cfg.dtype), (None, "ssm_state")),
        "conv_c": PSpec(jnp.full((w, n), 1.0 / w, cfg.dtype), (None, "ssm_state")),
        "A_log": PSpec(jnp.zeros((h,), jnp.float32), ("ssm_heads",)),
        "D": PSpec(jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "dt_bias": PSpec(jnp.full((h,), -2.0, jnp.float32), ("ssm_heads",)),
        "ln_gate": L.rmsnorm_init(di, cfg.dtype),
        "wo": PSpec(fan_in_normal(ko, (di, d), di, cfg.dtype), ("inner", "embed")),
    }


# --- causal depthwise conv ----------------------------------------------------


def causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                tail: jnp.ndarray | None = None):
    """x: [B, S, C]; kernel: [W, C].  ``tail`` [B, W-1, C] is the decode
    conv state (pre-activation inputs preceding x); zeros when None.
    Returns (y [B, S, C], new_tail [B, W-1, C]).

    Implemented as ONE depthwise ``lax.conv``: the W-tap shifted-add
    formulation materialized ~5 stream-sized tensors per call (§Perf
    mamba2 iter3 measured 340 GB/step of pad/mul/concat traffic)."""
    b, s, c = x.shape
    w = kernel.shape[0]
    if tail is None:
        tail = jnp.zeros((b, w - 1, c), x.dtype)
    if s == 1:
        # decode: explicit dot with the tail is cheaper than a conv op
        xp = jnp.concatenate([tail, x], axis=1)        # [B, W, C]
        y = jnp.einsum("bwc,wc->bc", xp, kernel)[:, None, :]
        return y.astype(x.dtype), xp[:, -(w - 1):, :] if w > 1 else tail
    xp = jnp.concatenate([tail, x], axis=1)            # [B, S+W-1, C]
    y = jax.lax.conv_general_dilated(
        xp, kernel[:, None, :].astype(x.dtype),        # [W, 1, C]
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    new_tail = xp[:, -(w - 1):, :] if w > 1 else tail
    return y.astype(x.dtype), new_tail


# --- chunked SSD --------------------------------------------------------------


def ssd_chunked(xh, la, b, c, chunk: int, state0=None):
    """Chunked SSD.  xh: [B,S,H,P]; la: [B,S,H] (log decay); b,c: [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    cdt = xh.dtype if xh.dtype == jnp.bfloat16 else jnp.float32
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q
    xh = xh.reshape(bsz, nc, q, h, p).astype(cdt)
    la = la.reshape(bsz, nc, q, h).astype(jnp.float32)
    bm = b.reshape(bsz, nc, q, n).astype(cdt)
    cm = c.reshape(bsz, nc, q, n).astype(cdt)

    lc = jnp.cumsum(la, axis=2)                        # [B,Nc,Q,H] inclusive
    lc = shard(lc, "act_batch", None, None, "act_heads")
    lsum = lc[:, :, -1, :]                             # [B,Nc,H]

    # intra-chunk: y[q] += sum_{s<=q} exp(lc[q]-lc[s]) (c_q.b_s) x[s]
    g = jnp.einsum("bnqN,bnsN->bnqs", cm, bm,
                   preferred_element_type=jnp.float32)  # [B,Nc,Q,Q]
    diff = lc[:, :, :, None, :] - lc[:, :, None, :, :]  # [B,Nc,Q,S,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask the exponent (not the product) so the masked side never
    # overflows exp and never poisons gradients with inf*0
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    # the [B,Nc,Q,Q,H] decay matrix is THE memory hot spot of chunked
    # SSD: keep it head-sharded and in compute dtype, accumulate fp32
    m = (g[..., None] * jnp.exp(diff)).astype(cdt)
    m = shard(m, "act_batch", None, None, None, "act_heads")
    y_intra = jnp.einsum("bnqsh,bnshp->bnqhp", m, xh,
                         preferred_element_type=jnp.float32)

    # chunk-boundary states: h_n = sum_s exp(lsum - lc[s]) b_s (x) x_s
    w = jnp.exp(lsum[:, :, None, :] - lc).astype(cdt)  # [B,Nc,Q,H]
    h_chunk = jnp.einsum("bnqh,bnqN,bnqhp->bnhNp", w, bm, xh,
                         preferred_element_type=jnp.float32)

    # inter-chunk scan: state entering chunk n
    if state0 is None:
        state0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def step(state, inp):
        hc, ls = inp                                   # [B,H,N,P], [B,H]
        prior = state
        state = jnp.exp(ls)[:, :, None, None] * state + hc
        return state, prior

    final, priors = jax.lax.scan(
        step, state0,
        (h_chunk.transpose(1, 0, 2, 3, 4), lsum.transpose(1, 0, 2)),
    )
    priors = priors.transpose(1, 0, 2, 3, 4)           # [B,Nc,H,N,P]
    y_inter = jnp.einsum(
        "bnqN,bnhNp,bnqh->bnqhp", cm, priors, jnp.exp(lc)
    )
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(jnp.float32), final


# --- block apply --------------------------------------------------------------


def block_apply(cfg: Mamba2Config, params, x, *, cache: SSMCache | None):
    """Pre-norm Mamba2 block; returns (x, new_cache)."""
    x = shard(x, "act_batch", "act_seq", "act_embed")
    hin = L.rmsnorm(params["ln"], x, cfg.norm_eps)

    z = jnp.einsum("bsd,di->bsi", hin, params["wz"])
    xs = jnp.einsum("bsd,di->bsi", hin, params["wx"])
    bb = jnp.einsum("bsd,dn->bsn", hin, params["wB"])
    cc = jnp.einsum("bsd,dn->bsn", hin, params["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", hin, params["wdt"].astype(hin.dtype),
                   preferred_element_type=jnp.float32)
        + params["dt_bias"]
    )                                                   # [B,S,H]

    tails = (cache.conv_x, cache.conv_b, cache.conv_c) if cache else (None,) * 3
    xs, tail_x = causal_conv(xs, params["conv_x"], tails[0])
    bb, tail_b = causal_conv(bb, params["conv_b"], tails[1])
    cc, tail_c = causal_conv(cc, params["conv_c"], tails[2])
    xs, bb, cc = jax.nn.silu(xs), jax.nn.silu(bb), jax.nn.silu(cc)
    xs = shard(xs, "act_batch", "act_seq", "act_mlp")

    bsz, s, _ = xs.shape
    h, p = cfg.heads, cfg.head_dim
    xh = xs.reshape(bsz, s, h, p)
    xh = shard(xh, "act_batch", "act_seq", "act_heads", None)
    la = -jnp.exp(params["A_log"]) * dt                 # [B,S,H] log decay
    xin = xh.astype(jnp.float32) * dt[..., None]

    state0 = cache.state if cache is not None else None
    if cache is not None and s == 1:
        # single-step recurrence (decode)
        lat = la[:, 0, :]                               # [B,H]
        hb = jnp.einsum("bN,bhp->bhNp", bb[:, 0].astype(jnp.float32),
                        xin[:, 0])
        state = jnp.exp(lat)[:, :, None, None] * cache.state + hb
        y = jnp.einsum("bN,bhNp->bhp", cc[:, 0].astype(jnp.float32), state)
        y = y[:, None]                                  # [B,1,H,P]
        final = state
    else:
        y, final = ssd_chunked(xin, la, bb, cc, cfg.chunk, state0)
    final = shard(final, "act_batch", "act_heads", None, None)

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, cfg.d_inner).astype(x.dtype)
    y = L.rmsnorm(params["ln_gate"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"])
    out = shard(out, "act_batch", "act_seq", "act_embed")

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(tail_x, tail_b, tail_c, final,
                             cache.length + s)
    return x + out, new_cache


# --- LM stack -----------------------------------------------------------------


def init(key, cfg: Mamba2Config):
    ke, kb, ku = jax.random.split(key, 3)
    from repro.models.transformer import stack_layer_params

    block_keys = jax.random.split(kb, cfg.layers)
    blocks = stack_layer_params(
        jax.vmap(lambda k: block_init(k, cfg))(block_keys)
    )
    params = {
        "embed": L.embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.linear_init(
            ku, cfg.d_model, cfg.padded_vocab, ("embed", "vocab"), cfg.dtype
        )
    return params


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.linear(params["unembed"], x)
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def forward(params, tokens, cfg: Mamba2Config, *, caches=None):
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(carry, layer):
        lp, cache = layer
        if cache is not None:
            cache = jax.lax.optimization_barrier(cache)
        xc, new_cache = block_apply(cfg, lp, carry, cache=cache)
        return xc, new_cache

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body_fn, x, (params["blocks"], caches))
    else:
        outs = []
        for i in range(cfg.layers):
            lp = jax.tree.map(lambda a: a[i], params["blocks"])
            cc = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, nc = body_fn(x, (lp, cc))
            outs.append(nc)
        new_caches = (
            None if caches is None
            else jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), new_caches


def loss_fn(params, batch, cfg: Mamba2Config):
    from repro.models.transformer import softmax_xent

    logits, _ = forward(params, batch["tokens"], cfg)
    return softmax_xent(logits, batch["labels"], cfg.zloss)


def init_caches(cfg: Mamba2Config, batch: int, max_len: int = 0):
    """Stacked [L, ...] SSM caches; ``max_len`` is ignored (O(1) state —
    the reason long_500k runs for this family)."""
    w, di, n = cfg.conv_width, cfg.d_inner, cfg.ssm_state
    return SSMCache(
        conv_x=jnp.zeros((cfg.layers, batch, w - 1, di), cfg.dtype),
        conv_b=jnp.zeros((cfg.layers, batch, w - 1, n), cfg.dtype),
        conv_c=jnp.zeros((cfg.layers, batch, w - 1, n), cfg.dtype),
        state=jnp.zeros((cfg.layers, batch, cfg.heads, n, cfg.head_dim),
                        jnp.float32),
        length=jnp.zeros((cfg.layers,), jnp.int32),
    )


def prefill(params, tokens, cfg: Mamba2Config, caches):
    logits, caches = forward(params, tokens, cfg, caches=caches)
    return logits[:, -1, :], caches


def decode_step(params, token, cfg: Mamba2Config, caches, length):
    del length  # SSM state is position-free
    logits, caches = forward(params, token, cfg, caches=caches)
    return logits[:, -1, :], caches
