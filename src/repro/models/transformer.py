"""Decoder-only transformer family (llama/qwen/yi/deepseek/mixtral/arctic/phi3).

One stack implementation covers dense GQA, MoE (mixtral), MoE+dense
residual (arctic), sliding-window attention, and sequence-parallel
attention.  Layers are scanned (``lax.scan`` over stacked params) with
optional remat so HLO size and compile time stay O(1) in depth — a
hard requirement for lowering 95-layer configs against 512 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 10000.0
    window: int | None = None
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 128
    moe: MoEConfig | None = None
    dense_ff: bool = True            # arctic keeps a dense MLP beside the MoE
    attn_sp: bool = False            # sequence-parallel attention (56-head archs)
    sp_residuals: bool = False       # Megatron-SP: residual stream (and the
    #                                  layer-scan saved carry) seq-sharded
    attn_impl: str = "blocked"
    block_q: int = 1024
    remat: bool = True
    scan_layers: bool = True
    norm_eps: float = 1e-6
    zloss: float = 1e-4

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        h, kv, hd = self.heads, self.kv_heads, self.head_dim
        attn_p = d * (h + 2 * kv) * hd + h * hd * d
        mlp_p = 3 * d * f if (self.moe is None or self.dense_ff) else 0
        moe_p = 3 * d * f * self.moe.num_experts + d * self.moe.num_experts \
            if self.moe else 0
        return self.layers * (attn_p + mlp_p + moe_p + 2 * d) + 2 * v * d + d

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.param_count
        d, f = self.d_model, self.d_ff
        dense = self.param_count - self.layers * 3 * d * f * self.moe.num_experts
        return dense + self.layers * 3 * d * f * self.moe.top_k


# --- single block -------------------------------------------------------------


def block_init(key, cfg: TransformerConfig):
    ka, km, ke = jax.random.split(key, 3)
    p = {
        "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn.attn_init(
            ka, cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.dtype
        ),
        "ln_mlp": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if cfg.moe is None or cfg.dense_ff:
        p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.dtype)
    if cfg.moe is not None:
        p["moe"] = moe_init(ke, cfg.d_model, cfg.d_ff, cfg.moe, cfg.dtype)
    return p


def block_apply(cfg: TransformerConfig, params, x, *, positions,
                cache: attn.KVCache | None):
    """Pre-norm residual block; returns (x, new_cache, aux_loss)."""
    # SP residuals only pay off in training (the constraint shards the
    # scan's saved carry, the dominant remat memory); decode/prefill
    # have no saved activations and seq=1 decode can't shard anyway.
    res_seq = "act_sp_seq" if (cfg.sp_residuals and cache is None) else "act_seq"
    x = shard(x, "act_batch", res_seq, "act_embed")
    h = L.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    a, new_cache = attn.gqa_attention(
        params["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, window=cfg.window, cache=cache, sp=cfg.attn_sp,
        attn_impl=cfg.attn_impl, block_q=cfg.block_q,
    )
    x = x + a
    h = L.rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    y = jnp.zeros_like(x)
    if "mlp" in params:
        hm = L.mlp(params["mlp"], h)
        y = y + shard(hm, "act_batch", res_seq, "act_embed")
    if "moe" in params:
        # training keeps capacity-drop semantics; inference (cache
        # present) routes exactly so prefill == stepwise decode
        ym, aux = moe_apply(params["moe"], h, cfg.moe, drop=cache is None)
        y = y + ym
    return shard(x + y, "act_batch", res_seq, "act_embed"), new_cache, aux


# --- stacked model ------------------------------------------------------------


def stack_layer_params(per_layer):
    """vmapped-init PSpec tree -> prepend the 'layers' logical axis."""
    values, axes = L.unzip_params(per_layer)
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(e, (str, type(None))) for e in t),
    )
    return L.zip_params(values, axes)


def init(key, cfg: TransformerConfig):
    ke, kb, ku = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.layers)
    blocks = stack_layer_params(
        jax.vmap(lambda k: block_init(k, cfg))(block_keys)
    )
    return {
        "embed": L.embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": L.linear_init(
            ku, cfg.d_model, cfg.padded_vocab, ("embed", "vocab"), cfg.dtype
        ),
    }


def scan_cache_carry(body_fn, x0, stacked_params, caches, extras=()):
    """Layer scan with the stacked cache as *carry* (not xs/ys).

    Passing caches through scan as xs/ys double-buffers the whole
    multi-GB cache (input stack + fresh ys stack both live); carrying
    it and dynamic-update-slicing layer ``i`` lets XLA alias the buffer
    in place — the production serving pattern.  ``body_fn(carry_extras,
    layer_params, cache_i) -> (carry_extras, new_cache_i)``."""
    def body(carry, lp):
        ex, caches_c, i = carry
        cache_i = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            caches_c,
        )
        # barrier: stop loop-invariant motion from materializing an f32
        # shadow of the full stacked cache (CPU bf16 legalization)
        cache_i = jax.lax.optimization_barrier(cache_i)
        ex, new_cache_i = body_fn(ex, lp, cache_i)
        caches_c = jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(
                a, u.astype(a.dtype), i, 0),
            caches_c, new_cache_i,
        )
        return (ex, caches_c, i + 1), None

    (ex, caches, _), _ = jax.lax.scan(
        body, ((x0, *extras), caches, jnp.zeros((), jnp.int32)),
        stacked_params,
    )
    return ex, caches


def _scan_blocks(cfg, params, x, positions, caches):
    zero = jnp.zeros((), jnp.float32)
    if caches is not None and cfg.scan_layers:
        def body(ex, lp, cache_i):
            xc, aux_sum = ex
            xc, new_cache, aux = block_apply(
                cfg, lp, xc, positions=positions, cache=cache_i
            )
            return (xc, aux_sum + aux), new_cache

        (x, aux), new_caches = scan_cache_carry(
            body, x, params, caches, extras=(zero,)
        )
        return x, new_caches, aux

    def body(carry, layer):
        xc, aux_sum = carry
        lp, cache = layer
        if cache is not None:
            cache = jax.lax.optimization_barrier(cache)
        xc, new_cache, aux = block_apply(
            cfg, lp, xc, positions=positions, cache=cache
        )
        return (xc, aux_sum + aux), new_cache

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, zero), (params, caches)
        )
    else:
        aux = zero
        outs = []
        for i in range(cfg.layers):
            lp = jax.tree.map(lambda a: a[i], params)
            c = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            (x, aux), nc = body_fn((x, aux), (lp, c))
            outs.append(nc)
        new_caches = (
            None if caches is None
            else jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        )
    return x, new_caches, aux


def forward(
    params,
    tokens: jnp.ndarray,            # [B, S] int32
    cfg: TransformerConfig,
    *,
    positions: jnp.ndarray | None = None,
    caches: attn.KVCache | None = None,   # stacked [L, ...] or None
    prefix_embeds: jnp.ndarray | None = None,  # [B, P, D] (VLM patches)
):
    """Returns (logits [B, S(+P), Vp], new_caches, aux_loss)."""
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard(x, "act_batch", "act_seq", "act_embed")
    x, new_caches, aux = _scan_blocks(cfg, params["blocks"], x, positions, caches)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.linear(params["unembed"], x)
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(attn.NEG_INF, logits.dtype), logits)
    return logits, new_caches, aux


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, zloss: float):
    """Mean cross-entropy (+ z-loss) in fp32 over a (possibly sharded) vocab."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if zloss:
        loss = loss + zloss * jnp.mean(lse ** 2)
    return loss


def loss_fn(params, batch, cfg: TransformerConfig):
    """batch: {"tokens": [B,S], "labels": [B,S], ["patch_embeds": [B,P,D]]}."""
    prefix = batch.get("patch_embeds")
    logits, _, aux = forward(
        params, batch["tokens"], cfg, prefix_embeds=prefix
    )
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:, :]  # loss on text positions only
    return softmax_xent(logits, batch["labels"], cfg.zloss) + aux


# --- serving ------------------------------------------------------------------


def init_caches(cfg: TransformerConfig, batch: int, max_len: int):
    """Stacked [L, ...] KV caches (seq dim sharded over 'model' via the
    act_kv_seq rule at use)."""
    return attn.KVCache(
        k=jnp.zeros((cfg.layers, batch, max_len, cfg.kv_heads, cfg.head_dim),
                    cfg.dtype),
        v=jnp.zeros((cfg.layers, batch, max_len, cfg.kv_heads, cfg.head_dim),
                    cfg.dtype),
        length=jnp.zeros((cfg.layers,), jnp.int32),
    )


def prefill(params, tokens, cfg: TransformerConfig, caches,
            prefix_embeds=None):
    """Run the full prompt through the stack, filling the caches.
    Returns (last-token logits [B, Vp], caches)."""
    logits, caches, _ = forward(
        params, tokens, cfg, caches=caches, prefix_embeds=prefix_embeds
    )
    return logits[:, -1, :], caches


def decode_step(params, token, cfg: TransformerConfig, caches, length):
    """One decode step.  token: [B, 1]; length: scalar tokens-so-far.
    Returns (logits [B, Vp], caches)."""
    b = token.shape[0]
    positions = jnp.broadcast_to(length[None, None], (b, 1)).astype(jnp.int32)
    logits, caches, _ = forward(params, token, cfg, positions=positions,
                                caches=caches)
    return logits[:, -1, :], caches
