"""Uniform family API: every architecture family exposes the same
batch-dict interface so configs/launch/serve code is family-agnostic.

    fam = get_family("transformer")
    params = fam.init(key, cfg)
    loss   = fam.loss_fn(params, batch, cfg)
    caches = fam.init_caches(cfg, batch_size, max_len, **kw)
    logits, caches = fam.prefill(params, batch, cfg, caches)
    logits, caches = fam.decode_step(params, batch, cfg, caches, length)

``cache_axes(cfg)`` returns a logical-axes tree parallel to the cache
pytree (tuples at leaf positions) for ``repro.dist.sharding``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.models import attention as attn
from repro.models import encdec, hybrid, multimodal, ssm
from repro.models import transformer as tfm


class Family(NamedTuple):
    name: str
    init: Callable
    loss_fn: Callable
    init_caches: Callable
    prefill: Callable
    decode_step: Callable
    cache_axes: Callable


_KV_AXES = ("layers", "act_batch", "act_kv_seq", "act_kv_heads", None)


def _kv_cache_axes(_cfg):
    return attn.KVCache(k=_KV_AXES, v=_KV_AXES, length=("layers",))


def _ssm_cache_axes(_cfg, lead=("layers",)):
    return ssm.SSMCache(
        conv_x=lead + ("act_batch", None, "act_mlp"),
        conv_b=lead + ("act_batch", None, None),
        conv_c=lead + ("act_batch", None, None),
        state=lead + ("act_batch", "act_heads", None, None),
        length=lead,
    )


def _hybrid_cache_axes(cfg: hybrid.Zamba2Config):
    ga = ("groups", "act_batch", "act_kv_seq", "act_kv_heads", None)
    return hybrid.HybridCache(
        groups=_ssm_cache_axes(None, lead=("groups", "layers")),
        trailing=_ssm_cache_axes(None) if cfg.trailing else None,
        attn=attn.KVCache(k=ga, v=ga, length=("groups",)),
        length=(),
    )


def _encdec_cache_axes(_cfg):
    return encdec.EncDecCache(
        self_kv=attn.KVCache(k=_KV_AXES, v=_KV_AXES, length=("layers",)),
        cross_k=_KV_AXES,
        cross_v=_KV_AXES,
        length=(),
    )


TRANSFORMER = Family(
    name="transformer",
    init=tfm.init,
    loss_fn=tfm.loss_fn,
    init_caches=tfm.init_caches,
    prefill=lambda p, batch, cfg, caches: tfm.prefill(
        p, batch["tokens"], cfg, caches
    ),
    decode_step=lambda p, batch, cfg, caches, length: tfm.decode_step(
        p, batch["token"], cfg, caches, length
    ),
    cache_axes=_kv_cache_axes,
)

SSM = Family(
    name="ssm",
    init=ssm.init,
    loss_fn=ssm.loss_fn,
    init_caches=ssm.init_caches,
    prefill=lambda p, batch, cfg, caches: ssm.prefill(
        p, batch["tokens"], cfg, caches
    ),
    decode_step=lambda p, batch, cfg, caches, length: ssm.decode_step(
        p, batch["token"], cfg, caches, length
    ),
    cache_axes=_ssm_cache_axes,
)

HYBRID = Family(
    name="hybrid",
    init=hybrid.init,
    loss_fn=hybrid.loss_fn,
    init_caches=hybrid.init_caches,
    prefill=lambda p, batch, cfg, caches: hybrid.prefill(
        p, batch["tokens"], cfg, caches
    ),
    decode_step=lambda p, batch, cfg, caches, length: hybrid.decode_step(
        p, batch["token"], cfg, caches, length
    ),
    cache_axes=_hybrid_cache_axes,
)

ENCDEC = Family(
    name="encdec",
    init=encdec.init,
    loss_fn=encdec.loss_fn,
    init_caches=encdec.init_caches,
    prefill=lambda p, batch, cfg, caches: encdec.prefill(
        p, batch["frames"], batch["tokens"], cfg, caches
    ),
    decode_step=lambda p, batch, cfg, caches, length: encdec.decode_step(
        p, batch["token"], cfg, caches, length
    ),
    cache_axes=_encdec_cache_axes,
)

VLM = Family(
    name="vlm",
    init=multimodal.init,
    loss_fn=multimodal.loss_fn,
    init_caches=multimodal.init_caches,
    prefill=lambda p, batch, cfg, caches: multimodal.prefill(
        p, batch["patches"], batch["tokens"], cfg, caches
    ),
    decode_step=lambda p, batch, cfg, caches, length: multimodal.decode_step(
        p, batch["token"], cfg, caches, length
    ),
    cache_axes=lambda cfg: _kv_cache_axes(cfg.backbone),
)

FAMILIES = {f.name: f for f in (TRANSFORMER, SSM, HYBRID, ENCDEC, VLM)}


def get_family(name: str) -> Family:
    return FAMILIES[name]
