"""Encoder–decoder backbone (seamless-m4t).  The audio/text modality
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
frame embeddings [B, S_src, D]; this module implements the transformer
backbone (bidirectional encoder + causal decoder with cross-attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as attn
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int
    dec_layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 128
    attn_impl: str = "blocked"
    block_q: int = 1024
    remat: bool = True
    scan_layers: bool = True
    norm_eps: float = 1e-6
    zloss: float = 1e-4

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def param_count(self) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        qkvo = d * (self.heads + 2 * self.kv_heads) * hd + self.heads * hd * d
        enc = self.enc_layers * (qkvo + 3 * d * f + 2 * d)
        dec = self.dec_layers * (2 * qkvo + 3 * d * f + 3 * d)
        return enc + dec + 2 * self.padded_vocab * d + 2 * d

    active_param_count = param_count


class EncDecCache(NamedTuple):
    self_kv: attn.KVCache            # [Ld, B, max_len, kv, hd]
    cross_k: jnp.ndarray             # [Ld, B, S_src, kv, hd]
    cross_v: jnp.ndarray
    length: jnp.ndarray


def _enc_block_init(key, cfg):
    ka, km = jax.random.split(key)
    return {
        "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn.attn_init(ka, cfg.d_model, cfg.heads, cfg.kv_heads,
                               cfg.head_dim, cfg.dtype),
        "ln_mlp": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _dec_block_init(key, cfg):
    ka, kx, km = jax.random.split(key, 3)
    p = _enc_block_init(jax.random.fold_in(key, 0), cfg)
    p["ln_cross"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    p["cross"] = attn.attn_init(kx, cfg.d_model, cfg.heads, cfg.kv_heads,
                                cfg.head_dim, cfg.dtype)
    return p


def init(key, cfg: EncDecConfig):
    from repro.models.transformer import stack_layer_params

    ke, kd, kv, ku = jax.random.split(key, 4)
    enc = stack_layer_params(jax.vmap(lambda k: _enc_block_init(k, cfg))(
        jax.random.split(ke, cfg.enc_layers)))
    dec = stack_layer_params(jax.vmap(lambda k: _dec_block_init(k, cfg))(
        jax.random.split(kd, cfg.dec_layers)))
    return {
        "embed": L.embed_init(kv, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "encoder": enc,
        "enc_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "decoder": dec,
        "dec_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": L.linear_init(ku, cfg.d_model, cfg.padded_vocab,
                                 ("embed", "vocab"), cfg.dtype),
    }


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def encode(params, frames: jnp.ndarray, cfg: EncDecConfig) -> jnp.ndarray:
    """frames: [B, S_src, D] precomputed modality embeddings -> memory."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard(frames.astype(cfg.dtype), "act_batch", "act_seq", "act_embed")

    def body(xc, lp):
        h = L.rmsnorm(lp["ln_attn"], xc, cfg.norm_eps)
        a, _ = attn.gqa_attention(
            lp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
            causal=False, attn_impl=cfg.attn_impl, block_q=cfg.block_q,
        )
        xc = xc + a
        m = L.mlp(lp["mlp"], L.rmsnorm(lp["ln_mlp"], xc, cfg.norm_eps))
        return xc + shard(m, "act_batch", "act_seq", "act_embed"), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["encoder"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(cfg, lp, x, *, positions, cross_kv, self_cache):
    h = L.rmsnorm(lp["ln_attn"], x, cfg.norm_eps)
    a, new_cache = attn.gqa_attention(
        lp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, cache=self_cache, attn_impl=cfg.attn_impl,
        block_q=cfg.block_q,
    )
    x = x + a
    h = L.rmsnorm(lp["ln_cross"], x, cfg.norm_eps)
    c, _ = attn.gqa_attention(
        lp["cross"], h, positions=positions, rope_theta=cfg.rope_theta,
        causal=False, kv_override=cross_kv, attn_impl=cfg.attn_impl,
        block_q=cfg.block_q,
    )
    x = x + c
    m = L.mlp(lp["mlp"], L.rmsnorm(lp["ln_mlp"], x, cfg.norm_eps))
    return x + shard(m, "act_batch", "act_seq", "act_embed"), new_cache


def decode_stack(params, tokens, memory, cfg: EncDecConfig, *,
                 caches: EncDecCache | None = None, positions=None):
    """memory: [B, S_src, D] (ignored when cross-KV comes from caches)."""
    b, s = tokens.shape
    if positions is None:
        base = caches.length if caches is not None else 0
        positions = jnp.broadcast_to(
            base + jnp.arange(s, dtype=jnp.int32)[None], (b, s)
        ).astype(jnp.int32)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xc, layer):
        lp, self_c, ck, cv = layer
        if self_c is not None:
            self_c = jax.lax.optimization_barrier(self_c)
        if ck is None:
            cross_kv = attn.project_kv(lp["cross"], memory)
        else:
            cross_kv = jax.lax.optimization_barrier((ck, cv))
        xc, new_cache = _dec_block(cfg, lp, xc, positions=positions,
                                   cross_kv=cross_kv, self_cache=self_c)
        return xc, new_cache

    self_caches = caches.self_kv if caches is not None else None
    ck = caches.cross_k if caches is not None else None
    cv = caches.cross_v if caches is not None else None
    x, new_self = jax.lax.scan(
        _maybe_remat(cfg, body), x, (params["decoder"], self_caches, ck, cv)
    )
    x = L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.linear(params["unembed"], x)
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    new_caches = None
    if caches is not None:
        new_caches = EncDecCache(new_self, caches.cross_k, caches.cross_v,
                                 caches.length + s)
    return logits, new_caches


def loss_fn(params, batch, cfg: EncDecConfig):
    """batch: {"frames": [B,Ss,D], "tokens": [B,St], "labels": [B,St]}."""
    from repro.models.transformer import softmax_xent

    memory = encode(params, batch["frames"], cfg)
    logits, _ = decode_stack(params, batch["tokens"], memory, cfg)
    return softmax_xent(logits, batch["labels"], cfg.zloss)


def project_cross_kv(params, memory, cfg: EncDecConfig):
    """Per-layer cross K/V from encoder memory (computed once)."""
    def one(lp):
        return attn.project_kv(lp["cross"], memory)

    ks, vs = jax.lax.map(one, params["decoder"])
    return ks, vs


def init_caches(cfg: EncDecConfig, batch: int, max_len: int, src_len: int):
    return EncDecCache(
        self_kv=attn.KVCache(
            k=jnp.zeros((cfg.dec_layers, batch, max_len, cfg.kv_heads,
                         cfg.head_dim), cfg.dtype),
            v=jnp.zeros((cfg.dec_layers, batch, max_len, cfg.kv_heads,
                         cfg.head_dim), cfg.dtype),
            length=jnp.zeros((cfg.dec_layers,), jnp.int32),
        ),
        cross_k=jnp.zeros((cfg.dec_layers, batch, src_len, cfg.kv_heads,
                           cfg.head_dim), cfg.dtype),
        cross_v=jnp.zeros((cfg.dec_layers, batch, src_len, cfg.kv_heads,
                           cfg.head_dim), cfg.dtype),
        length=jnp.zeros((), jnp.int32),
    )


def prefill(params, frames, tokens, cfg: EncDecConfig, caches: EncDecCache):
    """Encode the source and prefill the decoder self-cache."""
    memory = encode(params, frames, cfg)
    ck, cv = project_cross_kv(params, memory, cfg)
    caches = caches._replace(cross_k=ck.astype(cfg.dtype),
                             cross_v=cv.astype(cfg.dtype))
    logits, caches = decode_stack(params, tokens, None, cfg, caches=caches)
    return logits[:, -1, :], caches


def decode_step(params, token, cfg: EncDecConfig, caches: EncDecCache, length):
    b = token.shape[0]
    positions = jnp.broadcast_to(length[None, None], (b, 1)).astype(jnp.int32)
    logits, caches = decode_stack(params, token, None, cfg, caches=caches,
                                  positions=positions)
    return logits[:, -1, :], caches
