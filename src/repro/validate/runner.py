"""Multi-process paper-matrix validation runner.

Executes the paper's full validation matrix — every
``repro.workloads.polybench`` workload × the three Table-5 CPUs ×
core counts {1,2,4,8} × interleave strategies — through the
``PredictionRequest``/``Session`` grid, and scores each cell the way
the paper does:

* **hit rates** — analytical SDCM prediction vs the exact
  set-associative LRU simulation of the same mimicked traces (the
  container's PAPI stand-in), absolute error per level in percent;
* **runtimes** — the Eq. 4–7 chain with SDCM rates vs the same chain
  with exact rates, relative error in percent (isolates the SDCM
  approximation, the paper's modeling contribution).

Cells are sharded across worker processes by workload (one workload's
cells share mimicked traces, so they stay on one worker for in-memory
cache locality); every worker layers its Session on the SAME
disk-backed :class:`~repro.validate.store.ArtifactStore`, and results
are merged store-mediated: each worker writes its per-workload payload
under the ``validation`` kind and the parent reads the shards back.
A second run with the same ``artifact_dir`` therefore performs zero
reuse-profile recomputations (``session_stats.profile_builds == 0``)
and zero exact-LRU resimulations — asserted by tests and the CI
``validation-smoke`` job.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing
import os
from pathlib import Path

import numpy as np

from repro.api import PredictionRequest, Session
from repro.api.stages import (
    default_runtime_model,
    resolve_runtime_model,
    shared_level_index,
    supported_runtime_models,
)
from repro.hw.targets import CPU_TARGETS, resolve_target
from repro.validate.reference import paper_claim, reference_record
from repro.validate.store import ArtifactStore, atomic_write_bytes
from repro.workloads.polybench import MAKERS

DEFAULT_TARGETS = tuple(CPU_TARGETS)          # the three Table-5 CPUs
DEFAULT_CORES = (1, 2, 4, 8)
DEFAULT_STRATEGIES = ("round_robin", "uniform")


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """Declarative description of one validation matrix.

    ``workloads`` entries are registry names (``polybench/atx``,
    ``model/llama3_8b/decode``); legacy Table-4 abbreviations keep
    resolving as aliases, so the default roster stays spelled as the
    paper abbreviates it.
    """

    workloads: tuple[str, ...] = tuple(MAKERS)
    targets: tuple[str, ...] = DEFAULT_TARGETS
    core_counts: tuple[int, ...] = DEFAULT_CORES
    strategies: tuple[str, ...] = DEFAULT_STRATEGIES
    sizes: str | None = "validation"   # polybench.SIZE_PRESETS key
    seed: int = 0
    # also run every cell through a binned=True Session (the fused
    # device-histogram profile path) and record the absolute deviation
    # of its SDCM hit rates from the exact-profile prediction
    binned_check: bool = True
    # also run every cell through a sampled=R Session (SHARDS-sampled
    # profiles, core/reuse/sampled.py) and record the absolute
    # deviation of its SDCM hit rates from the exact-profile
    # prediction ALONGSIDE the per-level error bound each sampled
    # profile declares — the gate's tolerance is the bound itself,
    # not a fixed constant like the binned check's 1e-3
    sampled_check: bool = True
    sampled_rate: float = 0.5

    def matrix_id(self) -> str:
        """Stable id of the matrix — namespaces the result shards in
        the store so different matrices never mix."""
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    def describe(self) -> str:
        return (
            f"{len(self.workloads)} workloads x {len(self.targets)} targets"
            f" x cores {list(self.core_counts)}"
            f" x strategies {list(self.strategies)}"
            f" (sizes={self.sizes or 'default'})"
        )


def _levels_fingerprint(target) -> str:
    """Content key of a target's cache hierarchy — exact-LRU baselines
    depend only on the hierarchy, so targets sharing one (or reruns)
    share the cached simulation."""
    t = resolve_target(target)
    parts = [
        (lvl.name, lvl.size_bytes, lvl.line_size, lvl.assoc)
        for lvl in t.levels
    ]
    parts.append(("shared_level", getattr(t, "shared_level", -1)))
    return hashlib.sha1(repr(parts).encode()).hexdigest()[:12]


def _exact_hit_rates(session: Session, store: ArtifactStore | None,
                     tid: str, source, target, cores: int, strategy: str,
                     seed: int) -> dict[str, float]:
    """Exact-LRU baseline for one cell, store-cached under the trace
    content hash + hierarchy fingerprint."""
    key = (f"{tid}-{_levels_fingerprint(target)}"
           f"-c{cores}-{strategy}-s{seed}")
    if store is not None:
        cached = store.get_json("exact", key)
        if cached is not None:
            return {k: float(v) for k, v in cached.items()}
    rates = session.ground_truth_hit_rates(
        source, target, cores, strategy=strategy, seed=seed
    )
    if store is not None:
        store.put_json("exact", key, rates)
    return rates


def _shard_key(spec: MatrixSpec, name: str) -> str:
    """Store key of one workload's shard; registry names contain
    ``/`` which must not become directory separators."""
    return f"{spec.matrix_id()}-{name.replace('/', '_')}"


def run_workload(abbr: str, spec: MatrixSpec,
                 artifact_dir: str | os.PathLike | None) -> dict:
    """Score every matrix cell of one workload (one worker's shard)."""
    from repro.workloads import registry

    store = ArtifactStore(artifact_dir) if artifact_dir else None
    session = Session(store=store)
    w = registry.resolve(abbr, spec.sizes, store=store)
    # fingerprint only — the trace is materialized lazily, so a warm
    # store serves the whole shard with zero trace builds
    tid = session.identify(w)

    request = PredictionRequest(
        targets=spec.targets,
        core_counts=spec.core_counts,
        strategies=spec.strategies,
        counts=w.op_counts,
        seed=spec.seed,
        respect_core_limit=False,
    )
    predset = session.predict(w, request)

    binned_by_key: dict[tuple, dict] = {}
    binned_stats = None
    if spec.binned_check:
        # separate Session: the binned builder has its own store
        # fingerprint, so its cells are cached/persisted independently
        bsession = Session(store=store, binned=True)
        bpred = bsession.predict(w, request)
        binned_by_key = {
            (p.target, p.cores, p.strategy, p.mode): p.hit_rates
            for p in bpred
        }
        binned_stats = dataclasses.asdict(bsession.stats)

    sampled_by_key: dict[tuple, dict] = {}
    sampled_session = None
    sampled_stats = None
    if spec.sampled_check:
        # separate sampled Session, same store: ``+sampled{R}``
        # fingerprints keep its cells disjoint from exact/binned ones
        sampled_session = Session(store=store, sampled=spec.sampled_rate)
        spred = sampled_session.predict(w, request)
        sampled_by_key = {
            (p.target, p.cores, p.strategy, p.mode): p.hit_rates
            for p in spred
        }

    records = []
    for cell in predset:
        target = resolve_target(cell.target)
        exact = _exact_hit_rates(
            session, store, tid, w, target, cell.cores, cell.strategy,
            spec.seed,
        )
        levels = {
            lvl: {
                "predicted": float(cell.hit_rates[lvl]),
                "exact": float(exact[lvl]),
                "abs_err_pct": abs(cell.hit_rates[lvl] - exact[lvl]) * 100,
            }
            for lvl in cell.hit_rates
        }
        # the cell's reference runtime: the per-target default model
        # (Eq. 4–7 for the instruction-timed CPUs, roofline for the
        # TPU) evaluated with the EXACT rates — this container's
        # stand-in for the paper's wall-clock measurement
        t_exact = default_runtime_model(target).runtime(
            target, exact, w.op_counts, cell.cores, mode=cell.mode
        )["t_pred_s"]
        # every named stage-4 model the target supports, scored against
        # that ONE common reference.  Scoring each model against its
        # own exact-rates prediction would measure rate sensitivity,
        # not fidelity (a model that ignores hit rates scores a
        # degenerate 0%) — the --runtime-gate comparison (ECM vs
        # Roofline) needs a shared yardstick.
        runtime_models = {}
        for mname in supported_runtime_models(target):
            model = resolve_runtime_model(mname, target)
            t_sdcm = model.runtime(
                target, cell.hit_rates, w.op_counts, cell.cores,
                mode=cell.mode,
            )["t_pred_s"]
            runtime_models[mname] = {
                "t_pred_s": float(t_sdcm),
                "rel_err_pct":
                    abs(t_sdcm - t_exact) / max(t_exact, 1e-12) * 100,
            }
        rec = {
            "workload": w.workload_name,
            "target": cell.target,
            "cores": cell.cores,
            "strategy": cell.strategy,
            "levels": levels,
            "t_pred_s": float(cell.t_pred_s),
            "t_exact_rates_s": float(t_exact),
            "runtime_rel_err_pct":
                abs(cell.t_pred_s - t_exact) / max(t_exact, 1e-12) * 100,
            "runtime_models": runtime_models,
        }
        bkey = (cell.target, cell.cores, cell.strategy, cell.mode)
        if bkey in binned_by_key:
            brates = binned_by_key[bkey]
            rec["binned_abs_dev"] = {
                lvl: abs(float(brates[lvl]) - float(cell.hit_rates[lvl]))
                for lvl in cell.hit_rates
            }
        if bkey in sampled_by_key:
            srates = sampled_by_key[bkey]
            rec["sampled_abs_dev"] = {
                lvl: abs(float(srates[lvl]) - float(cell.hit_rates[lvl]))
                for lvl in cell.hit_rates
            }
            # per-level DECLARED bound: private levels read the PRD
            # estimate, the shared level(s) the CRD one (same routing
            # as AnalyticalSDCM) — served from the sampled Session's
            # in-memory cell cache, so this costs zero rebuilds
            sart = sampled_session.artifacts(
                w, cell.cores, strategy=cell.strategy, seed=spec.seed,
                line_size=target.levels[0].line_size,
            )
            shared_idx = shared_level_index(target)
            rec["sampled_bound"] = {
                lvl.name: float(
                    (sart.crd if i >= shared_idx else sart.prd).error_bound
                    or 0.0
                )
                for i, lvl in enumerate(target.levels)
                if lvl.name in cell.hit_rates
            }
        records.append(rec)

    stats = dataclasses.asdict(session.stats)
    if sampled_session is not None:
        # read AFTER the record loop: the bound lookups go through the
        # sampled Session's cell cache and must show up as hits there
        sampled_stats = dataclasses.asdict(sampled_session.stats)
    for extra in (binned_stats, sampled_stats):
        if extra:  # fold the check Sessions' counters in
            for k, v in extra.items():
                stats[k] = stats.get(k, 0) + int(v)
    # refs come from the store's workload meta when the trace never
    # materialized this run (warm store); only a store-less run has to
    # load the trace just to count it
    refs = None
    if store is not None:
        meta = store.get_json("workload", tid)
        if meta:
            refs = meta.get("refs")
    if refs is None:
        refs = len(session.load(w)[1])
    payload = {
        "workload": w.workload_name,
        "trace_id": tid,
        "refs": int(refs),
        "records": records,
        "session_stats": stats,
        "store_stats": dataclasses.asdict(store.stats) if store else None,
    }
    if store is not None:
        # store-mediated merge: the parent reads this shard back
        store.put_json("validation", _shard_key(spec, w.workload_name),
                       payload)
    return payload


def _worker(args) -> str:
    abbr, spec, artifact_dir = args
    run_workload(abbr, spec, artifact_dir)
    return abbr


def _merge(shards: list[dict], spec: MatrixSpec) -> dict:
    """Fold per-workload shards into the validation summary: per-cell
    records, per-architecture and aggregate errors, paper comparison,
    and the summed Session counters the zero-recompute assertions use."""
    hit_by_arch: dict[str, list] = {}
    rt_by_arch: dict[str, list] = {}
    hit_by_level: dict[str, list] = {}
    per_workload: dict[str, dict] = {}
    stats_total: dict[str, int] = {}
    all_hit, all_rt = [], []
    binned_devs: list[float] = []
    sampled_devs: list[float] = []
    sampled_bounds: list[float] = []
    sampled_exceed = 0
    # per named stage-4 model: model -> {"all": [...], arch: [...]}
    model_errs: dict[str, dict[str, list]] = {}

    for shard in shards:
        w_hit, w_rt = [], []
        for rec in shard["records"]:
            arch = rec["target"]
            for lvl, entry in rec["levels"].items():
                err = entry["abs_err_pct"]
                hit_by_arch.setdefault(arch, []).append(err)
                hit_by_level.setdefault(lvl, []).append(err)
                all_hit.append(err)
                w_hit.append(err)
            binned_devs.extend(rec.get("binned_abs_dev", {}).values())
            sdev = rec.get("sampled_abs_dev", {})
            sbound = rec.get("sampled_bound", {})
            for lvl, dev in sdev.items():
                bound = float(sbound.get(lvl, 0.0))
                sampled_devs.append(float(dev))
                sampled_bounds.append(bound)
                if float(dev) > bound:
                    sampled_exceed += 1
            rt = rec["runtime_rel_err_pct"]
            rt_by_arch.setdefault(arch, []).append(rt)
            all_rt.append(rt)
            w_rt.append(rt)
            for mname, entry in rec.get("runtime_models", {}).items():
                buckets = model_errs.setdefault(mname, {"all": []})
                buckets["all"].append(entry["rel_err_pct"])
                buckets.setdefault(arch, []).append(entry["rel_err_pct"])
        per_workload[shard["workload"]] = {
            "refs": shard["refs"],
            "trace_id": shard["trace_id"],
            "avg_hit_err_pct": float(np.mean(w_hit)) if w_hit else 0.0,
            "avg_runtime_err_pct": float(np.mean(w_rt)) if w_rt else 0.0,
        }
        for k, v in shard["session_stats"].items():
            stats_total[k] = stats_total.get(k, 0) + int(v)

    def vs_paper(ours: float, claimed: float) -> dict:
        return {"ours": ours, "paper": claimed,
                "delta": ours - claimed}

    per_arch = {}
    for arch in hit_by_arch:
        claim = paper_claim(arch)
        per_arch[arch] = {
            "hit_rate_err_pct": vs_paper(
                float(np.mean(hit_by_arch[arch])), claim.hit_rate_err_pct
            ),
            "runtime_err_pct": vs_paper(
                float(np.mean(rt_by_arch[arch])), claim.runtime_err_pct
            ),
            "cells": len(rt_by_arch[arch]),
        }

    from repro.validate.reference import PAPER_OVERALL

    return {
        "spec": dataclasses.asdict(spec),
        "matrix_id": spec.matrix_id(),
        "description": spec.describe(),
        "reference": reference_record(),
        "aggregates": {
            "overall": {
                "hit_rate_err_pct": vs_paper(
                    float(np.mean(all_hit)) if all_hit else 0.0,
                    PAPER_OVERALL.hit_rate_err_pct,
                ),
                "runtime_err_pct": vs_paper(
                    float(np.mean(all_rt)) if all_rt else 0.0,
                    PAPER_OVERALL.runtime_err_pct,
                ),
                "cells": len(all_rt),
            },
            "per_arch": per_arch,
            "per_level_hit_err_pct": {
                lvl: float(np.mean(v)) for lvl, v in hit_by_level.items()
            },
            # every named stage-4 model scored identically (prediction
            # with SDCM rates vs with exact rates); the --runtime-gate
            # compares ecm vs roofline here
            "runtime_models": {
                mname: {
                    "overall_rel_err_pct": float(np.mean(buckets["all"])),
                    "cells": len(buckets["all"]),
                    "per_arch": {
                        arch: float(np.mean(errs))
                        for arch, errs in buckets.items()
                        if arch != "all"
                    },
                }
                for mname, buckets in sorted(model_errs.items())
            },
            # fused device-binned profiles vs exact profiles, same SDCM:
            # the binned path is usable iff this stays under tolerance
            "binned_profile": {
                "cells": len(binned_devs),
                "max_abs_dev": float(np.max(binned_devs))
                if binned_devs else 0.0,
                "mean_abs_dev": float(np.mean(binned_devs))
                if binned_devs else 0.0,
                "tolerance": 1e-3,
                "within_tolerance": bool(
                    not binned_devs or float(np.max(binned_devs)) <= 1e-3
                ),
            },
            # SHARDS-sampled profiles vs exact profiles, same SDCM:
            # unlike the binned check's fixed 1e-3, each level cell is
            # gated against the error bound ITS OWN profile declared
            # (core/reuse/sampled.sampling_error_bound), so the
            # tolerance tightens automatically as traces grow
            "sampled_profile": {
                "cells": len(sampled_devs),
                "rate": spec.sampled_rate if spec.sampled_check else None,
                "max_abs_dev": float(np.max(sampled_devs))
                if sampled_devs else 0.0,
                "mean_abs_dev": float(np.mean(sampled_devs))
                if sampled_devs else 0.0,
                "max_declared_bound": float(np.max(sampled_bounds))
                if sampled_bounds else 0.0,
                "mean_declared_bound": float(np.mean(sampled_bounds))
                if sampled_bounds else 0.0,
                "bound_exceedances": int(sampled_exceed),
                "within_bound": bool(sampled_exceed == 0),
            },
        },
        "per_workload": per_workload,
        "records": [r for s in shards for r in s["records"]],
        "session_stats": stats_total,
    }


def run_validation(
    spec: MatrixSpec | None = None,
    *,
    artifact_dir: str | os.PathLike | None = None,
    processes: int | None = None,
) -> dict:
    """Run the validation matrix and return the merged summary.

    ``processes > 1`` shards workloads across spawned worker processes
    that share ``artifact_dir``; ``processes=1`` (or a single workload)
    runs in-process.  Without an ``artifact_dir`` everything is
    recomputed (no cross-run incrementality).
    """
    spec = spec or MatrixSpec()
    if processes is None:
        # no store -> no channel for worker shards: default to serial
        # rather than erroring out (an explicit processes>1 still does)
        if artifact_dir is None:
            processes = 1
        else:
            processes = max(1, min(len(spec.workloads), os.cpu_count() or 1))

    if processes <= 1 or len(spec.workloads) <= 1:
        shards = [
            run_workload(abbr, spec, artifact_dir)
            for abbr in spec.workloads
        ]
    else:
        if artifact_dir is None:
            raise ValueError(
                "multi-process validation needs an artifact_dir: workers "
                "hand their shards to the parent through the store"
            )
        ctx = multiprocessing.get_context("spawn")
        jobs = [(abbr, spec, artifact_dir) for abbr in spec.workloads]
        with ctx.Pool(processes) as pool:
            done = pool.map(_worker, jobs)
        # store-mediated merge: read every worker's shard back from disk
        from repro.workloads import registry

        store = ArtifactStore(artifact_dir)
        shards = []
        for abbr in done:
            shard = store.get_json(
                "validation", _shard_key(spec, registry.canonical_name(abbr))
            )
            if shard is None:
                raise RuntimeError(
                    f"worker shard for {abbr!r} missing from the store"
                )
            shards.append(shard)
    return _merge(shards, spec)


def save_results(summary: dict, path: str | os.PathLike) -> Path:
    """Atomically write the merged summary json (same fsync'd
    temp-file + replace discipline as the store's payloads)."""
    path = Path(path)
    blob = json.dumps(summary, indent=2, default=float).encode()
    atomic_write_bytes(path, blob)
    return path
