"""Paper reference data for the validation harness (machine-readable).

Encodes, as plain data:

* the paper's headline validation claims — 1.23% average absolute
  cache-hit-rate error and 9.08% average runtime error (abstract, §4) —
  broken down per modeled architecture as reported by the Tables 6–8 /
  Figs. 8–10 validation matrix;
* the Table 4 benchmark roster (full names, suite, domain, and the
  paper's standard input sizes) keyed by the ``MAKERS`` abbreviations
  used across this repo;
* the paper's known weak spots (workload × level cells the paper itself
  calls out as high-error).

Measurement convention: the paper validates predicted hit rates against
PAPI hardware counters and predicted runtimes against wall-clock runs.
This container has neither, so the reproduction's "measured" side is
the exact set-associative LRU simulation of the same mimicked traces
(``repro.api.stages.ExactLRU`` — the PAPI stand-in, see
``docs/architecture.md``) and the Eq. 4–7 chain evaluated with those
exact rates.  Input sizes are scaled down (the paper's traces run
7–335 GB); absolute hit rates therefore differ from the paper's tables,
and the comparison that carries over is the *error statistic*: our
SDCM-vs-exact error per cell, aggregated per architecture, against the
paper's claimed per-architecture averages below.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PaperClaim:
    """One architecture's claimed average errors (percent)."""

    hit_rate_err_pct: float
    runtime_err_pct: float
    source: str  # which paper table/figure the figure is transcribed from


# Abstract / §4 headline aggregates.
PAPER_OVERALL = PaperClaim(1.23, 9.08, "abstract; §4.3–4.4 aggregate")

# Per-architecture averages of the paper's validation matrix
# (hit rates: Tables 6–8; runtimes: Figs. 8–10).  Keyed by the target
# registry names in ``repro.hw.targets.CPU_TARGETS``.
PAPER_ARCH_CLAIMS: dict[str, PaperClaim] = {
    "i7-5960X": PaperClaim(1.20, 8.42, "Table 6 / Fig. 8 (Haswell)"),
    "Xeon E5-2699 v4": PaperClaim(1.30, 9.85, "Table 7 / Fig. 9 (Broadwell)"),
    "EPYC 7702P": PaperClaim(1.19, 8.98, "Table 8 / Fig. 10 (Zen2)"),
}


@dataclass(frozen=True)
class WorkloadRef:
    """Table 4 roster entry for one benchmark."""

    abbr: str
    name: str
    suite: str
    domain: str
    paper_input: str  # the standard input the paper traced


# The paper's benchmark roster (Table 4), keyed by the MAKERS
# abbreviation used by ``repro.workloads.polybench``.
PAPER_TABLE4: dict[str, WorkloadRef] = {
    "adi": WorkloadRef("adi", "ADI", "PolyBench", "Stencils",
                       "N=1024, TSTEPS=10"),
    "atx": WorkloadRef("atx", "ATAX", "PolyBench", "Linear Algebra",
                       "N=4000"),
    "bcg": WorkloadRef("bcg", "BICG", "PolyBench", "Linear Algebra",
                       "N=4000"),
    "blk": WorkloadRef("blk", "Blackscholes", "PARSEC", "RMS",
                       "native input, 100 runs"),
    "c2d": WorkloadRef("c2d", "Convolution-2D", "PolyBench", "Stencils",
                       "N=4096"),
    "cov": WorkloadRef("cov", "Covariance", "PolyBench", "Datamining",
                       "N=1000"),
    "dgn": WorkloadRef("dgn", "Doitgen", "PolyBench", "Linear Algebra",
                       "NQ=NR=NP=128"),
    "dbn": WorkloadRef("dbn", "Durbin", "PolyBench", "Linear Algebra",
                       "N=4000"),
    "grm": WorkloadRef("grm", "Gramschmidt", "PolyBench", "Linear Algebra",
                       "N=512"),
    "jcb": WorkloadRef("jcb", "Jacobi-2D", "PolyBench", "Stencils",
                       "N=1024, TSTEPS=10"),
    "lu": WorkloadRef("lu", "LU", "PolyBench", "Linear Algebra",
                      "N=1024"),
    "2mm": WorkloadRef("2mm", "2MM", "PolyBench", "Linear Algebra",
                       "N=1024"),
    "mvt": WorkloadRef("mvt", "MVT", "PolyBench", "Linear Algebra",
                       "N=4000"),
    "smm": WorkloadRef("smm", "SYMM", "PolyBench", "Linear Algebra",
                       "N=1024"),
}

# Cells the paper itself flags as its weak spots (§4.3): the mimicked
# interleaving misses some L2 locality for these kernels.
PAPER_KNOWN_WEAK_SPOTS: tuple[tuple[str, str], ...] = (
    ("grm", "L2"),
    ("smm", "L2"),
)

# Provenance of the stage-4 runtime models the validation tier scores
# side by side (``aggregates.runtime_models``): what each one computes
# and which literature its parameters transcribe.
RUNTIME_MODEL_REFS: dict[str, str] = {
    "eq": "paper Eq. 4–7 chain + two-mode T_CPU (§3.4; Table 5 "
          "latency/throughput parameters)",
    "ecm": "ECM-style in-core model: per-class port tables (Table 5 "
           "sources + OSACA-style port counts; 'Bridging the "
           "Architecture Gap' non-overlap data chain, chip-wide "
           "shared-bandwidth saturation)",
    "roofline": "two-term roofline: sustained-bandwidth memory stream "
                "vs peak-FLOP compute (declared peaks on accelerators, "
                "derived from Table 5 parameters on CPUs)",
}


def paper_claim(arch_name: str) -> PaperClaim:
    """Per-architecture claim, falling back to the overall aggregate
    for targets outside the paper's matrix (e.g. the TPU adaptation)."""
    return PAPER_ARCH_CLAIMS.get(arch_name, PAPER_OVERALL)


def reference_record() -> dict:
    """The whole reference block as JSON-serializable data — embedded
    into ``validation_full.json`` so the report is self-contained."""
    return {
        "overall": {
            "hit_rate_err_pct": PAPER_OVERALL.hit_rate_err_pct,
            "runtime_err_pct": PAPER_OVERALL.runtime_err_pct,
            "source": PAPER_OVERALL.source,
        },
        "per_arch": {
            name: {
                "hit_rate_err_pct": c.hit_rate_err_pct,
                "runtime_err_pct": c.runtime_err_pct,
                "source": c.source,
            }
            for name, c in PAPER_ARCH_CLAIMS.items()
        },
        "workloads": {
            abbr: {
                "name": r.name, "suite": r.suite, "domain": r.domain,
                "paper_input": r.paper_input,
            }
            for abbr, r in PAPER_TABLE4.items()
        },
        "known_weak_spots": [list(t) for t in PAPER_KNOWN_WEAK_SPOTS],
        "runtime_models": dict(RUNTIME_MODEL_REFS),
    }
