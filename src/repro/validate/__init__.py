"""repro.validate — disk-backed artifacts + the paper-validation harness.

Three pieces (docs/validation.md is generated from their output):

* :class:`~repro.validate.store.ArtifactStore` — content-hash-keyed,
  disk-backed artifact store (npz + json, atomic writes, version-
  stamped keys) that ``Session(artifact_dir=...)`` layers under its
  in-memory caches, making sweeps incremental across processes/runs;
* :func:`~repro.validate.runner.run_validation` — the multi-process
  paper-matrix runner (workloads × Table-5 CPUs × core counts ×
  interleave strategies) with store-mediated shard merging;
* :func:`~repro.validate.report.generate_report` — renders the merged
  summary into ``docs/validation.md`` against the paper's reference
  claims (``repro.validate.reference``).

CLI::

    PYTHONPATH=src python -m repro.validate          # full matrix + report
    PYTHONPATH=src python -m repro.validate --smoke  # CI double-run gate
"""
from repro.validate.reference import (
    PAPER_ARCH_CLAIMS,
    PAPER_OVERALL,
    PAPER_TABLE4,
    PaperClaim,
    paper_claim,
)
from repro.validate.report import generate_report, render_markdown
from repro.validate.runner import (
    MatrixSpec,
    run_validation,
    run_workload,
    save_results,
)
from repro.validate.store import (
    STORE_VERSION,
    ArtifactStore,
    StoreStats,
    artifact_key,
    load_profile_artifacts,
    save_profile_artifacts,
)

__all__ = [
    "ArtifactStore",
    "MatrixSpec",
    "PAPER_ARCH_CLAIMS",
    "PAPER_OVERALL",
    "PAPER_TABLE4",
    "PaperClaim",
    "STORE_VERSION",
    "StoreStats",
    "artifact_key",
    "generate_report",
    "load_profile_artifacts",
    "paper_claim",
    "render_markdown",
    "run_validation",
    "run_workload",
    "save_profile_artifacts",
    "save_results",
]
