"""CLI for the validation harness.

    python -m repro.validate                 # full matrix -> json + docs
    python -m repro.validate --smoke         # tiny matrix twice, assert
                                             # zero recomputes on run 2
    python -m repro.validate --workloads atx jcb --processes 1

The smoke mode is the CI gate: it runs the whole workload roster at
tiny sizes twice against one shared artifact dir and fails loudly if
the second run rebuilt any reuse profile (the disk store must make it
fully incremental).
"""
from __future__ import annotations

import argparse
import sys

from repro.validate.report import generate_report
from repro.validate.runner import MatrixSpec, run_validation, save_results
from repro.workloads.polybench import MAKERS


def check_runtime_gate(aggregates: dict) -> tuple[bool, str]:
    """The --runtime-gate criterion: the instruction-aware ECM model
    must predict runtime at least as accurately as the crude roofline
    baseline, aggregated over every scored cell.

    Returns ``(passed, message)``; missing per-model aggregates (a
    matrix that scored neither model) fail loudly rather than passing
    vacuously.
    """
    models = aggregates.get("runtime_models", {})
    ecm = models.get("ecm")
    roofline = models.get("roofline")
    if not ecm or not roofline:
        return False, ("runtime gate: matrix did not score both 'ecm' and "
                       f"'roofline' (scored: {sorted(models)})")
    e, r = ecm["overall_rel_err_pct"], roofline["overall_rel_err_pct"]
    msg = (f"runtime gate: ecm {e:.3f}% vs roofline {r:.3f}% aggregate "
           f"relative error over {ecm['cells']} cells")
    if e <= r + 1e-9:
        return True, f"OK: {msg}"
    return False, f"FAIL: {msg} — ECM must not be worse than roofline"


def check_sampling_gate(aggregates: dict) -> tuple[bool, str]:
    """The --sampling-gate criterion: every sampled SDCM hit rate must
    deviate from the exact-profile prediction by less than the error
    bound its own profile declared (core.reuse.sampled).

    Returns ``(passed, message)``; a matrix that scored no sampled
    cells (``sampled_check=False``) fails loudly rather than passing
    vacuously.
    """
    sampled = aggregates.get("sampled_profile") or {}
    cells = sampled.get("cells", 0)
    if not cells:
        return False, ("sampling gate: matrix scored no sampled cells "
                       "(was sampled_check disabled?)")
    msg = (f"sampling gate: max deviation {sampled['max_abs_dev']:.2e} "
           f"vs max declared bound {sampled['max_declared_bound']:.2e} "
           f"over {cells} level cells at rate "
           f"{sampled.get('rate')}")
    if sampled.get("within_bound"):
        return True, f"OK: {msg}"
    return False, (f"FAIL: {msg} — {sampled['bound_exceedances']} cell(s) "
                   "exceeded their declared error bound")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.validate")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, run twice, assert zero recomputes")
    ap.add_argument("--sizes", default=None,
                    choices=["validation", "validation-xl",
                             "validation-xxl", "smoke", "default"],
                    help="workload size preset (default: validation; "
                         "'validation-xl' = ~100-200k refs/workload, "
                         "feasible via the batched reuse-distance "
                         "engines; 'validation-xxl' = >=1M "
                         "refs/workload, the scale the SHARDS-sampled "
                         "profile path targets; 'default' = the "
                         "quickstart/benchmark sizes)")
    ap.add_argument("--workloads", nargs="+", default=None, metavar="NAME",
                    help="subset of registry workload names "
                         "(polybench/atx, model/llama3_8b/decode, ...); "
                         "legacy Table-4 abbreviations accepted as "
                         "aliases")
    ap.add_argument("--targets", nargs="+", default=None, metavar="TARGET",
                    help="subset of hardware targets (default: the three "
                         "Table-5 CPUs; add tpu-v5e for VMEM hit-rate "
                         "cells)")
    ap.add_argument("--cores", nargs="+", type=int, default=None,
                    metavar="N", help="core counts (default: 1 2 4 8)")
    ap.add_argument("--artifact-dir", default=".validation-cache",
                    help="shared disk store (cross-run incrementality + "
                         "the worker-shard channel; default: "
                         ".validation-cache, gitignored).  Pass 'none' "
                         "to disable and recompute everything serially")
    ap.add_argument("--processes", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="results json path (default: experiments/results/"
                         "validation_full.json or validation_smoke.json)")
    ap.add_argument("--report", default=None,
                    help="markdown report path (default: docs/validation.md "
                         "for full runs; omitted for --smoke)")
    ap.add_argument("--no-report", action="store_true")
    ap.add_argument("--runtime-gate", action="store_true",
                    help="fail unless the ECM model's aggregate runtime "
                         "error is <= the roofline baseline's")
    ap.add_argument("--sampling-gate", action="store_true",
                    help="fail unless every sampled SDCM hit rate "
                         "deviates from the exact prediction by less "
                         "than its profile's declared error bound")
    args = ap.parse_args(argv)

    sizes = args.sizes or ("smoke" if args.smoke else "validation")
    if sizes == "default":
        sizes = None
    if args.artifact_dir and args.artifact_dir.lower() == "none":
        args.artifact_dir = None
    workloads = tuple(args.workloads) if args.workloads else tuple(MAKERS)
    # fail fast on typos (and normalize aliases for the matrix id)
    from repro.workloads import registry

    try:
        workloads = tuple(registry.canonical_name(w) for w in workloads)
    except KeyError as exc:
        ap.error(str(exc.args[0] if exc.args else exc))
    overrides = {}
    if args.targets:
        from repro.hw.targets import ALL_TARGETS

        unknown = [t for t in args.targets if t not in ALL_TARGETS]
        if unknown:
            ap.error(f"unknown target(s) {unknown} "
                     f"(choose from {sorted(ALL_TARGETS)})")
        overrides["targets"] = tuple(args.targets)
    if args.cores:
        overrides["core_counts"] = tuple(args.cores)
    spec = MatrixSpec(workloads=workloads, sizes=sizes, **overrides)
    print(f"validation matrix: {spec.describe()}")

    if args.smoke:
        if not args.artifact_dir:
            ap.error("--smoke needs --artifact-dir (the incrementality "
                     "assertion is about the shared store)")
        first = run_validation(spec, artifact_dir=args.artifact_dir,
                               processes=args.processes)
        second = run_validation(spec, artifact_dir=args.artifact_dir,
                                processes=args.processes)
        s2 = second["session_stats"]
        rebuilt = s2.get("profile_builds", 0) + s2.get("rd_builds", 0)
        summary = {
            "mode": "smoke",
            "first_run_stats": first["session_stats"],
            "second_run_stats": s2,
            "aggregates": second["aggregates"],
            "description": second["description"],
            "matrix_id": second["matrix_id"],
        }
        out = args.out or "experiments/results/validation_smoke.json"
        save_results(summary, out)
        print(f"wrote {out}")
        print(f"run 1: {first['session_stats']}")
        print(f"run 2: {s2}")
        if rebuilt:
            print(f"FAIL: second run rebuilt {rebuilt} profiles/distance "
                  "passes — the artifact store is not incremental",
                  file=sys.stderr)
            return 1
        print("OK: second run performed zero reuse-profile recomputations "
              f"({s2.get('store_hits', 0)} disk-store hits)")
        if args.runtime_gate:
            passed, msg = check_runtime_gate(second["aggregates"])
            print(msg, file=None if passed else sys.stderr)
            if not passed:
                return 1
        if args.sampling_gate:
            passed, msg = check_sampling_gate(second["aggregates"])
            print(msg, file=None if passed else sys.stderr)
            if not passed:
                return 1
        return 0

    summary = run_validation(spec, artifact_dir=args.artifact_dir,
                             processes=args.processes)
    out = args.out or "experiments/results/validation_full.json"
    save_results(summary, out)
    print(f"wrote {out}")
    agg = summary["aggregates"]["overall"]
    print(f"overall: hit err {agg['hit_rate_err_pct']['ours']:.2f}% "
          f"(paper {agg['hit_rate_err_pct']['paper']:.2f}%), "
          f"runtime err {agg['runtime_err_pct']['ours']:.2f}% "
          f"(paper {agg['runtime_err_pct']['paper']:.2f}%)")
    binned = summary["aggregates"].get("binned_profile", {})
    if binned.get("cells"):
        print(f"binned-profile deviation: max "
              f"{binned['max_abs_dev']:.2e} over {binned['cells']} "
              f"level cells (tolerance {binned['tolerance']:.0e}, "
              f"{'OK' if binned['within_tolerance'] else 'EXCEEDED'})")
    sampled = summary["aggregates"].get("sampled_profile", {})
    if sampled.get("cells"):
        print(f"sampled-profile deviation: max "
              f"{sampled['max_abs_dev']:.2e} over {sampled['cells']} "
              f"level cells at rate {sampled.get('rate')} (max declared "
              f"bound {sampled['max_declared_bound']:.2e}, "
              f"{'OK' if sampled['within_bound'] else 'EXCEEDED'})")
    models = summary["aggregates"].get("runtime_models", {})
    for mname, entry in models.items():
        print(f"runtime model {mname}: {entry['overall_rel_err_pct']:.2f}% "
              f"aggregate error over {entry['cells']} cells")
    if not args.no_report:
        md = args.report or "docs/validation.md"
        generate_report(out, md)
        print(f"wrote {md}")
    if args.runtime_gate:
        passed, msg = check_runtime_gate(summary["aggregates"])
        print(msg, file=None if passed else sys.stderr)
        if not passed:
            return 1
    if args.sampling_gate:
        passed, msg = check_sampling_gate(summary["aggregates"])
        print(msg, file=None if passed else sys.stderr)
        if not passed:
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
