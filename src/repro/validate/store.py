"""Disk-backed, content-hash-keyed artifact store.

The Session's in-memory caches die with the process; every sweep in a
new interpreter recomputed every reuse profile from scratch.  The
:class:`ArtifactStore` persists the expensive derived artifacts —
PRD/CRD reuse profiles (npz) and exact-LRU baselines / merged
validation results (json) — under a directory keyed by

    v{STORE_VERSION}/{kind}/{content-hash-derived key}.{npz|json}

so repeated sweeps are incremental *across processes and runs*: the
validation runner's worker processes share one store, and a second run
with the same ``artifact_dir`` performs zero reuse-profile
recomputations (asserted by tests and the CI smoke job).

Durability rules:

* **Atomic writes** — payloads are serialized to a temp file in the
  destination directory and ``os.replace``d into place, so readers
  never observe a partially-written artifact.
* **Corruption tolerance** — a truncated or undecodable file reads as
  a miss (counted in ``stats.corrupt``) and is deleted; the caller
  recomputes and rewrites it.
* **Concurrent same-key safety** — every writer stages under its own
  mkstemp name (two service workers healing one cell never interleave
  partial bytes), and the corrupt-file cleanup re-checks the file's
  stat identity before unlinking so it cannot delete a cell a
  concurrent writer just healed.
* **Version-stamped keys** — every key lives under ``v{version}``;
  bumping :data:`STORE_VERSION` (a format/semantics change) orphans
  old entries instead of misreading them.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import zipfile
from pathlib import Path

import numpy as np

# Bump when the on-disk payload format or the meaning of a key changes:
# old entries become unreachable (they live under the old version dir).
# v2: profile cells carry a ``binned`` meta flag (device-binned log2
# profiles from the fused kernels/reuse_hist path share the namespace
# with exact cells, disambiguated by builder fingerprint + this flag).
# v3: trace ids of registry-resolved workloads are declared
# fingerprints (repro.workloads.registry) rather than content hashes,
# and the ``workload`` kind records per-fingerprint metadata (recorded
# trace_content_id cross-check, refs, model-trace op counts).
# v4: profile cells may be SHARDS-sampled (core.reuse.sampled): meta
# gains the ``sampled`` rate and per-profile ``prd_error_bound`` /
# ``crd_error_bound``, and sampled builders stamp their keys with
# ``+sampled{rate}`` — exact, binned, and sampled cells of one
# workload can never be confused in a shared store.
# v4 (unversioned addition): the ``explore`` kind persists
# config-sweep search results (repro.explore) — best config, top-k,
# round-by-round trajectory — keyed by explore_key(); purely additive,
# so existing stores stay readable.
STORE_VERSION = 4

_KINDS = ("profile", "exact", "validation", "workload", "explore")


def atomic_write(target: Path, write_fn) -> None:
    """Write via a same-directory temp file + fsync + ``os.replace`` —
    readers never observe a partial payload, a crashed writer leaves
    no temp file, and concurrent writers each use a private name."""
    target.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=target.parent, prefix=f".{target.name}.", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "wb") as fh:
            write_fn(fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, target)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_bytes(target: str | Path, blob: bytes) -> None:
    atomic_write(Path(target), lambda fh: fh.write(blob))


@dataclasses.dataclass
class StoreStats:
    """Observable store behaviour (asserted by tests and the runner)."""

    hits: int = 0
    misses: int = 0
    puts: int = 0
    corrupt: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class ArtifactStore:
    """Filesystem key-value store for npz and json artifact payloads.

    Keys are plain strings (callers derive them from trace content
    hashes plus grid coordinates); kinds namespace the payload type.
    One store may be shared by any number of Sessions and processes —
    writes are atomic and last-writer-wins (all writers produce the
    same bytes for a given key, by construction of the keys).
    """

    def __init__(self, root: str | Path, *, version: int = STORE_VERSION):
        self.root = Path(root)
        self.version = int(version)
        self.stats = StoreStats()

    # --- paths ------------------------------------------------------------

    def _dir(self, kind: str) -> Path:
        return self.root / f"v{self.version}" / kind

    def path(self, kind: str, key: str, ext: str) -> Path:
        return self._dir(kind) / f"{key}.{ext}"

    def keys(self, kind: str) -> list[str]:
        d = self._dir(kind)
        if not d.is_dir():
            return []
        return sorted(p.stem for p in d.iterdir() if p.is_file())

    def _drop_corrupt(self, path: Path, seen: os.stat_result | None) -> None:
        """Clear a corrupt payload — unless a concurrent writer already
        replaced it.

        Between this reader's failed decode and its unlink, another
        service worker may have healed the cell with a complete
        rewrite; unconditionally unlinking would delete the *good*
        file.  Comparing the pre-read stat identity (inode, mtime,
        size) to the current one detects the swap.  The residual
        stat-to-unlink window is benign: deleting a healed file can
        only cost a recompute, never serve bad data.
        """
        self.stats.corrupt += 1
        try:
            if seen is not None:
                cur = path.stat()
                if ((cur.st_ino, cur.st_mtime_ns, cur.st_size)
                        != (seen.st_ino, seen.st_mtime_ns, seen.st_size)):
                    return  # healed since we read it — keep the new file
            path.unlink()
        except OSError:
            pass

    # --- npz payloads (numpy arrays + a json meta record) ------------------

    def put_arrays(
        self, kind: str, key: str,
        arrays: dict[str, np.ndarray], meta: dict | None = None,
    ) -> Path:
        """Persist named arrays plus a json-serializable ``meta`` dict
        as one atomic npz file."""
        target = self.path(kind, key, "npz")
        payload = dict(arrays)
        payload["__meta__"] = np.frombuffer(
            json.dumps(meta or {}).encode(), dtype=np.uint8
        )
        atomic_write(target, lambda fh: np.savez(fh, **payload))
        self.stats.puts += 1
        return target

    def get_arrays(
        self, kind: str, key: str
    ) -> tuple[dict[str, np.ndarray], dict] | None:
        """Load (arrays, meta) for a key, or None on miss/corruption."""
        path = self.path(kind, key, "npz")
        try:
            seen = path.stat()  # pre-read identity, guards the heal race
        except OSError:
            self.stats.misses += 1
            return None
        try:
            with np.load(path) as data:
                arrays = {k: data[k] for k in data.files if k != "__meta__"}
                meta = json.loads(bytes(data["__meta__"]).decode())
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile, json.JSONDecodeError):
            # truncated/partial/undecodable file: treat as a miss and
            # clear it so the recompute's rewrite heals the store
            self._drop_corrupt(path, seen)
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        return arrays, meta

    # --- json payloads -----------------------------------------------------

    def put_json(self, kind: str, key: str, obj) -> Path:
        target = self.path(kind, key, "json")
        blob = json.dumps(obj, indent=2, default=float).encode()
        atomic_write_bytes(target, blob)
        self.stats.puts += 1
        return target

    def get_json(self, kind: str, key: str):
        path = self.path(kind, key, "json")
        try:
            seen = path.stat()
        except OSError:
            self.stats.misses += 1
            return None
        try:
            obj = json.loads(path.read_text())
        except (OSError, ValueError, json.JSONDecodeError):
            self._drop_corrupt(path, seen)
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        return obj


# --- ProfileArtifacts (de)serialization -------------------------------------
#
# The store persists the *profiles* of a grid cell (the expensive
# Fenwick-pass output), not the mimicked traces: traces are cheap O(N)
# rebuilds that Session materializes on demand (``need_traces``) for
# trace-consuming models like ExactLRU.


def builder_fingerprint(builder) -> str:
    """Identity of the profile builder that produced a cell.

    Different builders produce different profiles for the same grid
    coordinates, so the disk key must separate them (the in-memory
    cache is per-Session and never mixes builders).  A builder may
    override via a ``store_fingerprint`` attribute; the default is its
    qualified class name."""
    fp = getattr(builder, "store_fingerprint", None)
    if fp:
        return str(fp)
    cls = type(builder)
    return f"{cls.__module__}.{cls.__qualname__}".replace("/", "_")


DEFAULT_BUILDER_FP = "repro.api.stages.MimicProfileBuilder"


def artifact_key(tid: str, line_size: int, cores: int, strategy: str,
                 seed: int, window_size: int | None,
                 builder: str = DEFAULT_BUILDER_FP) -> str:
    """Stable store key for one profile cell — mirrors the Session's
    in-memory cache key, rooted in the trace content hash and stamped
    with the producing builder's identity."""
    return (
        f"{tid}-l{line_size}-c{cores}-{strategy}-s{seed}"
        f"-w{window_size or 0}-{builder}"
    )


def save_profile_artifacts(store: ArtifactStore, art,
                           builder: str = DEFAULT_BUILDER_FP) -> Path:
    """Persist one ProfileArtifacts cell (PRD/CRD histograms + cell
    coordinates).  The traces are intentionally not stored."""
    key = artifact_key(art.trace_id, art.line_size, art.cores,
                       art.strategy, art.seed, art.window_size, builder)
    return store.put_arrays(
        "profile", key,
        {
            "prd_distances": np.asarray(art.prd.distances, dtype=np.int64),
            "prd_counts": np.asarray(art.prd.counts, dtype=np.int64),
            "crd_distances": np.asarray(art.crd.distances, dtype=np.int64),
            "crd_counts": np.asarray(art.crd.counts, dtype=np.int64),
        },
        # "builder" is write-only provenance: the artifact key already
        # encodes the builder fingerprint, so the loader never needs it
        # back; it exists for humans inspecting the store directory.
        # repro-lint: disable=CK403 -- builder is write-only provenance
        {
            "trace_id": art.trace_id,
            "cores": art.cores,
            "strategy": art.strategy,
            "seed": art.seed,
            "line_size": art.line_size,
            "window_size": art.window_size,
            "binned": bool(getattr(art, "binned", False)),
            "sampled": getattr(art, "sampled", None),
            "prd_error_bound": art.prd.error_bound,
            "crd_error_bound": art.crd.error_bound,
            "builder": builder,
        },
    )


def load_profile_artifacts(
    store: ArtifactStore, tid: str, line_size: int, cores: int,
    strategy: str, seed: int, window_size: int | None,
    builder: str = DEFAULT_BUILDER_FP,
):
    """Load one profile cell, or None.  The returned artifact carries
    no traces (``privates == []``, ``shared is None``); Session
    rematerializes them from the cached trace when a trace-consuming
    stage (ExactLRU ground truth) asks."""
    from repro.api.stages import ProfileArtifacts
    from repro.core.reuse.profile import ReuseProfile

    key = artifact_key(tid, line_size, cores, strategy, seed, window_size,
                       builder)
    found = store.get_arrays("profile", key)
    if found is None:
        return None
    arrays, meta = found

    def prof(prefix: str) -> ReuseProfile:
        counts = arrays[f"{prefix}_counts"].astype(np.int64)
        bound = meta.get(f"{prefix}_error_bound")
        return ReuseProfile(
            arrays[f"{prefix}_distances"].astype(np.int64),
            counts, int(counts.sum()),
            float(bound) if bound is not None else None,
        )

    sampled = meta.get("sampled")
    return ProfileArtifacts(
        trace_id=meta["trace_id"], cores=int(meta["cores"]),
        strategy=meta["strategy"], seed=int(meta["seed"]),
        line_size=int(meta["line_size"]), privates=[], shared=None,
        prd=prof("prd"), crd=prof("crd"),
        window_size=meta.get("window_size"),
        binned=bool(meta.get("binned", False)),
        sampled=float(sampled) if sampled is not None else None,
    )
