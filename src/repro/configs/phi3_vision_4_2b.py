"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (STUB),
hf:microsoft/Phi-3-vision-128k-instruct.

32L, d_model=3072, 32 heads (MHA kv=32, head_dim=96), d_ff=8192,
vocab=32064.  ``input_specs()`` provides precomputed patch embeddings
(1024 patches of clip_dim=1024); loss is computed on text positions.
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.multimodal import VLMConfig
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="phi-3-vision-4.2b",
    family_name="vlm",
    config=VLMConfig(
        backbone=TransformerConfig(
            layers=32,
            d_model=3072,
            heads=32,
            kv_heads=32,
            d_ff=8192,
            vocab=32064,
            head_dim=96,
            rope_theta=10000.0,
        ),
        clip_dim=1024,
        num_patches=1024,
    ),
    rules={"kv_heads": "tp", "act_kv_heads": "tp", "act_kv_seq": None},
    grad_accum={"train_4k": 4},
    skip={"long_500k": FULL_ATTN_SKIP},
)
