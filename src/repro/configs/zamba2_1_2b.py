"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block,
arXiv:2411.15242.

38 Mamba2 layers, d_model=2048 (d_inner=4096, 64 SSD heads of P=64),
ssm_state=64, shared attn block (32H, kv=32, head_dim=64, d_ff=8192)
applied every 6 layers on concat(hidden, embedding).  ``long_500k``
RUNS (hybrid family): SSM state is O(1); the shared-attn KV cache is
linear in S across only ~6 application sites.
"""
from repro.configs.base import ArchSpec
from repro.models.hybrid import Zamba2Config

SPEC = ArchSpec(
    arch_id="zamba2-1.2b",
    family_name="hybrid",
    config=Zamba2Config(
        layers=38,
        d_model=2048,
        vocab=32000,
        heads=32,
        kv_heads=32,
        d_ff=8192,
        ssm_state=64,
        head_dim=64,
        attn_every=6,
        tie_embeddings=True,
    ),
    rules={"kv_heads": "tp", "act_kv_heads": "tp", "act_kv_seq": None},
    grad_accum={"train_4k": 8},
)
