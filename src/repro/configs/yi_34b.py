"""yi-34b [dense] — llama-arch GQA, arXiv:2403.04652.

60L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=20480,
vocab=64000.  56 heads don't divide the 16-way model axis (GSPMD
rejects uneven shards — probe-verified), so attention runs
sequence-parallel (queries sharded over "model", K/V gathered) and
heads stay replicated; MLP/vocab use standard TP.
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="yi-34b",
    family_name="transformer",
    config=TransformerConfig(
        layers=60,
        d_model=7168,
        heads=56,
        kv_heads=8,
        d_ff=20480,
        vocab=64000,
        head_dim=128,
        rope_theta=5_000_000.0,
        attn_sp=True,
        sp_residuals=True,
    ),
    rules={"heads": None},          # 56 % 16 != 0
    grad_accum={"train_4k": 1},     # §Perf cell-1 lesson applied
    skip={"long_500k": FULL_ATTN_SKIP},
)
