"""ArchSpec: binds a model family + exact config to the assigned input
shapes, sharding-rule overrides, and memory knobs (grad accumulation).

Every assigned architecture gets one ``<arch>.py`` exporting ``SPEC``;
the registry in ``repro.configs`` exposes them by ``--arch`` id.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import Family, get_family


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = Shape("train_4k", 4096, 256, "train")
PREFILL_32K = Shape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = Shape("decode_32k", 32768, 128, "decode")
LONG_500K = Shape("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

FULL_ATTN_SKIP = (
    "pure full attention — long_500k requires sub-quadratic attention "
    "(DESIGN.md §4); decode over a 512k KV cache would be O(S) per token "
    "with an O(S) resident cache"
)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family_name: str
    config: Any
    rules: dict[str, str | None] = dataclasses.field(default_factory=dict)
    serve_rules: dict[str, str | None] = dataclasses.field(default_factory=dict)
    grad_accum: dict[str, int] = dataclasses.field(default_factory=dict)
    accum_dtype: Any = jnp.float32
    optimizer_name: str = "adamw"
    peak_lr: float = 3e-4
    skip: dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""
    # MODEL_FLOPS accounting: fraction of shape.seq_len each parameter
    # actually processes (enc-dec splits seq_len into src/tgt halves)
    flops_token_factor: float = 1.0
    # ZeRO-1 style: optimizer-state sharding rules may differ from the
    # parameter rules (e.g. params TP-resident, moments dp+tp sharded)
    opt_rules: dict[str, str | None] = dataclasses.field(default_factory=dict)

    @property
    def family(self) -> Family:
        return get_family(self.family_name)

    @property
    def vocab(self) -> int:
        cfg = self.config
        return getattr(cfg, "vocab", None) or cfg.backbone.vocab

    def shapes(self) -> list[Shape]:
        return [s for s in SHAPES.values() if s.name not in self.skip]

    def rules_for(self, kind: str) -> dict[str, str | None]:
        merged = dict(self.rules)
        if kind != "train":
            merged.update(self.serve_rules)
        return merged

    # --- abstract inputs (ShapeDtypeStruct stand-ins; nothing allocated) ---

    def input_specs(self, shape: Shape) -> dict[str, jax.ShapeDtypeStruct]:
        b, s = shape.global_batch, shape.seq_len
        i32, f = jnp.int32, getattr(self.config, "dtype", jnp.bfloat16)
        sds = jax.ShapeDtypeStruct
        if self.family_name == "encdec":
            d = self.config.d_model
            if shape.kind == "train":
                return {"frames": sds((b, s // 2, d), f),
                        "tokens": sds((b, s // 2), i32),
                        "labels": sds((b, s // 2), i32)}
            if shape.kind == "prefill":
                return {"frames": sds((b, s // 2, d), f),
                        "tokens": sds((b, s // 2), i32)}
            return {"token": sds((b, 1), i32)}
        if self.family_name == "vlm":
            cfg = self.config
            p = cfg.num_patches
            dt = cfg.backbone.dtype
            if shape.kind == "train":
                return {"patches": sds((b, p, cfg.clip_dim), dt),
                        "tokens": sds((b, s - p), i32),
                        "labels": sds((b, s - p), i32)}
            if shape.kind == "prefill":
                return {"patches": sds((b, p, cfg.clip_dim), dt),
                        "tokens": sds((b, s - p), i32)}
            return {"token": sds((b, 1), i32)}
        if shape.kind == "train":
            return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if shape.kind == "prefill":
            return {"tokens": sds((b, s), i32)}
        return {"token": sds((b, 1), i32)}

    def batch_axes(self, shape: Shape) -> dict[str, tuple]:
        specs = self.input_specs(shape)
        return {
            name: ("act_batch",) + (None,) * (len(s.shape) - 1)
            for name, s in specs.items()
        }

    def cache_kwargs(self, shape: Shape) -> dict[str, int]:
        b, s = shape.global_batch, shape.seq_len
        if self.family_name == "encdec":
            return {"batch": b, "max_len": s // 2, "src_len": s // 2}
        return {"batch": b, "max_len": s}

    def grad_accum_for(self, shape: Shape) -> int:
        return self.grad_accum.get(shape.name, 1)
