"""llama3-8b [dense] — GQA + 128k vocab, arXiv:2407.21783.

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=128256 (padded to 128256 -> /16 = 8016 per shard).
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="llama3-8b",
    family_name="transformer",
    config=TransformerConfig(
        layers=32,
        d_model=4096,
        heads=32,
        kv_heads=8,
        d_ff=14336,
        vocab=128256,
        head_dim=128,
        rope_theta=500000.0,
    ),
    grad_accum={"train_4k": 4},
    skip={"long_500k": FULL_ATTN_SKIP},
)
