"""mixtral-8x7b [moe] — 8 experts top-2 + sliding-window attention,
arXiv:2401.04088.

32L, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=14336,
vocab=32000, window=4096.  8 experts < 16 devices -> experts stay
replicated and the expert FFN dim is TP-sharded (TP-MoE).  SWA is
sub-quadratic, so ``long_500k`` RUNS (banded attention in prefill;
decode reads only the masked window).
"""
from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="mixtral-8x7b",
    family_name="transformer",
    config=TransformerConfig(
        layers=32,
        d_model=4096,
        heads=32,
        kv_heads=8,
        d_ff=14336,
        vocab=32000,
        head_dim=128,
        rope_theta=1_000_000.0,
        window=4096,
        moe=MoEConfig(num_experts=8, top_k=2, tokens_per_group=4096),
        dense_ff=False,
    ),
    rules={"experts": None},   # 8 % 16 != 0 -> TP-MoE over the FFN dim
    grad_accum={"train_4k": 4},
)
