"""Reduced (smoke-test) variants of every assigned architecture — same
family and code paths, small dims.  The FULL configs are exercised only
by the dry-run (ShapeDtypeStruct, no allocation); these run real
forward/train steps on 1 CPU device.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_arch
from repro.configs.base import ArchSpec, Shape
from repro.models.encdec import EncDecConfig
from repro.models.hybrid import Zamba2Config
from repro.models.moe import MoEConfig
from repro.models.multimodal import VLMConfig
from repro.models.ssm import Mamba2Config
from repro.models.transformer import TransformerConfig

SMOKE_SHAPE = Shape("smoke", 64, 4, "train")
SMOKE_PREFILL = Shape("smoke_prefill", 32, 2, "prefill")
SMOKE_DECODE = Shape("smoke_decode", 32, 2, "decode")


def _reduce_transformer(cfg: TransformerConfig) -> TransformerConfig:
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=4, top_k=2, tokens_per_group=32,
                        capacity_factor=cfg.moe.capacity_factor)
    return dataclasses.replace(
        cfg, layers=2, d_model=64, heads=4, kv_heads=min(cfg.kv_heads, 2) if
        cfg.kv_heads < cfg.heads else 4, d_ff=128, vocab=256, head_dim=16,
        window=16 if cfg.window else None, moe=moe, block_q=16,
        vocab_pad_multiple=32,
    )


def reduced(spec: ArchSpec) -> ArchSpec:
    cfg = spec.config
    if isinstance(cfg, TransformerConfig):
        small = _reduce_transformer(cfg)
    elif isinstance(cfg, Mamba2Config):
        small = dataclasses.replace(
            cfg, layers=2, d_model=32, vocab=256, ssm_state=16, head_dim=8,
            chunk=8, vocab_pad_multiple=32,
        )
    elif isinstance(cfg, Zamba2Config):
        small = dataclasses.replace(
            cfg, layers=5, d_model=32, vocab=256, heads=4, kv_heads=4,
            d_ff=64, ssm_state=16, head_dim=8, attn_every=2, chunk=8,
            block_q=16, vocab_pad_multiple=32,
        )
    elif isinstance(cfg, EncDecConfig):
        small = dataclasses.replace(
            cfg, enc_layers=2, dec_layers=2, d_model=32, heads=4, kv_heads=4,
            d_ff=64, vocab=256, head_dim=8, block_q=16, vocab_pad_multiple=32,
        )
    elif isinstance(cfg, VLMConfig):
        small = VLMConfig(
            backbone=_reduce_transformer(cfg.backbone),
            clip_dim=24, num_patches=8,
        )
    else:
        raise TypeError(type(cfg))
    return dataclasses.replace(
        spec, config=small, grad_accum={"smoke": 2}, skip={},
    )


def reduced_arch(arch_id: str) -> ArchSpec:
    return reduced(get_arch(arch_id))
