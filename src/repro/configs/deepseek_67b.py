"""deepseek-67b [dense] — llama-arch, arXiv:2401.02954.

95L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=22016,
vocab=102400.  The depth-95 config is why every stack in this framework
scans layers: HLO size and compile time must be O(1) in depth.
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="deepseek-67b",
    family_name="transformer",
    config=TransformerConfig(
        layers=95,
        d_model=8192,
        heads=64,
        kv_heads=8,
        d_ff=22016,
        vocab=102400,
        head_dim=128,
        rope_theta=10000.0,
        sp_residuals=True,   # 95 saved carries/chip: seq-shard them (SP)
    ),
    # §Perf cell 1: accum=1 with SP residuals is 6.7x less collective
    # traffic than the ZeRO-3-faithful accum=16 baseline
    grad_accum={"train_4k": 1},
    skip={"long_500k": FULL_ATTN_SKIP},
)
