"""mamba2-780m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L, d_model=1536 (d_inner=3072, 48 SSD heads of P=64), ssm_state=128,
vocab=50280, attention-free.  ``long_500k`` RUNS: decode state is O(1).
"""
from repro.configs.base import ArchSpec
from repro.models.ssm import Mamba2Config

SPEC = ArchSpec(
    arch_id="mamba2-780m",
    family_name="ssm",
    config=Mamba2Config(
        layers=48,
        d_model=1536,
        vocab=50280,
        ssm_state=128,
        head_dim=64,
        tie_embeddings=True,
    ),
    # §Perf cell 3: a 780M model is over-sharded at TP=16 — flat 256-way
    # DP (batch over data x model) with a ZeRO-sharded optimizer is 2.8x
    # faster at the bound; serving re-shards batch over "data" only
    # (decode batch 128 doesn't divide 256).
    rules={
        "act_batch": "dp+tp", "inner": None, "conv_dim": None,
        "ssm_heads": None, "act_mlp": None, "act_heads": None,
        "vocab": None, "act_vocab": None, "embed": None,
    },
    opt_rules={"embed": "dp+tp"},
    # serving keeps the TP layout: prefill batch 32 / decode batch 128
    # can't divide the 256-chip grid, so flat-DP would idle the model
    # axis (measured: 3.7x worse prefill bound)
    serve_rules={
        "act_batch": "dp", "inner": "tp", "conv_dim": "tp",
        "ssm_heads": "tp", "act_mlp": "tp", "act_heads": "tp",
        "vocab": "tp", "act_vocab": "tp", "embed": "dp",
    },
    grad_accum={"train_4k": 1},
    notes="paper-representative cell: the SSD chunked scan is the Pallas "
          "kernel hot spot (kernels/ssd_scan)",
)
