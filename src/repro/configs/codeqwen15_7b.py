"""codeqwen1.5-7b [dense] — qwen1.5-arch, hf:Qwen/CodeQwen1.5-7B.

32L, d_model=4096, 32 heads (kv=32 — full MHA KV), d_ff=13440,
vocab=92416.  kv_heads=32 divides the model axis, so the decode cache
can shard over kv heads as well as sequence.
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="codeqwen1.5-7b",
    family_name="transformer",
    config=TransformerConfig(
        layers=32,
        d_model=4096,
        heads=32,
        kv_heads=32,
        d_ff=13440,
        vocab=92416,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    # full-MHA KV: shard the decode cache over kv heads (32/16) instead
    # of sequence — no reshard churn against the head-TP attention math
    rules={"kv_heads": "tp", "act_kv_heads": "tp", "act_kv_seq": None},
    grad_accum={"train_4k": 4},
    skip={"long_500k": FULL_ATTN_SKIP},
)
