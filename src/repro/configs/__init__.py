"""Architecture registry: ``get_arch("<id>")`` / ``--arch <id>``."""
from repro.configs.base import (
    ArchSpec, Shape, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

from repro.configs import (  # noqa: E402
    arctic_480b,
    codeqwen15_7b,
    deepseek_67b,
    llama3_8b,
    mamba2_780m,
    mixtral_8x7b,
    phi3_vision_4_2b,
    seamless_m4t_medium,
    yi_34b,
    zamba2_1_2b,
)

REGISTRY: dict[str, ArchSpec] = {
    m.SPEC.arch_id: m.SPEC
    for m in (
        mamba2_780m, yi_34b, deepseek_67b, llama3_8b, codeqwen15_7b,
        arctic_480b, mixtral_8x7b, seamless_m4t_medium, phi3_vision_4_2b,
        zamba2_1_2b,
    )
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch_id]


def list_archs() -> list[str]:
    return sorted(REGISTRY)


__all__ = [
    "ArchSpec", "Shape", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "REGISTRY", "get_arch", "list_archs",
]
