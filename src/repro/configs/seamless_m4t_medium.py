"""seamless-m4t-medium [audio] — enc-dec multimodal backbone,
arXiv:2308.11596.

12L encoder + 12L decoder, d_model=1024, 16 heads (MHA kv=16,
head_dim=64), d_ff=4096, vocab=256206 (padded to 256256 for 16-way TP).
The audio frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings.  Shapes split seq_len as source-half /
target-half (DESIGN.md §5).
"""
from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.encdec import EncDecConfig

SPEC = ArchSpec(
    arch_id="seamless-m4t-medium",
    family_name="encdec",
    config=EncDecConfig(
        enc_layers=12,
        dec_layers=12,
        d_model=1024,
        heads=16,
        kv_heads=16,
        d_ff=4096,
        vocab=256206,
        head_dim=64,
    ),
    rules={"kv_heads": "tp", "act_kv_heads": "tp", "act_kv_seq": None},
    grad_accum={"train_4k": 1},
    flops_token_factor=0.5,  # src/tgt halves each traverse half the stack
    skip={"long_500k": FULL_ATTN_SKIP},
)
