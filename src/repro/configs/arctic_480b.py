"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP,
hf:Snowflake/snowflake-arctic-base.

35L, d_model=7168, 56 heads (GQA kv=8), per-expert d_ff=4864,
vocab=32000.  Memory plan (DESIGN.md §6): experts sharded over "model"
(8/chip — true EP), every weight FSDP-sharded over "data"; Adafactor
(factored second moment) + bf16 grad accumulators keep the 480B state
under 16 GB/chip.  56 heads -> sequence-parallel attention like yi-34b.
"""
import jax.numpy as jnp

from repro.configs.base import FULL_ATTN_SKIP, ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

SPEC = ArchSpec(
    arch_id="arctic-480b",
    family_name="transformer",
    config=TransformerConfig(
        layers=35,
        d_model=7168,
        heads=56,
        kv_heads=8,
        d_ff=4864,
        vocab=32000,
        head_dim=128,
        attn_sp=True,
        sp_residuals=True,      # §Perf cell 2 (3.3x collective win)
        moe=MoEConfig(num_experts=128, top_k=2, tokens_per_group=1024),
        dense_ff=True,          # arctic's dense residual MLP branch
    ),
    # expert d_ff unsharded (EP over "model" instead); act_mlp must match
    # or the [G,E,C,F] expert activations would map "model" twice
    rules={"heads": None, "mlp": None, "act_mlp": None},
    serve_rules={"embed": "dp"},          # weights must stay fully sharded
    grad_accum={"train_4k": 1},
    accum_dtype=jnp.bfloat16,
    optimizer_name="adafactor",
    skip={"long_500k": FULL_ATTN_SKIP},
    notes="most-collective-bound hillclimb candidate: EP all-to-all + "
          "FSDP gathers",
)
