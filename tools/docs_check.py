"""Docs lint: every intra-repo link, referenced path, and documented
``python -m`` entrypoint in README.md and docs/*.md must resolve.

    python tools/docs_check.py            # exit 1 on any dangling ref

Three checks:

1. **Markdown links** — ``[text](target)`` with a non-http, non-anchor
   target must point at an existing file/dir (resolved relative to the
   doc, then the repo root).
2. **Backticked paths** — `...`-quoted tokens that look like repo
   paths (contain a ``/`` and end in a known extension, or live under
   a top-level source dir) must exist.  A trailing ``::symbol`` is
   stripped first.
3. **Documented commands** — every ``python -m <module>`` must name an
   importable module under ``src``/the repo root (spec lookup only;
   nothing is executed here — CI smoke-runs the service CLI
   separately).
4. **Lint rule catalogue** — every rule ID mentioned in
   ``docs/lint.md`` must exist in ``repro.lint.rules.RULES``, and
   every registered rule must be documented there (both directions,
   so the catalogue can never drift from the registry).
5. **Runtime timing tables** — the per-class (δ, β, ports) tables in
   ``docs/runtime.md`` must match the ``incore`` tables on
   ``repro.hw.targets.ALL_TARGETS`` both directions: every table-
   carrying target documented, every documented section/row backed by
   the code values.
6. **Sampling error bound** — ``docs/sampling.md``'s documented
   ``SAMPLE_BOUND_DELTA`` and Bernstein closed form must match
   ``repro.core.reuse.sampled`` (the documented formula, recomputed at
   a reference point, must equal ``sampling_error_bound``).
7. **Explore axes** — the search-space axis table in
   ``docs/explore.md`` must name exactly the axes of
   ``repro.explore.SearchSpace.AXES`` (both directions), and the
   documented agent names must match ``repro.explore.AGENTS``.

Run by the CI ``docs-check`` job and by ``tests/docs/test_docs.py``,
so documentation drift fails the build instead of accumulating.
"""
from __future__ import annotations

import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICK_RE = re.compile(r"`([^`\n]+)`")
PYMOD_RE = re.compile(r"python\s+(?:-\S+\s+)*-m\s+([A-Za-z_][\w.]*)")

PATH_EXTS = (".py", ".md", ".json", ".yml", ".toml", ".npz", ".txt")
PATH_ROOTS = ("src/", "docs/", "tests/", "benchmarks/", "examples/",
              "experiments/", "tools/", ".github/")


def iter_docs():
    for doc in DOC_FILES:
        if doc.is_file():
            yield doc, doc.read_text()


def _strip_code_fences(text: str) -> str:
    """Fenced code blocks keep inline-path checks but not link checks
    (they hold shell output, not markdown)."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def _exists(target: str, doc: Path) -> bool:
    """Resolve against the doc's dir, the repo root, and the repo's
    two established shorthand roots (``repro/...`` means
    ``src/repro/...``; package-relative like ``api/batched.py`` means
    ``src/repro/api/batched.py``)."""
    candidates = (
        doc.parent / target,
        REPO / target,
        REPO / "src" / target,
        REPO / "src" / "repro" / target,
    )
    return any(c.exists() for c in candidates)


def check_links(doc: Path, text: str) -> list[str]:
    problems = []
    for target in LINK_RE.findall(_strip_code_fences(text)):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        bare = target.split("#", 1)[0]
        if bare and not _exists(bare, doc):
            problems.append(f"{doc.name}: dangling link ({target})")
    return problems


def looks_like_path(token: str) -> bool:
    if any(ch in token for ch in " *{}<>$(),=") or "://" in token:
        return False
    if token.startswith(PATH_ROOTS):
        return True
    return "/" in token and token.endswith(PATH_EXTS)


def check_paths(doc: Path, text: str) -> list[str]:
    problems = []
    for token in TICK_RE.findall(text):
        token = token.split("::", 1)[0].strip()
        if not looks_like_path(token):
            continue
        if not _exists(token, doc):
            problems.append(f"{doc.name}: missing path `{token}`")
    return problems


def check_commands(doc: Path, text: str) -> list[str]:
    problems = []
    for mod in PYMOD_RE.findall(text):
        if mod == "pytest":
            continue  # third-party, not a repo module
        try:
            spec = importlib.util.find_spec(mod)
            if spec is None:
                raise ModuleNotFoundError(mod)
            # a runnable -m target needs __main__ (or to be a module)
            if spec.submodule_search_locations is not None:
                if importlib.util.find_spec(mod + ".__main__") is None:
                    raise ModuleNotFoundError(f"{mod}.__main__")
        except (ImportError, ModuleNotFoundError) as exc:
            problems.append(
                f"{doc.name}: documented command `python -m {mod}` "
                f"does not resolve ({exc})"
            )
    return problems


RULE_ID_RE = re.compile(r"\b(?:JP|DN|CC|CK)\d{3}\b")


def check_lint_rules() -> list[str]:
    """docs/lint.md and repro.lint.rules.RULES must agree exactly."""
    doc = REPO / "docs" / "lint.md"
    if not doc.is_file():
        return ["docs/lint.md: missing (the lint rule catalogue must "
                "be documented)"]
    try:
        from repro.lint.rules import RULES
    except ImportError as exc:
        return [f"lint.md: cannot import repro.lint.rules ({exc})"]
    documented = set(RULE_ID_RE.findall(doc.read_text()))
    registered = set(RULES)
    problems = []
    for rid in sorted(documented - registered):
        problems.append(f"lint.md: documents rule {rid} which is not "
                        f"in repro.lint.rules.RULES")
    for rid in sorted(registered - documented):
        problems.append(f"lint.md: rule {rid} is registered in "
                        f"repro.lint.rules but not documented")
    return problems


# docs/runtime.md timing-table row: | class | δ | β | ports |
TIMING_ROW_RE = re.compile(
    r"^\|\s*(int|fp|div|load|store)\s*\|\s*([\d.]+)\s*\|\s*([\d.]+)\s*"
    r"\|\s*(\d+)\s*\|\s*$"
)
# docs class labels -> InCoreTimings field names
TIMING_CLASS_FIELD = {"int": "int_ops", "fp": "fp_ops", "div": "div_ops",
                      "load": "loads", "store": "stores"}


def _parse_timing_sections(text: str) -> dict[str, dict[str, tuple]]:
    """``### <target>`` sections of docs/runtime.md -> their parsed
    timing rows: {target: {class: (delta, beta, ports)}}."""
    sections: dict[str, dict[str, tuple]] = {}
    current: dict[str, tuple] | None = None
    for line in text.splitlines():
        if line.startswith("### "):
            current = sections.setdefault(line[4:].strip(), {})
            continue
        m = TIMING_ROW_RE.match(line.strip())
        if m and current is not None:
            current[m.group(1)] = (
                float(m.group(2)), float(m.group(3)), int(m.group(4))
            )
    # prose-only sections (no timing rows) are not timing tables
    return {name: rows for name, rows in sections.items() if rows}


def check_runtime_timings() -> list[str]:
    """docs/runtime.md tables and hw.targets incore tables must agree
    exactly, both directions."""
    doc = REPO / "docs" / "runtime.md"
    if not doc.is_file():
        return ["docs/runtime.md: missing (the runtime-model timing "
                "tables must be documented)"]
    try:
        from repro.hw.targets import ALL_TARGETS
    except ImportError as exc:
        return [f"runtime.md: cannot import repro.hw.targets ({exc})"]
    documented = _parse_timing_sections(doc.read_text())
    in_code = {
        name: t.incore for name, t in ALL_TARGETS.items()
        if getattr(t, "incore", None) is not None
    }
    problems = []
    for name in sorted(set(in_code) - set(documented)):
        problems.append(f"runtime.md: target {name!r} carries an incore "
                        "table but has no timing section")
    for name in sorted(set(documented) - set(in_code)):
        problems.append(f"runtime.md: documents a timing table for "
                        f"{name!r}, which has no incore table in "
                        "repro.hw.targets")
    for name in sorted(set(documented) & set(in_code)):
        rows, table = documented[name], in_code[name]
        for cls, field_name in TIMING_CLASS_FIELD.items():
            timing = getattr(table, field_name)
            if cls not in rows:
                problems.append(f"runtime.md: {name}: class {cls!r} "
                                "missing from the timing table")
                continue
            delta, beta, ports = rows[cls]
            code_vals = (timing.delta, timing.beta, timing.ports)
            if (abs(delta - timing.delta) > 1e-9
                    or abs(beta - timing.beta) > 1e-9
                    or ports != timing.ports):
                problems.append(
                    f"runtime.md: {name}/{cls}: documented "
                    f"(δ={delta:g}, β={beta:g}, ports={ports}) != code "
                    f"(δ={code_vals[0]:g}, β={code_vals[1]:g}, "
                    f"ports={code_vals[2]})"
                )
        for cls in sorted(set(rows) - set(TIMING_CLASS_FIELD)):
            problems.append(f"runtime.md: {name}: unknown class {cls!r}")
    return problems


def check_sampling_bound() -> list[str]:
    """docs/sampling.md's documented error-bound constants and closed
    form must match repro.core.reuse.sampled."""
    import math

    doc = REPO / "docs" / "sampling.md"
    if not doc.is_file():
        return ["docs/sampling.md: missing (the sampled-profile error "
                "bound must be documented)"]
    try:
        from repro.core.reuse import sampled
    except ImportError as exc:
        return [f"sampling.md: cannot import repro.core.reuse.sampled "
                f"({exc})"]
    text = doc.read_text()
    problems = []
    m = re.search(r"SAMPLE_BOUND_DELTA\s*=\s*([0-9eE.+-]+)", text)
    if not m:
        problems.append("sampling.md: does not document the "
                        "SAMPLE_BOUND_DELTA value")
    elif float(m.group(1)) != sampled.SAMPLE_BOUND_DELTA:
        problems.append(
            f"sampling.md: documents SAMPLE_BOUND_DELTA = {m.group(1)}, "
            f"code has {sampled.SAMPLE_BOUND_DELTA:g}"
        )
    # the documented closed form must survive verbatim — and, recomputed
    # at a reference point, must equal the implementation
    for fragment in ("ln(2 (n+1) / SAMPLE_BOUND_DELTA",
                     "sum_l w_l^2 / (R * n^2)",
                     "sqrt(2 V L) + w_max L / (3 R n)",
                     "eps * n / S_hat + |n - S_hat| / S_hat"):
        if fragment not in text:
            problems.append(
                f"sampling.md: formula fragment `{fragment}` missing — "
                "keep the documented closed form in sync with "
                "sampling_error_bound"
            )
    rate, n, ssq, wmax = 0.5, 10_000, 4.0e5, 80.0
    log_term = math.log(2.0 * (n + 1) / sampled.SAMPLE_BOUND_DELTA)
    variance = (1.0 - rate) * ssq / (rate * n**2)
    expected = min(1.0, math.sqrt(2.0 * variance * log_term)
                   + wmax * log_term / (3.0 * rate * n))
    got = sampled.sampling_error_bound(
        rate, n, sq_line_mass=ssq, max_line_mass=wmax
    )
    if abs(got - expected) > 1e-12:
        problems.append(
            f"sampling.md: the documented closed form gives {expected!r} "
            f"at the reference point, sampling_error_bound returns {got!r}"
        )
    if sampled.sampling_error_bound(1.0, n) != 0.0:
        problems.append("sampling.md: documents bound == 0.0 at "
                        "rate >= 1.0; the code disagrees")
    # the Hajek ratio correction: eps * n / S_hat + |n - S_hat| / S_hat
    kept = 3_000
    s_hat = kept / rate
    expected_hajek = min(1.0, (expected * n / s_hat)
                         + abs(n - s_hat) / s_hat)
    got_hajek = sampled.sampling_error_bound(
        rate, n, sq_line_mass=ssq, max_line_mass=wmax, kept_refs=kept
    )
    if abs(got_hajek - expected_hajek) > 1e-12:
        problems.append(
            f"sampling.md: the documented Hajek ratio form gives "
            f"{expected_hajek!r} at the reference point, "
            f"sampling_error_bound returns {got_hajek!r}"
        )
    return problems


# docs/explore.md table row whose first column is a backticked name
NAMED_ROW_RE = re.compile(r"^\|\s*`([a-z_]+)`\s*\|")


def _named_table_rows(text: str, heading_substr: str) -> set[str]:
    """First-column backticked names of table rows under the ``## ``
    heading containing ``heading_substr`` (case-insensitive)."""
    rows: set[str] = set()
    in_section = False
    for line in text.splitlines():
        if line.startswith("## "):
            in_section = heading_substr in line.lower()
            continue
        if in_section:
            m = NAMED_ROW_RE.match(line.strip())
            if m:
                rows.add(m.group(1))
    return rows


def check_explore_axes() -> list[str]:
    """docs/explore.md's axes table and repro.explore.SearchSpace.AXES
    must agree exactly, both directions (same for the agents table)."""
    doc = REPO / "docs" / "explore.md"
    if not doc.is_file():
        return ["docs/explore.md: missing (the search-space axes must "
                "be documented)"]
    try:
        from repro.explore import AGENTS, SearchSpace
    except ImportError as exc:
        return [f"explore.md: cannot import repro.explore ({exc})"]
    text = doc.read_text()
    problems = []
    documented = _named_table_rows(text, "axes")
    if not documented:
        return ["explore.md: no axes table found (need a `## ...axes` "
                "section with one row per SearchSpace axis)"]
    axes = set(SearchSpace.AXES)
    for name in sorted(documented - axes):
        problems.append(f"explore.md: documents axis `{name}` which is "
                        f"not in SearchSpace.AXES")
    for name in sorted(axes - documented):
        problems.append(f"explore.md: SearchSpace axis `{name}` is not "
                        f"documented in the axes table")
    documented_agents = _named_table_rows(text, "agents")
    for name in sorted(documented_agents - set(AGENTS)):
        problems.append(f"explore.md: documents agent `{name}` which is "
                        f"not registered in repro.explore.AGENTS")
    for name in sorted(set(AGENTS) - documented_agents):
        problems.append(f"explore.md: agent `{name}` is registered but "
                        f"not documented in the agents table")
    return problems


def run() -> list[str]:
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    problems: list[str] = []
    for doc, text in iter_docs():
        problems += check_links(doc, text)
        problems += check_paths(doc, text)
        problems += check_commands(doc, text)
    problems += check_lint_rules()
    problems += check_runtime_timings()
    problems += check_sampling_bound()
    problems += check_explore_axes()
    return problems


def main() -> int:
    problems = run()
    for p in problems:
        print(f"DOCS-CHECK FAIL: {p}", file=sys.stderr)
    checked = sum(1 for _ in iter_docs())
    if problems:
        print(f"{len(problems)} dangling reference(s) across "
              f"{checked} docs", file=sys.stderr)
        return 1
    print(f"docs-check OK: {checked} docs, all links/paths/commands "
          "resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
