"""ModelTraceSource: deterministic HLO-derived traces and store-served
metadata (ISSUE-7 satellite)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.validate.store import ArtifactStore
from repro.workloads import registry as R
from repro.workloads.model_trace import ModelTraceSource, arch_slug

ARCH = "llama3-8b"
NAME = "model/llama3_8b/decode"


def test_arch_slug():
    assert arch_slug("llama3-8b") == "llama3_8b"
    assert arch_slug("zamba2-1.2b") == "zamba2_1_2b"


def test_unknown_step_rejected():
    with pytest.raises(ValueError, match="unknown model step"):
        ModelTraceSource(ARCH, "finetune")


def test_determinism_same_fingerprint_and_bitidentical_trace():
    """Same (config, step): identical declared fingerprint from two
    independent resolutions, and bit-identical traces from two
    independent lowerings."""
    a = R.resolve(NAME, "smoke")
    b = R.resolve("model/llama3-8b/decode", "smoke")   # raw-id alias
    assert a.declared_fingerprint == b.declared_fingerprint
    ta, tb = a.trace(), b.trace()
    np.testing.assert_array_equal(ta.addresses, tb.addresses)
    np.testing.assert_array_equal(ta.bb_ids, tb.bb_ids)
    np.testing.assert_array_equal(ta.shared_mask, tb.shared_mask)
    assert len(ta) > 0
    # entry parameters (weights) are the shared references
    assert ta.shared_mask.any() and not ta.shared_mask.all()


def test_op_counts_served_from_store_without_lowering(tmp_path):
    """A warm store answers op_counts from workload meta — the second
    source never invokes XLA."""
    store = ArtifactStore(tmp_path)
    first = R.resolve(NAME, "smoke", store=store)
    first.trace()                                    # lowers + persists
    counts = first.op_counts

    fresh = R.resolve(NAME, "smoke", store=store)
    fresh.lowered_hlo = lambda: (_ for _ in ()).throw(
        AssertionError("warm op_counts must not lower")
    )
    assert fresh.op_counts == counts
    assert fresh.info["touched_bytes"] == first.info["touched_bytes"]
    assert counts.fp_ops > 0 and counts.total_bytes > 0


def test_session_verify_fingerprints_cross_check(tmp_path):
    """verify_fingerprints=True recomputes the content hash on
    materialization and raises if it diverges from the recorded one."""
    from repro.api import Session

    store = ArtifactStore(tmp_path)
    w = R.resolve("polybench/atx", "smoke", store=store)
    s = Session(store=store, verify_fingerprints=True)
    tid, trace = s.load(w)                 # records trace_content_id
    meta = store.get_json("workload", tid)
    assert meta["trace_content_id"]

    # poison the recorded hash: a fresh verifying Session must notice
    store.put_json("workload", tid,
                   {**meta, "trace_content_id": "0" * 16})
    s2 = Session(store=store, verify_fingerprints=True)
    w2 = R.resolve("polybench/atx", "smoke", store=store)
    with pytest.raises(RuntimeError, match="stale"):
        s2.load(w2)
