"""PolyBench workload suite: trace invariants + JAX kernel correctness."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.workloads.polybench import MAKERS, all_workloads

SMALL = {
    "atx": dict(n=24), "bcg": dict(n=24), "mvt": dict(n=24),
    "2mm": dict(n=12), "smm": dict(n=12),
    "dgn": dict(nq=6, nr=6, npp=6), "dbn": dict(n=32), "grm": dict(n=12),
    "lu": dict(n=16), "jcb": dict(n=16), "c2d": dict(n=16),
    "adi": dict(n=12), "cov": dict(n=16), "blk": dict(num_options=64),
}


@pytest.mark.parametrize("abbr", sorted(MAKERS))
def test_trace_wellformed(abbr):
    w = MAKERS[abbr](**SMALL[abbr])
    tr = w.trace()
    assert len(tr) > 0
    assert tr.addresses.min() > 0
    assert tr.shared_mask.shape == tr.addresses.shape
    # parallel-section workloads must expose shared (labeled) arrays
    assert tr.shared_mask.any()
    # op counts are positive and bytes follow loads+stores
    assert w.op_counts.fp_ops > 0
    assert w.op_counts.total_bytes == pytest.approx(
        (w.op_counts.loads + w.op_counts.stores) * 8)


@pytest.mark.parametrize("abbr", sorted(MAKERS))
def test_trace_deterministic(abbr):
    w = MAKERS[abbr](**SMALL[abbr])
    t1, t2 = w.trace(), w.trace()
    np.testing.assert_array_equal(t1.addresses, t2.addresses)


def test_jax_kernels_match_numpy():
    rng_key = jax.random.key(0)
    # atax
    w = MAKERS["atx"](n=24)
    A, x = w.jax_args(rng_key)
    np.testing.assert_allclose(
        np.asarray(w.jax_fn(A, x)),
        np.asarray(A).T @ (np.asarray(A) @ np.asarray(x)), rtol=2e-4)
    # 2mm
    w = MAKERS["2mm"](n=12)
    A, B, C, D = w.jax_args(rng_key)
    np.testing.assert_allclose(
        np.asarray(w.jax_fn(A, B, C, D)),
        1.5 * (np.asarray(A) @ np.asarray(B)) @ np.asarray(C)
        + 1.2 * np.asarray(D), rtol=2e-4)
    # covariance vs numpy
    w = MAKERS["cov"](n=16)
    (data,) = w.jax_args(rng_key)
    np.testing.assert_allclose(
        np.asarray(w.jax_fn(data)),
        np.cov(np.asarray(data), rowvar=False), rtol=1e-3, atol=1e-4)


def test_all_workloads_subset():
    ws = all_workloads(["atx", "jcb"])
    assert [w.abbr for w in ws] == ["atx", "jcb"]
    assert len(all_workloads()) == 14  # Table 4 complete


def test_predictor_end_to_end_on_atax():
    """Full paper pipeline on one workload: trace -> mimic -> interleave
    -> profiles -> SDCM -> runtime; prediction error vs exact sim within
    a few % (the paper's Fig. 5 band)."""
    from repro.core.predictor import PPTMulticorePredictor
    from repro.hw.targets import HASWELL_I7_5960X

    w = MAKERS["atx"](n=48)
    tr = w.trace()
    pred = PPTMulticorePredictor(HASWELL_I7_5960X)
    rates, _, _ = pred.hit_rates(tr, 4)
    exact = pred.ground_truth_hit_rates(tr, 4)
    for lvl in rates:
        assert abs(rates[lvl] - exact[lvl]) < 0.06, (lvl, rates, exact)
    out = pred.predict(tr, 4, w.op_counts)
    assert out.t_pred_s > 0
