"""Workload registry round-trip: register/resolve/alias/errors, and
declared-fingerprint stability (ISSUE-7 tentpole surface)."""
from __future__ import annotations

import types

import pytest

from repro.workloads import registry as R
from repro.workloads.polybench import MAKERS, SIZE_PRESETS
from repro.workloads.registry import WorkloadRegistry, WorkloadSpec


def _spec(name="test/unit", aliases=(), version="1", presets=("smoke",)):
    return WorkloadSpec(
        name=name,
        build=lambda sizes: types.SimpleNamespace(),
        size_kwargs=lambda sizes: {"sizes": sizes or "default"},
        presets=presets,
        aliases=aliases,
        version=version,
    )


class TestRegistryRoundTrip:
    def test_register_resolve(self):
        reg = WorkloadRegistry()
        reg.register(_spec(aliases=("tu",)))
        assert reg.names() == ["test/unit"]
        assert reg.canonical("test/unit") == "test/unit"
        assert reg.canonical("tu") == "test/unit"
        src = reg.resolve("tu", "smoke")
        assert src.workload_name == "test/unit"
        assert len(src.declared_fingerprint) == 16

    def test_unnamespaced_name_rejected(self):
        reg = WorkloadRegistry()
        with pytest.raises(ValueError, match="namespaced"):
            reg.register(_spec(name="bare"))

    def test_duplicate_name_and_alias_rejected(self):
        reg = WorkloadRegistry()
        reg.register(_spec(aliases=("tu",)))
        with pytest.raises(ValueError, match="already registered"):
            reg.register(_spec())
        with pytest.raises(ValueError, match="already taken"):
            reg.register(_spec(name="test/other", aliases=("tu",)))

    def test_unknown_name_lists_roster(self):
        reg = WorkloadRegistry()
        reg.register(_spec())
        with pytest.raises(KeyError, match="unknown workload"):
            reg.canonical("nope")

    def test_unknown_preset_rejected(self):
        reg = WorkloadRegistry()
        reg.register(_spec(presets=("smoke",)))
        with pytest.raises(ValueError, match="unknown size preset"):
            reg.resolve("test/unit", "enormous")
        # None (defaults) is always accepted
        reg.resolve("test/unit", None)


class TestDeclaredFingerprints:
    def test_stable_across_spec_objects(self):
        a = _spec().fingerprint("smoke")
        b = _spec().fingerprint("smoke")
        assert a == b

    def test_sensitive_to_kwargs_and_version(self):
        base = _spec().fingerprint("smoke")
        assert _spec().fingerprint(None) != base
        assert _spec(version="2").fingerprint("smoke") != base

    def test_same_resolved_kwargs_share_fingerprint(self):
        """Two presets resolving to identical kwargs dedup to one
        artifact set."""
        spec = WorkloadSpec(
            name="test/unit",
            build=lambda sizes: types.SimpleNamespace(),
            size_kwargs=lambda sizes: {"n": 8},   # every preset -> same
            presets=("smoke", "validation"),
        )
        assert spec.fingerprint("smoke") == spec.fingerprint("validation")


class TestGlobalRegistry:
    def test_every_maker_registered_with_alias(self):
        names = R.workload_names("polybench")
        assert names == sorted(f"polybench/{a}" for a in MAKERS)
        aliases = R.workload_aliases()
        for abbr in MAKERS:
            assert aliases[abbr] == f"polybench/{abbr}"

    def test_model_and_synthetic_namespaces_present(self):
        assert "model/llama3_8b/decode" in R.workload_names("model")
        assert R.workload_names("synthetic") == [
            "synthetic/stream", "synthetic/stride",
        ]

    def test_resolve_matches_make_workload(self):
        """Registry resolution is the MAKERS shim: same trace bytes."""
        import numpy as np

        from repro.workloads.polybench import make_workload

        via_registry = R.resolve("polybench/atx", "smoke").trace()
        via_makers = make_workload("atx", "smoke").trace()
        np.testing.assert_array_equal(
            via_registry.addresses, via_makers.addresses
        )
        np.testing.assert_array_equal(
            via_registry.shared_mask, via_makers.shared_mask
        )

    def test_polybench_fingerprints_distinct_per_size(self):
        fps = {
            R.declared_fingerprint("polybench/atx", s)
            for s in (None, *SIZE_PRESETS)
        }
        assert len(fps) == 1 + len(SIZE_PRESETS)

    def test_synthetic_sources_trace(self):
        src = R.resolve("synthetic/stride", "smoke")
        t = src.trace()
        assert len(t) > 0
        assert src.op_counts.mem_ops > 0
