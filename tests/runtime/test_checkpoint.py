"""Checkpoint/restart + elastic re-shard + straggler monitor tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_host_mesh
from repro.runtime.checkpoint import (
    CheckpointManager, restore_checkpoint, save_checkpoint,
)
from repro.runtime.elastic import fits, plan_remesh
from repro.runtime.straggler import StragglerMonitor


def _state():
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {
            "w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
            "b": jnp.ones((8,), jnp.bfloat16),
        },
    }


def _axes():
    return {"step": (), "params": {"w": ("embed", "mlp"), "b": ("mlp",)}}


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 7, state, _axes())
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = restore_checkpoint(tmp_path / "step_00000007", abstract)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manager_rolling_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    state = _state()
    for step in (1, 2, 3):
        mgr.save(step, state, _axes())
    assert mgr.steps() == [2, 3]
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    step, restored = mgr.restore_latest(abstract)
    assert step == 3
    assert int(restored["step"]) == 7


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(5, _state(), _axes())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_elastic_restore_onto_mesh(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 1, state, _axes())
    rules = ShardingRules(make_host_mesh())
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = restore_checkpoint(tmp_path / "step_00000001", abstract,
                                  rules)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"]))
    assert restored["params"]["w"].sharding is not None


def test_plan_remesh_reports_fallbacks(tmp_path):
    state = {"w": jnp.zeros((6, 8), jnp.float32)}
    save_checkpoint(tmp_path, 1, state, {"w": ("vocab", "mlp")})
    mesh = make_host_mesh()  # 1 device -> everything replicates
    plan = plan_remesh(tmp_path / "step_00000001", mesh)
    assert plan.bytes_per_device == 6 * 8 * 4
    assert fits(plan, hbm_bytes=16 * 2**30)
    assert "GiB/device" in plan.summary()


def test_shape_mismatch_rejected(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 1, state, _axes())
    bad = dict(state)
    bad["params"] = {"w": jnp.zeros((5, 8), jnp.float32),
                     "b": state["params"]["b"]}
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bad)
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path / "step_00000001", abstract)


# --- straggler monitor ---------------------------------------------------------


def test_straggler_detection_with_fake_clock():
    now = {"t": 0.0}
    mon = StragglerMonitor(num_workers=4, predicted_step_s=1.0, slack=3.0,
                           clock=lambda: now["t"])
    for w in range(4):
        mon.heartbeat(w, 0)
    now["t"] = 2.0
    for w in range(3):
        mon.heartbeat(w, 1)
    dec = mon.check()
    assert dec.stragglers == [] and dec.failed == []
    now["t"] = 4.0  # worker 3 idle 4s: > 3s deadline, < 5s fail line
    for w in range(3):
        mon.heartbeat(w, 2)
    dec = mon.check()
    assert dec.stragglers == [3] and dec.failed == []
    now["t"] = 30.0
    for w in range(3):
        mon.heartbeat(w, 3)
    dec = mon.check()
    assert 3 in dec.failed
    mon.remove(3)
    assert mon.num_workers == 3


def test_deadline_tightens_with_observations():
    now = {"t": 0.0}
    mon = StragglerMonitor(num_workers=1, predicted_step_s=0.1, slack=2.0,
                           clock=lambda: now["t"])
    base = mon.deadline_s()
    assert base == pytest.approx(0.2)
    for step in range(1, 12):
        now["t"] += 0.5  # observed steps are slower than predicted
        mon.heartbeat(0, step)
    assert mon.deadline_s() == pytest.approx(1.0)  # median 0.5 x slack 2


def test_ppt_predicted_deadline_integration():
    """The monitor's prior comes straight from the roofline bound —
    the paper's predict-before-running property feeding ops."""
    from repro.analysis.roofline import Roofline

    r = Roofline(arch="x", shape="train_4k", mesh="pod", kind="train",
                 compute_s=0.4, memory_s=0.2, collective_s=0.1,
                 model_flops_chip=1e12, hlo_flops_chip=2e12, chips=256)
    mon = StragglerMonitor(num_workers=2,
                           predicted_step_s=r.t_step_bound_s, slack=3.0,
                           clock=lambda: 0.0)
    assert mon.deadline_s() == pytest.approx(1.2)


def test_concurrent_same_step_savers_never_interleave(tmp_path):
    """Two savers of the same step race: each stages under a unique
    temp dir, one wins the rename, and the surviving checkpoint is
    complete and restorable (a fixed temp name would interleave)."""
    import threading

    state = _state()
    errors: list[BaseException] = []

    def save():
        try:
            save_checkpoint(tmp_path, 3, state)
        except BaseException as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=save) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    restored = restore_checkpoint(
        tmp_path / "step_00000003",
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
    )
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_publish_failure_does_not_destroy_existing_checkpoint(tmp_path):
    """A persistent non-contention rename error must propagate without
    deleting the existing good checkpoint (regression: the retry loop
    used to rmtree `final` on ANY OSError, then report success)."""
    import errno

    from repro.runtime.checkpoint import _publish

    final = tmp_path / "step_00000001"
    save_checkpoint(tmp_path, 1, _state())
    assert (final / "manifest.json").exists()

    class BadTmp:
        def rename(self, target):
            raise OSError(errno.EACCES, "permission denied")

    with pytest.raises(OSError) as ei:
        _publish(BadTmp(), final)
    assert ei.value.errno == errno.EACCES
    assert (final / "manifest.json").exists(), "good checkpoint destroyed"
