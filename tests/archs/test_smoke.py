"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one train step + one prefill+decode step on CPU, asserting
output shapes and no NaNs.  Full configs are dry-run only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs
from repro.configs.reduced import (
    SMOKE_DECODE, SMOKE_PREFILL, SMOKE_SHAPE, reduced_arch,
)
from repro.launch.steps import make_optimizer
from repro.train.data import synthetic_batch
from repro.train.train_step import build_train_step, init_state

ARCHS = list_archs()


def _concrete_batch(spec, shape, step=0):
    specs = spec.input_specs(shape)
    np_batch = synthetic_batch(specs, spec.config.padded_vocab and spec.vocab,
                               seed=7, step=step)
    return {k: jnp.asarray(v) for k, v in np_batch.items()}


def _finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.fixture(scope="module")
def smoke_state():
    return {}


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step(arch_id):
    spec = reduced_arch(arch_id)
    fam, cfg = spec.family, spec.config
    params = fam.init(jax.random.key(0), cfg)
    from repro.models.layers import unzip_params

    values, _ = unzip_params(params)
    optimizer = make_optimizer(spec)
    step_fn = jax.jit(build_train_step(
        lambda p, b: fam.loss_fn(p, b, cfg), optimizer,
        grad_accum=spec.grad_accum_for(SMOKE_SHAPE),
        accum_dtype=spec.accum_dtype,
    ), donate_argnums=(0,))
    state = init_state(values, optimizer)
    batch = _concrete_batch(spec, SMOKE_SHAPE)
    state, metrics = step_fn(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    assert _finite(state.params), "NaN/inf parameter after one update"

    # second step must also be finite (catches optimizer-state bugs)
    state, metrics2 = step_fn(state, _concrete_batch(spec, SMOKE_SHAPE, 1))
    assert np.isfinite(float(metrics2["loss"]))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_prefill_then_decode(arch_id):
    spec = reduced_arch(arch_id)
    fam, cfg = spec.family, spec.config
    params = fam.init(jax.random.key(1), cfg)
    from repro.models.layers import unzip_params

    values, _ = unzip_params(params)

    caches = fam.init_caches(cfg, **spec.cache_kwargs(SMOKE_PREFILL))
    batch = _concrete_batch(spec, SMOKE_PREFILL)
    logits, caches = jax.jit(
        lambda p, b, c: fam.prefill(p, b, cfg, c)
    )(values, batch, caches)
    vocab_pad = spec.config.padded_vocab
    assert logits.shape == (SMOKE_PREFILL.global_batch, vocab_pad)
    assert bool(jnp.all(jnp.isfinite(logits[:, : spec.vocab])))

    prompt_len = batch["tokens"].shape[1]
    decode = jax.jit(
        lambda p, b, c, n: fam.decode_step(p, b, cfg, c, n),
        donate_argnums=(2,),
    )
    length = jnp.asarray(prompt_len, jnp.int32)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, caches = decode(values, {"token": tok}, caches, length)
        assert logits.shape == (SMOKE_PREFILL.global_batch, vocab_pad)
        assert bool(jnp.all(jnp.isfinite(logits[:, : spec.vocab])))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        length = length + 1
    # padded vocab ids must never win argmax
    assert int(tok.max()) < spec.vocab
