"""Cache-consistency property: prefilling a whole prompt must produce
the same final logits as prefilling a prefix and decoding the rest
token-by-token.  This pins down every cache mechanism at once: DUS
append positions, SSM recurrent state handoff (chunked scan == stepwise
recurrence), conv tails, hybrid shared-attn caches, cross-attn reuse.

Run in f32 so the comparison is tight.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced_arch

CASES = ["llama3-8b", "codeqwen1.5-7b", "mixtral-8x7b", "mamba2-780m",
         "zamba2-1.2b", "seamless-m4t-medium"]


def _f32(cfg):
    if hasattr(cfg, "backbone"):
        return dataclasses.replace(
            cfg, backbone=dataclasses.replace(cfg.backbone, dtype=jnp.float32))
    return dataclasses.replace(cfg, dtype=jnp.float32)


@pytest.mark.parametrize("arch_id", CASES)
def test_prefill_then_decode_matches_full_prefill(arch_id):
    spec = reduced_arch(arch_id)
    cfg = _f32(spec.config)
    fam = spec.family
    from repro.models.layers import unzip_params

    params, _ = unzip_params(fam.init(jax.random.key(2), cfg))

    rng = np.random.default_rng(0)
    b, total, split = 2, 12, 7
    tokens = rng.integers(0, spec.vocab, (b, total), dtype=np.int32)

    def mk_batch(toks):
        batch = {"tokens": jnp.asarray(toks)}
        if spec.family_name == "encdec":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, 8, cfg.d_model)), jnp.float32)
        return batch

    def caches():
        if spec.family_name == "encdec":
            return fam.init_caches(cfg, batch=b, max_len=total, src_len=8)
        return fam.init_caches(cfg, batch=b, max_len=total)

    frames_fixed = None
    full_batch = mk_batch(tokens)
    if "frames" in full_batch:
        frames_fixed = full_batch["frames"]
    logits_full, _ = jax.jit(
        lambda p, bt, c: fam.prefill(p, bt, cfg, c)
    )(params, full_batch, caches())

    prefix_batch = mk_batch(tokens[:, :split])
    if frames_fixed is not None:
        prefix_batch["frames"] = frames_fixed
    logits, c2 = jax.jit(
        lambda p, bt, c: fam.prefill(p, bt, cfg, c)
    )(params, prefix_batch, caches())
    decode = jax.jit(lambda p, bt, c, n: fam.decode_step(p, bt, cfg, c, n),
                     donate_argnums=(2,))
    length = jnp.asarray(split, jnp.int32)
    for t in range(split, total):
        logits, c2 = decode(params, {"token": jnp.asarray(tokens[:, t:t+1])},
                            c2, length)
        length = length + 1

    np.testing.assert_allclose(
        np.asarray(logits[:, : spec.vocab]),
        np.asarray(logits_full[:, : spec.vocab]),
        rtol=2e-4, atol=2e-4,
        err_msg=f"{arch_id}: stepwise decode diverges from full prefill",
    )