"""Docs stay truthful (ISSUE-4 satellite): every link, path, and
``python -m`` command the docs mention must resolve — run in-process
here and as the CI ``docs-check`` job."""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "tools"))

import docs_check  # noqa: E402


def test_all_docs_references_resolve():
    problems = docs_check.run()
    assert problems == []


def test_checker_catches_dangling_link(tmp_path):
    doc = tmp_path / "fake.md"
    doc.write_text("see [gone](no/such/file.md) and `src/also_gone.py` "
                   "and run `python -m repro.no_such_module`")
    text = doc.read_text()
    assert docs_check.check_links(doc, text)
    assert docs_check.check_paths(doc, text)
    assert docs_check.check_commands(doc, text)


def test_checker_accepts_real_references(tmp_path):
    doc = tmp_path / "fake.md"
    doc.write_text(
        "see `src/repro/api/session.py` and `repro/api/batched.py` and "
        "`repro/core/reuse/distance.py::reuse_distances`; run "
        "`PYTHONPATH=src python -m repro.service --selftest`"
    )
    text = doc.read_text()
    assert docs_check.check_paths(doc, text) == []
    assert docs_check.check_commands(doc, text) == []
