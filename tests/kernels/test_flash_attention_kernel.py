"""Flash attention Pallas kernel: shape/dtype/GQA/causal sweep."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention


def make(b, h, hkv, sq, sk, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize(
    "b,h,hkv,s,d",
    [
        (1, 1, 1, 128, 64),
        (2, 4, 2, 256, 64),
        (1, 8, 1, 128, 128),   # MQA
        (1, 4, 4, 384, 32),    # MHA
    ],
)
def test_matches_ref_f32(b, h, hkv, s, d, causal):
    q, k, v = make(b, h, hkv, s, s, d, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_bf16_inputs():
    q, k, v = make(1, 2, 1, 128, 128, 64, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(ref, dtype=np.float32),
        atol=3e-2,
    )


def test_cross_attention_longer_kv():
    # decode-style: few queries, long KV
    q, k, v = make(1, 2, 2, 128, 512, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_block_size_invariance():
    q, k, v = make(1, 2, 2, 256, 256, 64, jnp.float32)
    a = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=128, interpret=True)
    b = flash_attention(q, k, v, causal=True, blk_q=128, blk_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_scale_override():
    q, k, v = make(1, 1, 1, 128, 128, 64, jnp.float32)
    got = flash_attention(q, k, v, scale=0.25, interpret=True)
    ref = attention_ref(q, k, v, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
