"""SDCM Pallas kernel: shape/dtype sweep vs pure-jnp oracle (interpret)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.sdcm import phit_given_d_np
from repro.kernels.sdcm import sdcm_hit_probs, sdcm_hit_rate, sdcm_ref


@pytest.mark.parametrize("n", [1, 7, 1024, 1025, 4096])
@pytest.mark.parametrize("assoc,blocks", [(1, 64), (4, 512), (8, 4096), (20, 327680)])
def test_matches_ref_shapes(n, assoc, blocks):
    rng = np.random.default_rng(n + assoc)
    d = rng.integers(-1, 60_000, size=n).astype(np.float32)
    got = np.asarray(
        sdcm_hit_probs(jnp.asarray(d), assoc=assoc, blocks=blocks, interpret=True)
    )
    assert got.shape == (n,)
    ref = np.asarray(sdcm_ref(jnp.asarray(d), assoc, blocks))
    np.testing.assert_allclose(got, ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_dtype_cast(dtype):
    d = np.array([-1, 0, 5, 100, 10000], dtype=dtype)
    got = np.asarray(sdcm_hit_probs(jnp.asarray(d), assoc=8, blocks=512, interpret=True))
    oracle = phit_given_d_np(np.asarray(d, dtype=np.int64), 8, 512)
    np.testing.assert_allclose(got, oracle, atol=5e-5)


def test_against_float64_oracle_large_d():
    """Where f32 betainc failed (~1e-2), the kernel must hold ~1e-5."""
    d = np.array([23092, 10368, 99999], dtype=np.float32)
    got = np.asarray(sdcm_hit_probs(jnp.asarray(d), assoc=2, blocks=16384, interpret=True))
    oracle = phit_given_d_np(d.astype(np.int64), 2, 16384)
    np.testing.assert_allclose(got, oracle, atol=2e-5)


def test_weighted_hit_rate_matches_eq3():
    d = jnp.asarray(np.array([-1, 0, 1, 2, 3], dtype=np.float32))
    w = jnp.asarray(np.array([4.0, 1.0, 1.0, 1.0, 1.0], dtype=np.float32))
    got = float(sdcm_hit_rate(d, w, assoc=4, blocks=4, interpret=True))
    # Table 2 profile with fully-assoc 4-block cache: P(h) = 0.5
    assert abs(got - 0.5) < 1e-6


def test_edge_all_inf():
    d = jnp.full((100,), -1.0)
    got = np.asarray(sdcm_hit_probs(d, assoc=8, blocks=64, interpret=True))
    assert (got == 0).all()
