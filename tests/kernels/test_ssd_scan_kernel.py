"""SSD chunked-scan Pallas kernel vs sequential-scan oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref


def make(bh, s, p, n, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (bh, s, p), dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[1], (bh, s))).astype(dtype)
    b = (jax.random.normal(ks[2], (bh, s, n)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[3], (bh, s, n)) * 0.3).astype(dtype)
    return x, la, b, c


@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize(
    "bh,s,p,n", [(1, 128, 16, 8), (3, 256, 32, 16), (2, 512, 64, 64)]
)
def test_matches_sequential_ref(bh, s, p, n, chunk):
    x, la, b, c = make(bh, s, p, n)
    got = ssd_scan(x, la, b, c, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, la, b, c)
    scale = float(jnp.abs(ref).max()) + 1e-9
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(ref) / scale, atol=5e-6
    )


def test_chunk_equals_seq():
    # one chunk == pure intra-chunk path
    x, la, b, c = make(2, 64, 16, 8)
    got = ssd_scan(x, la, b, c, chunk=64, interpret=True)
    ref = ssd_scan_ref(x, la, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_state_carry_across_chunks():
    """First token of chunk 2 must see chunk-1 history: compare against
    a run with zeroed early input."""
    x, la, b, c = make(1, 256, 16, 8, seed=3)
    full = ssd_scan(x, la, b, c, chunk=128, interpret=True)
    x_zero = x.at[:, :128].set(0.0)
    cut = ssd_scan(x_zero, la, b, c, chunk=128, interpret=True)
    # outputs in the second chunk must differ (history flows through)
    assert float(jnp.abs(full[:, 128:] - cut[:, 128:]).max()) > 1e-3


def test_bf16():
    x, la, b, c = make(2, 128, 32, 16, dtype=jnp.bfloat16)
    got = ssd_scan(x, la, b, c, chunk=64, interpret=True)
    ref = ssd_scan_ref(x, la, b, c)
    assert got.dtype == jnp.bfloat16
    scale = float(jnp.abs(ref.astype(jnp.float32)).max()) + 1e-9
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32) / scale,
        np.asarray(ref, dtype=np.float32) / scale,
        atol=5e-2,
    )


def test_decay_isolation():
    """With la = -inf-ish (full decay), each step only sees itself."""
    bh, s, p, n = 1, 128, 8, 4
    x, _, b, c = make(bh, s, p, n, seed=5)
    la = jnp.full((bh, s), -40.0)
    got = ssd_scan(x, la, b, c, chunk=64, interpret=True)
    expect = jnp.einsum("bsn,bsn->bs", c, b)[..., None] * x
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-5)
