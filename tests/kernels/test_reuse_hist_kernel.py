"""Reuse-histogram Pallas kernels vs oracles (interpret)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.reuse_hist import (
    reuse_hist_moments_ref,
    reuse_hist_ref,
    reuse_histogram,
    reuse_histogram_moments,
)
from repro.kernels.reuse_hist.reuse_hist import NUM_BINS


@pytest.mark.parametrize("n", [1, 5, 1024, 2049, 8192])
def test_matches_ref(n):
    rng = np.random.default_rng(n)
    d = rng.integers(-1, 1 << 20, size=n).astype(np.float32)
    got = np.asarray(reuse_histogram(jnp.asarray(d), interpret=True))
    ref = np.asarray(reuse_hist_ref(jnp.asarray(d), jnp.ones((n,), jnp.float32)))
    np.testing.assert_array_equal(got, ref)
    assert got.sum() == n  # mass conservation incl. padding correctness


def test_weighted():
    d = np.array([-1, 0, 1, 2, 1024], dtype=np.float32)
    w = np.array([2.0, 3.0, 1.0, 1.0, 5.0], dtype=np.float32)
    got = np.asarray(reuse_histogram(jnp.asarray(d), jnp.asarray(w), interpret=True))
    ref = np.asarray(reuse_hist_ref(jnp.asarray(d), jnp.asarray(w)))
    np.testing.assert_array_equal(got, ref)
    assert got[0] == 2.0  # INF mass
    assert got.sum() == w.sum()


def test_bin_layout():
    # d=0 and d=1 -> bin 1; d=2,3 -> bin 2; d in [2^k, 2^(k+1)) -> bin k+1
    d = np.array([0, 1, 2, 3, 4, 7, 8], dtype=np.float32)
    got = np.asarray(reuse_histogram(jnp.asarray(d), interpret=True))
    assert got[1] == 2 and got[2] == 2 and got[3] == 2 and got[4] == 1
    assert got.shape == (NUM_BINS,)


@pytest.mark.parametrize("n", [1, 5, 1024, 2049])
def test_moments_matches_ref(n):
    rng = np.random.default_rng(n)
    d = rng.integers(-1, 1 << 20, size=n).astype(np.float32)
    got = np.asarray(
        reuse_histogram_moments(jnp.asarray(d), interpret=True)
    )
    ref = np.asarray(
        reuse_hist_moments_ref(jnp.asarray(d), jnp.ones((n,), jnp.float32))
    )
    assert got.shape == (2, NUM_BINS)
    np.testing.assert_array_equal(got[0], ref[0])   # counts: exact
    np.testing.assert_allclose(got[1], ref[1], rtol=1e-6)  # f32 mass
    assert got[0].sum() == n


def test_moments_weighted_and_inf_mass():
    d = np.array([-1, 0, 1, 2, 1024], dtype=np.float32)
    w = np.array([2.0, 3.0, 1.0, 1.0, 5.0], dtype=np.float32)
    got = np.asarray(
        reuse_histogram_moments(jnp.asarray(d), jnp.asarray(w),
                                interpret=True)
    )
    # row 0 is exactly the plain histogram
    hist = np.asarray(reuse_histogram(jnp.asarray(d), jnp.asarray(w),
                                      interpret=True))
    np.testing.assert_array_equal(got[0], hist)
    # INF (bin 0) carries no distance mass; finite mass is w * d
    assert got[1][0] == 0.0
    assert got[1].sum() == pytest.approx(3 * 0 + 1 * 1 + 1 * 2 + 5 * 1024)
