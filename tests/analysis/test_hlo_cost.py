"""Loop-aware HLO cost analysis vs known programs (the Byfl analog)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_stats, num_partitions
from repro.analysis.hlo_cost import HloCostModel, loop_aware_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    n, trips = 128, 12

    def body(x, _):
        return jnp.tanh(x @ x), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y.sum()

    txt = _compiled_text(f, jnp.ones((n, n), jnp.float32))
    cost = loop_aware_cost(txt)
    expected = trips * 2 * n ** 3
    assert cost["flops"] == pytest.approx(expected, rel=0.05)
    # XLA's own cost analysis counts the body once — the discrepancy is
    # the whole reason this module exists
    xla = jax.jit(f).lower(
        jnp.ones((n, n), jnp.float32)).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):  # jax<=0.4.x returns [dict]
        xla = xla[0]
    assert xla["flops"] < cost["flops"] / (trips - 2)


def test_nested_scan_trips_compound():
    n, outer, inner = 64, 3, 5

    def inner_body(x, _):
        return x @ x, None

    def outer_body(x, _):
        y, _ = jax.lax.scan(inner_body, x, None, length=inner)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return y.sum()

    cost = loop_aware_cost(_compiled_text(f, jnp.ones((n, n), jnp.float32)))
    assert cost["flops"] == pytest.approx(outer * inner * 2 * n ** 3,
                                          rel=0.05)


def test_dot_contracting_dims_exact():
    a = jnp.ones((32, 48), jnp.float32)
    b = jnp.ones((48, 16), jnp.float32)
    cost = loop_aware_cost(_compiled_text(lambda x, y: x @ y, a, b))
    assert cost["flops"] == pytest.approx(2 * 32 * 48 * 16, rel=0.02)


def test_bytes_reasonable_for_elementwise():
    x = jnp.ones((1024, 1024), jnp.float32)
    cost = loop_aware_cost(_compiled_text(lambda x: (x * 2 + 1).sum(), x))
    # read + write within small factor of 2 x 4 MiB
    assert 0.5 * 8e6 < cost["bytes"] < 6 * 8e6


def test_fused_dus_charges_update_only():
    big = jnp.zeros((512, 1024), jnp.float32)   # 2 MiB
    upd = jnp.ones((1, 1024), jnp.float32)      # 4 KiB

    def f(big, upd):
        def body(c, i):
            return jax.lax.dynamic_update_slice_in_dim(c, upd, i, 0), None
        out, _ = jax.lax.scan(body, big, jnp.arange(64))
        return out.sum()

    cost = loop_aware_cost(_compiled_text(f, big, upd))
    # 64 iterations x ~8 KiB, NOT 64 x 2 MiB
    assert cost["bytes"] < 64 * 2**20


def test_parser_handles_tuple_types_with_comments():
    from repro.analysis.hlo_cost import parse_computations

    txt = """
%comp (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]{1,0}, /*index=2*/f32[8,8]{1,0}) tuple(%g, %d, %d)
}
"""
    comps = parse_computations(txt)
    assert "comp" in comps
    ops = [i.op for i in comps["comp"].instrs]
    assert "dot" in ops and "tuple" in ops


# --- collective parsing --------------------------------------------------------


def test_collective_stats_sharded_matmul():
    import os, subprocess, sys
    from pathlib import Path

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
f = jax.jit(lambda x, w: (x @ w).sum(),
            in_shardings=(NamedSharding(mesh, P("data", "model")),
                          NamedSharding(mesh, P("model", None))))
txt = f.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
              jax.ShapeDtypeStruct((512, 1024), jnp.float32)).compile().as_text()
from repro.analysis.hlo import collective_stats, num_partitions
s = collective_stats(txt)
assert num_partitions(txt) == 8
assert s.counts.get("all-reduce", 0) >= 1, s.counts
# partial [128,1024] f32 all-reduced over groups of 4: 2*(3/4)*512KiB
expected = 2 * 0.75 * 128 * 1024 * 4
assert abs(s.ici_bytes - expected) / expected < 0.35, (s.ici_bytes, expected)
print("COLL-OK")
"""
    repo = Path(__file__).resolve().parents[2]
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=300, cwd=repo,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # without this jax probes accelerator plugins for minutes
             **({"JAX_PLATFORMS": os.environ["JAX_PLATFORMS"]}
                if "JAX_PLATFORMS" in os.environ else {})},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COLL-OK" in proc.stdout
