"""Roofline math + HLO->trace (PPT-on-XLA) tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import Roofline, format_table, model_flops


def _r(**kw):
    base = dict(arch="a", shape="train_4k", mesh="pod", kind="train",
                compute_s=1.0, memory_s=0.5, collective_s=0.25,
                model_flops_chip=197e12 * 0.8, hlo_flops_chip=197e12,
                chips=256)
    base.update(kw)
    return Roofline(**base)


def test_bottleneck_and_bound():
    r = _r()
    assert r.bottleneck == "compute"
    assert r.t_step_bound_s == 1.0
    assert _r(memory_s=2.0).bottleneck == "memory"
    assert _r(collective_s=3.0).bottleneck == "collective"


def test_roofline_fraction_definition():
    r = _r()
    # useful flops at 80% of hlo flops, compute-bound -> fraction 0.8
    assert r.roofline_fraction == pytest.approx(0.8)
    # memory-bound halves the fraction
    r2 = _r(memory_s=2.0)
    assert r2.roofline_fraction == pytest.approx(0.4)


def test_model_flops_conventions():
    n, s, b = 8e9, 4096, 256
    assert model_flops("train", n, s, b) == 6 * n * s * b
    assert model_flops("prefill", n, s, b) == 2 * n * s * b
    assert model_flops("decode", n, s, b) == 2 * n * b


def test_format_table_includes_all_rows():
    out = format_table([_r(), _r(arch="b", shape="decode_32k")])
    assert "train_4k" in out and "decode_32k" in out


def test_hlo_trace_roundtrip_and_vmem_rate():
    from repro.analysis.hlo_trace import hlo_to_trace, vmem_hit_rate

    def body(x, _):
        return jnp.tanh(x @ x), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y.sum()

    txt = jax.jit(f).lower(
        jnp.ones((256, 256), jnp.float32)).compile().as_text()
    trace, info = hlo_to_trace(txt, loop_cap=2)
    assert len(trace) > 0
    assert info["touched_bytes"] > 256 * 256 * 4
    assert info["loop_scale"] >= 3.0  # 6 trips emitted as 2
    rate = vmem_hit_rate(trace)
    assert 0.0 <= rate <= 1.0
    # a 256KB working set reused across iterations must be VMEM-resident
    assert rate > 0.5


def test_refined_memory_term_discounts_reuse():
    from repro.analysis.hlo_trace import hlo_to_trace, refined_memory_term

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    txt = jax.jit(f).lower(
        jnp.ones((128, 128), jnp.float32)).compile().as_text()
    trace, info = hlo_to_trace(txt)
    out = refined_memory_term(info["touched_bytes"], trace)
    assert out["refined_memory_s"] <= out["flat_memory_s"]
    assert out["vmem_hit_rate"] > 0.5
