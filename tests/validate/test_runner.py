"""Validation runner + report (ISSUE-3 tentpole acceptance): the
matrix runs through the Session grid, a second run against the same
artifact dir performs zero reuse-profile recomputations, and the
report renders the paper comparison from the merged summary."""
from __future__ import annotations

import json

import pytest

from repro.validate import (
    MatrixSpec,
    generate_report,
    run_validation,
    save_results,
)
from repro.validate.reference import (
    PAPER_ARCH_CLAIMS,
    PAPER_OVERALL,
    PAPER_TABLE4,
)
from repro.workloads.polybench import MAKERS, SIZE_PRESETS

TINY = MatrixSpec(
    workloads=("atx", "jcb"),
    core_counts=(1, 2),
    strategies=("round_robin",),
    sizes="smoke",
)


def test_reference_tables_cover_roster():
    """Every MAKERS workload decodes to a Table-4 row, and the per-arch
    claims average to the paper's headline aggregates."""
    assert set(PAPER_TABLE4) == set(MAKERS)
    archs = list(PAPER_ARCH_CLAIMS.values())
    assert sum(c.hit_rate_err_pct for c in archs) / len(archs) == \
        pytest.approx(PAPER_OVERALL.hit_rate_err_pct, abs=0.01)
    assert sum(c.runtime_err_pct for c in archs) / len(archs) == \
        pytest.approx(PAPER_OVERALL.runtime_err_pct, abs=0.01)
    for preset in SIZE_PRESETS.values():
        assert set(preset) <= set(MAKERS)


def test_matrix_id_stable_and_spec_sensitive():
    assert TINY.matrix_id() == TINY.matrix_id()
    other = MatrixSpec(workloads=("atx",), sizes="smoke")
    assert other.matrix_id() != TINY.matrix_id()


def test_runner_scores_every_cell(tmp_path):
    summary = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    cells = (len(TINY.workloads) * len(TINY.targets)
             * len(TINY.core_counts) * len(TINY.strategies))
    assert len(summary["records"]) == cells
    for rec in summary["records"]:
        assert set(rec["levels"]) == {"L1", "L2", "L3"}
        for entry in rec["levels"].values():
            assert 0.0 <= entry["predicted"] <= 1.0
            assert 0.0 <= entry["exact"] <= 1.0
            assert entry["abs_err_pct"] >= 0.0
        assert rec["t_pred_s"] > 0 and rec["t_exact_rates_s"] > 0
    agg = summary["aggregates"]["overall"]
    assert agg["cells"] == cells
    assert agg["hit_rate_err_pct"]["paper"] == PAPER_OVERALL.hit_rate_err_pct
    assert set(summary["aggregates"]["per_arch"]) == set(TINY.targets)
    assert summary["reference"]["overall"]["runtime_err_pct"] == 9.08


def test_binned_profile_deviation_within_tolerance(tmp_path):
    """ISSUE-5 acceptance: SDCM hit rates from fused device-binned
    profiles stay within 1e-3 absolute of the exact-profile rates on
    every scored level cell, and the runner records the comparison."""
    summary = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    bp = summary["aggregates"]["binned_profile"]
    assert bp["cells"] > 0
    assert bp["max_abs_dev"] <= bp["tolerance"] == 1e-3
    assert bp["within_tolerance"]
    for rec in summary["records"]:
        assert set(rec["binned_abs_dev"]) == set(rec["levels"])


def test_binned_check_can_be_disabled(tmp_path):
    spec = MatrixSpec(workloads=("atx",), core_counts=(1,),
                      strategies=("round_robin",), sizes="smoke",
                      binned_check=False)
    summary = run_validation(spec, artifact_dir=tmp_path, processes=1)
    assert summary["aggregates"]["binned_profile"]["cells"] == 0
    assert all("binned_abs_dev" not in r for r in summary["records"])


def test_sampled_profile_deviation_within_bound(tmp_path):
    """ISSUE-9 acceptance: every sampled SDCM hit rate deviates from
    the exact-profile prediction by less than the error bound its own
    profile declared, and the runner records both per level cell."""
    summary = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    sp = summary["aggregates"]["sampled_profile"]
    assert sp["cells"] > 0
    assert sp["rate"] == TINY.sampled_rate == 0.5
    assert sp["max_declared_bound"] > 0.0
    assert sp["bound_exceedances"] == 0 and sp["within_bound"]
    for rec in summary["records"]:
        assert set(rec["sampled_abs_dev"]) == set(rec["levels"])
        assert set(rec["sampled_bound"]) == set(rec["levels"])
        for lvl, dev in rec["sampled_abs_dev"].items():
            assert dev < rec["sampled_bound"][lvl], (rec["workload"], lvl)


def test_sampled_check_can_be_disabled(tmp_path):
    spec = MatrixSpec(workloads=("atx",), core_counts=(1,),
                      strategies=("round_robin",), sizes="smoke",
                      sampled_check=False)
    summary = run_validation(spec, artifact_dir=tmp_path, processes=1)
    sp = summary["aggregates"]["sampled_profile"]
    assert sp["cells"] == 0 and sp["rate"] is None
    assert all("sampled_abs_dev" not in r for r in summary["records"])


def test_sampling_gate_checker():
    """check_sampling_gate: passes within bound, fails on exceedance,
    and fails LOUDLY (not vacuously) when no sampled cells scored."""
    from repro.validate.__main__ import check_sampling_gate

    good = {"sampled_profile": {
        "cells": 12, "rate": 0.5, "max_abs_dev": 1e-3,
        "max_declared_bound": 5e-2, "bound_exceedances": 0,
        "within_bound": True,
    }}
    ok, msg = check_sampling_gate(good)
    assert ok and msg.startswith("OK")

    bad = {"sampled_profile": {
        "cells": 12, "rate": 0.5, "max_abs_dev": 9e-2,
        "max_declared_bound": 5e-2, "bound_exceedances": 3,
        "within_bound": False,
    }}
    ok, msg = check_sampling_gate(bad)
    assert not ok and "3 cell(s)" in msg

    ok, msg = check_sampling_gate({})
    assert not ok and "no sampled cells" in msg
    ok, msg = check_sampling_gate({"sampled_profile": {"cells": 0}})
    assert not ok


def test_second_run_zero_profile_recomputation(tmp_path):
    """THE acceptance criterion: same artifact_dir, run twice — the
    second run rebuilds no reuse profile and resimulates no baseline."""
    first = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    assert first["session_stats"]["profile_builds"] > 0
    second = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    assert second["session_stats"]["profile_builds"] == 0
    assert second["session_stats"]["rd_builds"] == 0
    assert second["session_stats"]["mimic_builds"] == 0
    assert second["session_stats"]["store_hits"] > 0
    # identical scores both times (disk round-trip is lossless)
    assert second["aggregates"]["overall"] == first["aggregates"]["overall"]


@pytest.mark.slow
def test_multiprocess_workers_share_store(tmp_path):
    """Worker-sharded cells with store-mediated merging: two spawned
    workers produce the same summary a serial run does, and leave the
    store warm enough that a serial rerun rebuilds nothing."""
    summary = run_validation(TINY, artifact_dir=tmp_path, processes=2)
    assert len(summary["records"]) == 12
    rerun = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    assert rerun["session_stats"]["profile_builds"] == 0
    assert rerun["aggregates"]["overall"] == summary["aggregates"]["overall"]


def test_report_generation(tmp_path):
    summary = run_validation(TINY, artifact_dir=tmp_path, processes=1)
    json_path = save_results(summary, tmp_path / "validation.json")
    md_path = generate_report(json_path, tmp_path / "validation.md")
    md = md_path.read_text()
    assert "GENERATED by repro.validate.report" in md
    assert "Aggregate errors vs the paper" in md
    assert "1.23" in md and "9.08" in md        # paper claims present
    for arch in TINY.targets:
        assert f"### {arch}" in md
    assert "ATAX" in md                          # Table-4 decoding used
    # summary json is loadable and self-contained
    payload = json.loads(json_path.read_text())
    assert payload["reference"]["per_arch"].keys() == PAPER_ARCH_CLAIMS.keys()


def test_multiprocess_without_store_rejected(tmp_path):
    with pytest.raises(ValueError, match="artifact_dir"):
        run_validation(TINY, artifact_dir=None, processes=2)


def test_no_store_defaults_to_serial():
    """The documented zero-config call works: without an artifact_dir
    the runner falls back to in-process execution instead of raising."""
    spec = MatrixSpec(workloads=("atx",), core_counts=(1,),
                      strategies=("round_robin",), sizes="smoke")
    summary = run_validation(spec)           # no artifact_dir, no procs
    assert len(summary["records"]) == 3      # 3 targets x 1 core


def test_model_workload_cell_through_matrix(tmp_path):
    """ISSUE-7 acceptance: a model/<arch>/<step> workload joins the
    validation grid with a TPU VMEM hit-rate cell, and a second run is
    served entirely from the store (declared fingerprint stable)."""
    spec = MatrixSpec(workloads=("model/llama3_8b/decode",),
                      targets=("tpu-v5e",), core_counts=(1,),
                      strategies=("round_robin",), sizes="smoke",
                      binned_check=False)
    summary = run_validation(spec, artifact_dir=tmp_path, processes=1)
    assert len(summary["records"]) == 1
    rec = summary["records"][0]
    assert rec["workload"] == "model/llama3_8b/decode"
    assert set(rec["levels"]) == {"VMEM"}
    assert 0.0 <= rec["levels"]["VMEM"]["predicted"] <= 1.0
    assert rec["t_pred_s"] > 0          # roofline runtime on the TPU
    assert summary["per_workload"]["model/llama3_8b/decode"]["refs"] > 0

    second = run_validation(spec, artifact_dir=tmp_path, processes=1)
    assert second["session_stats"]["trace_builds"] == 0
    assert second["session_stats"]["profile_builds"] == 0
    assert second["aggregates"]["overall"] == summary["aggregates"]["overall"]
