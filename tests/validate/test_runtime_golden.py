"""Golden runtime-error aggregates: pins every named stage-4 model's
accuracy on a small seeded matrix, so a model or parameter edit shows
its accuracy delta in the diff instead of drifting silently.

The pipeline is deterministic (seeded mimicry, float64 throughout), so
the committed values hold to ~1e-6; a legitimate model change updates
them HERE, alongside the change that moved them.
"""
from __future__ import annotations

import pytest

from repro.validate.runner import MatrixSpec, run_validation

GOLDEN_SPEC = MatrixSpec(
    workloads=("polybench/atx", "polybench/mvt", "polybench/jcb"),
    core_counts=(1, 4),
    strategies=("round_robin",),
    sizes="smoke",
    binned_check=False,
)

# Committed aggregates for GOLDEN_SPEC (relative/absolute error in %).
GOLDEN_HIT_ERR_PCT = 0.259646889555145
GOLDEN_RUNTIME_ERR_PCT = 1.367613486290153
GOLDEN_MODEL_ERR_PCT = {
    "eq": 1.367613486290153,
    "ecm": 71.663113522307130,
    "roofline": 90.851810925179830,
}
GOLDEN_CELLS = 18
TOL = 1e-6


@pytest.fixture(scope="module")
def summary():
    return run_validation(GOLDEN_SPEC, artifact_dir=None, processes=1)


def test_golden_hit_and_runtime_aggregates(summary):
    agg = summary["aggregates"]["overall"]
    assert agg["cells"] == GOLDEN_CELLS
    assert agg["hit_rate_err_pct"]["ours"] == pytest.approx(
        GOLDEN_HIT_ERR_PCT, abs=TOL)
    assert agg["runtime_err_pct"]["ours"] == pytest.approx(
        GOLDEN_RUNTIME_ERR_PCT, abs=TOL)


def test_golden_per_model_aggregates(summary):
    models = summary["aggregates"]["runtime_models"]
    assert set(models) == set(GOLDEN_MODEL_ERR_PCT)
    for name, expected in GOLDEN_MODEL_ERR_PCT.items():
        assert models[name]["overall_rel_err_pct"] == pytest.approx(
            expected, abs=TOL), name
        assert models[name]["cells"] == GOLDEN_CELLS


def test_eq_model_matches_legacy_runtime_metric(summary):
    """The per-model scoring of `eq` and the legacy per-cell
    runtime_rel_err_pct are the same number by construction — both are
    the default CPU chain against the exact-rates reference."""
    agg = summary["aggregates"]
    assert agg["runtime_models"]["eq"]["overall_rel_err_pct"] == \
        pytest.approx(agg["overall"]["runtime_err_pct"]["ours"], abs=1e-12)


def test_runtime_gate_holds_on_golden_matrix(summary):
    """The CI gate's criterion on this matrix: the instruction-aware
    ECM model must beat (or tie) the crude roofline baseline."""
    from repro.validate.__main__ import check_runtime_gate

    passed, msg = check_runtime_gate(summary["aggregates"])
    assert passed, msg
