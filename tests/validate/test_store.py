"""ArtifactStore semantics (ISSUE-3 satellite): round-trips for every
artifact kind, cross-process-style cache hits via two Sessions sharing
one store, corruption/partial-write recovery, and version-bump key
invalidation."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import (
    ExactLRU,
    MimicProfileBuilder,
    PredictionRequest,
    Session,
)
from repro.core.trace.types import trace_from_blocks
from repro.validate.store import (
    STORE_VERSION,
    ArtifactStore,
    artifact_key,
    load_profile_artifacts,
    save_profile_artifacts,
)

TARGETS = ("i7-5960X", "Xeon E5-2699 v4")


def small_trace(iters=300, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


def request(cores=(1, 2, 4)):
    return PredictionRequest(
        targets=TARGETS, core_counts=cores, respect_core_limit=False
    )


# --- raw payload round-trips -------------------------------------------------


def test_arrays_round_trip_with_meta(tmp_path):
    store = ArtifactStore(tmp_path)
    arrays = {
        "a": np.arange(7, dtype=np.int64),
        "b": np.array([[1.5, -2.0]], dtype=np.float64),
    }
    meta = {"cores": 4, "strategy": "round_robin", "nested": {"x": 1}}
    store.put_arrays("profile", "k1", arrays, meta)
    got_arrays, got_meta = store.get_arrays("profile", "k1")
    assert got_meta == meta
    for name in arrays:
        np.testing.assert_array_equal(got_arrays[name], arrays[name])
    assert store.stats.puts == 1 and store.stats.hits == 1


def test_json_round_trip_and_miss(tmp_path):
    store = ArtifactStore(tmp_path)
    obj = {"L1": 0.99, "L2": 0.75, "L3": 0.5}
    store.put_json("exact", "cell", obj)
    assert store.get_json("exact", "cell") == obj
    assert store.get_json("exact", "absent") is None
    assert store.get_arrays("profile", "absent") is None
    assert store.stats.misses == 2
    assert store.keys("exact") == ["cell"]


def test_profile_artifacts_round_trip(tmp_path):
    """Every field of a ProfileArtifacts cell survives the npz trip
    (traces intentionally excluded)."""
    store = ArtifactStore(tmp_path)
    session = Session()
    art = session.artifacts(small_trace(), 4, strategy="round_robin")
    save_profile_artifacts(store, art)
    loaded = load_profile_artifacts(
        store, art.trace_id, art.line_size, art.cores, art.strategy,
        art.seed, art.window_size,
    )
    assert loaded is not None
    assert not loaded.has_traces  # traces never persisted
    assert (loaded.trace_id, loaded.cores, loaded.strategy,
            loaded.seed, loaded.line_size) == (
        art.trace_id, art.cores, art.strategy, art.seed, art.line_size)
    for name in ("prd", "crd"):
        a, b = getattr(art, name), getattr(loaded, name)
        np.testing.assert_array_equal(a.distances, b.distances)
        np.testing.assert_array_equal(a.counts, b.counts)
        assert a.total == b.total


# --- Session layering --------------------------------------------------------


def test_two_sessions_share_one_store(tmp_path):
    """The acceptance property: a second Session (a second process in
    real runs) rebuilds nothing — profiles come off disk, predictions
    are identical, and the counters prove it."""
    store = ArtifactStore(tmp_path)
    trace = small_trace()
    s1 = Session(store=store)
    r1 = s1.predict(trace, request())
    assert s1.stats.profile_builds > 0
    assert s1.stats.store_puts == s1.stats.profile_builds
    assert s1.stats.store_hits == 0

    s2 = Session(store=store)
    r2 = s2.predict(trace, request())
    assert s2.stats.profile_builds == 0
    assert s2.stats.rd_builds == 0
    assert s2.stats.mimic_builds == 0
    assert s2.stats.store_hits == s1.stats.profile_builds
    for a, b in zip(r1, r2):
        assert a.hit_rates == b.hit_rates


def test_different_builders_never_share_store_entries(tmp_path):
    """Profiles are keyed by the producing builder's fingerprint: a
    Session with a custom stage-2 builder must not be served another
    builder's profiles off disk."""
    store = ArtifactStore(tmp_path)
    trace = small_trace()
    s1 = Session(store=store)
    s1.artifacts(trace, 2)

    class OtherBuilder(MimicProfileBuilder):
        pass

    s2 = Session(store=store, profile_builder=OtherBuilder())
    s2.artifacts(trace, 2)
    assert s2.stats.store_hits == 0          # no cross-builder serving
    assert s2.stats.profile_builds == 1
    # same builder class -> shared entries, as before
    s3 = Session(store=store)
    s3.artifacts(trace, 2)
    assert s3.stats.store_hits == 1 and s3.stats.profile_builds == 0


def test_artifact_dir_constructs_store(tmp_path):
    s = Session(artifact_dir=tmp_path / "cache")
    assert isinstance(s.store, ArtifactStore)
    s.artifacts(small_trace(), 2)
    assert s.stats.store_puts == 1
    assert (tmp_path / "cache" / f"v{STORE_VERSION}" / "profile").is_dir()


def test_ground_truth_rematerializes_traces_from_store_hit(tmp_path):
    """A store-served (trace-less) cell still supports ExactLRU ground
    truth: the Session rebuilds the mimicked traces (cheap) without
    rerunning any profile pass."""
    store = ArtifactStore(tmp_path)
    trace = small_trace()
    s1 = Session(store=store)
    gt1 = s1.ground_truth_hit_rates(trace, TARGETS[0], 4)

    s2 = Session(store=store)
    gt2 = s2.ground_truth_hit_rates(trace, TARGETS[0], 4)
    assert gt2 == pytest.approx(gt1)
    assert s2.stats.profile_builds == 0
    assert s2.stats.store_hits == 1
    assert s2.stats.mimic_builds == 1  # traces rebuilt, profiles not


def test_exact_lru_predict_over_store_hits(tmp_path):
    """ExactLRU as the Session cache model declares needs_traces, so
    predict() materializes traces even for disk-served cells."""
    store = ArtifactStore(tmp_path)
    trace = small_trace()
    Session(store=store).predict(trace, request(cores=(2,)))

    s = Session(store=store, cache_model=ExactLRU())
    result = s.predict(trace, request(cores=(2,)))
    assert s.stats.profile_builds == 0
    gt = Session().ground_truth_hit_rates(trace, TARGETS[0], 2)
    assert result.one(target=TARGETS[0]).hit_rates == pytest.approx(gt)


# --- durability --------------------------------------------------------------


def test_truncated_file_falls_back_to_recompute(tmp_path):
    """Partial-write recovery: a truncated npz reads as a miss, is
    deleted, and the recompute heals the store."""
    store = ArtifactStore(tmp_path)
    trace = small_trace()
    s1 = Session(store=store)
    art = s1.artifacts(trace, 4)
    path = store.path(
        "profile",
        artifact_key(art.trace_id, art.line_size, 4, "round_robin", 0, None),
        "npz",
    )
    blob = path.read_bytes()
    path.write_bytes(blob[: len(blob) // 2])  # simulate a torn write

    s2 = Session(store=store)
    art2 = s2.artifacts(trace, 4)
    assert s2.stats.profile_builds == 1          # recomputed, not crashed
    assert s2.stats.store_hits == 0
    assert store.stats.corrupt == 1
    np.testing.assert_array_equal(art2.crd.distances, art.crd.distances)
    np.testing.assert_array_equal(art2.crd.counts, art.crd.counts)

    s3 = Session(store=store)                    # healed by the rewrite
    s3.artifacts(trace, 4)
    assert s3.stats.store_hits == 1 and s3.stats.profile_builds == 0


def test_corrupt_json_reads_as_miss(tmp_path):
    store = ArtifactStore(tmp_path)
    store.put_json("exact", "cell", {"L1": 0.5})
    store.path("exact", "cell", "json").write_text("{not json")
    assert store.get_json("exact", "cell") is None
    assert store.stats.corrupt == 1
    assert not store.path("exact", "cell", "json").exists()


def test_version_bump_invalidates_keys(tmp_path):
    """Entries written under one store version are unreachable after a
    version bump — stale formats are orphaned, never misread."""
    old = ArtifactStore(tmp_path, version=STORE_VERSION)
    trace = small_trace()
    s1 = Session(store=old)
    s1.artifacts(trace, 4)

    bumped = ArtifactStore(tmp_path, version=STORE_VERSION + 1)
    s2 = Session(store=bumped)
    s2.artifacts(trace, 4)
    assert s2.stats.store_hits == 0
    assert s2.stats.profile_builds == 1          # rebuilt under the new key
    # old entries untouched on disk; new version has its own namespace
    assert old.keys("profile") and bumped.keys("profile")
    assert (tmp_path / f"v{STORE_VERSION}").is_dir()
    assert (tmp_path / f"v{STORE_VERSION + 1}").is_dir()


def test_atomic_write_leaves_no_temp_files(tmp_path):
    store = ArtifactStore(tmp_path)
    store.put_arrays("profile", "k", {"a": np.arange(3)}, {})
    store.put_json("exact", "k", {"x": 1})
    leftovers = [p for p in tmp_path.rglob("*.tmp")]
    assert leftovers == []


# --- concurrent same-key safety (ISSUE-4 satellite) --------------------------


def test_concurrent_same_key_writers_never_interleave(tmp_path):
    """Many threads healing the same cell simultaneously: every read
    observes a complete, valid payload (each writer stages under its
    own temp name; os.replace publishes whole files only)."""
    import threading

    store = ArtifactStore(tmp_path)
    arrays = {"a": np.arange(4096, dtype=np.int64)}
    meta = {"k": "v"}
    stop = threading.Event()
    problems: list[str] = []

    def writer():
        w = ArtifactStore(tmp_path)  # own stats, same directory
        while not stop.is_set():
            w.put_arrays("profile", "cell", arrays, meta)

    def reader():
        r = ArtifactStore(tmp_path)
        while not stop.is_set():
            got = r.get_arrays("profile", "cell")
            if got is None:
                continue  # not yet written: a miss, never an error
            got_arrays, got_meta = got
            if (got_meta != meta
                    or not np.array_equal(got_arrays["a"], arrays["a"])):
                problems.append("partial payload observed")
                return

    threads = [threading.Thread(target=writer) for _ in range(3)]
    threads += [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    import time

    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join()
    assert problems == []
    got_arrays, got_meta = store.get_arrays("profile", "cell")
    assert got_meta == meta
    np.testing.assert_array_equal(got_arrays["a"], arrays["a"])


def test_corrupt_cleanup_spares_concurrently_healed_file(tmp_path):
    """The heal race: reader sees corrupt bytes, a writer replaces the
    file with a good payload before the reader's unlink — the cleanup
    must notice the swap and keep the healed file."""
    store = ArtifactStore(tmp_path)
    path = store.path("profile", "cell", "npz")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(b"definitely not an npz")
    seen = path.stat()

    # concurrent writer heals the cell between read and cleanup
    store.put_arrays("profile", "cell", {"a": np.arange(3)}, {"ok": True})
    store._drop_corrupt(path, seen)
    assert path.exists(), "cleanup deleted a healed cell"
    got = store.get_arrays("profile", "cell")
    assert got is not None and got[1] == {"ok": True}

    # ...but an actually-unchanged corrupt file is still cleared
    path.write_bytes(b"corrupt again")
    store._drop_corrupt(path, path.stat())
    assert not path.exists()
