"""Session cache semantics + legacy equivalence (ISSUE-1 acceptance)."""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.api import (
    ExactLRU,
    MimicProfileBuilder,
    PredictionRequest,
    Session,
)
from repro.core.runtime_model import OpCounts
from repro.core.trace.types import trace_from_blocks
from repro.hw.targets import CPU_TARGETS, TPU_V5E, resolve_target

CPU_NAMES = tuple(CPU_TARGETS)
CORES = (1, 2, 4, 8)
COUNTS = OpCounts(int_ops=3000, fp_ops=1500, div_ops=10, loads=3000,
                  stores=1500, total_bytes=4500 * 8)


def small_trace(iters=600, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


class CountingBuilder(MimicProfileBuilder):
    """Instrumented stage-2 builder: every profile construction counted."""

    def __init__(self):
        self.profile_calls = 0
        self.mimic_calls = 0
        self.interleave_calls = 0

    def private_traces(self, trace, cores):
        self.mimic_calls += 1
        return super().private_traces(trace, cores)

    def interleave(self, privates, strategy, seed):
        self.interleave_calls += 1
        return super().interleave(privates, strategy, seed)

    def profile(self, trace, line_size):
        self.profile_calls += 1
        return super().profile(trace, line_size)


def test_profiles_computed_once_across_three_target_sweep():
    """The acceptance criterion: a 3-target x 4-core grid computes each
    (cores, strategy) profile exactly once — asserted via counters, not
    trusted."""
    trace = small_trace()
    builder = CountingBuilder()
    session = Session(profile_builder=builder)
    request = PredictionRequest(
        targets=CPU_NAMES, core_counts=CORES, counts=COUNTS,
        respect_core_limit=False,
    )
    result = session.predict(trace, request)
    assert len(result) == len(CPU_NAMES) * len(CORES)
    # one artifact build per (cores, strategy) cell; 64B lines shared by
    # all three CPUs
    assert session.stats.profile_builds == len(CORES)
    assert session.stats.profile_hits == (len(CPU_NAMES) - 1) * len(CORES)
    # stage-level: cores>1 cells build PRD+CRD (2 calls) once each;
    # cores==1 goes through the cached reuse-distance path
    assert builder.profile_calls == 2 * (len(CORES) - 1)
    assert builder.mimic_calls == len(CORES) - 1
    assert builder.interleave_calls == len(CORES) - 1
    # a repeated identical request is served fully from cache
    before = session.stats.profile_builds
    session.predict(trace, request)
    assert session.stats.profile_builds == before


def test_prediction_set_matches_legacy_sweep_cores():
    """Legacy shim output must match Session output at f64 tolerance."""
    trace = small_trace()
    session = Session()
    request = PredictionRequest(
        targets=CPU_NAMES, core_counts=CORES, counts=COUNTS,
        respect_core_limit=False,
    )
    result = session.predict(trace, request)
    for name in CPU_NAMES:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from repro.core.predictor import PPTMulticorePredictor

            legacy = PPTMulticorePredictor(resolve_target(name))
            preds = legacy.sweep_cores(trace, list(CORES), COUNTS)
        for p in preds:
            cell = result.one(target=name, cores=p.num_cores)
            for lvl, rate in p.hit_rates.items():
                assert cell.hit_rates[lvl] == pytest.approx(rate, abs=1e-6)
            assert cell.t_pred_s == pytest.approx(p.t_pred_s, rel=1e-6)
            assert cell.t_mem_s == pytest.approx(p.t_mem_s, rel=1e-6)
            assert cell.t_cpu_s == pytest.approx(p.t_cpu_s, rel=1e-6)


def test_legacy_shim_emits_deprecation_warning():
    from repro.core.predictor import PPTMulticorePredictor

    with pytest.warns(DeprecationWarning, match="Session"):
        PPTMulticorePredictor(resolve_target(CPU_NAMES[0]))


def test_ground_truth_through_same_stage_interface():
    """ExactLRU over Session artifacts == the legacy ground-truth path."""
    trace = small_trace()
    session = Session()
    target = resolve_target(CPU_NAMES[0])
    gt = session.ground_truth_hit_rates(trace, target, 4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.predictor import PPTMulticorePredictor

        legacy = PPTMulticorePredictor(target).ground_truth_hit_rates(trace, 4)
    assert gt == pytest.approx(legacy)
    # SDCM prediction should land near the exact simulation
    pred = session.hit_rates(trace, target, 4)
    for lvl in pred:
        assert abs(pred[lvl] - gt[lvl]) < 0.05


def test_tpu_vmem_through_same_cache_model():
    """The TPU target runs through the identical SDCM path (no fork):
    VMEM is one fully-associative level, so SDCM degenerates to the
    exact stack rule and matches the LRU simulator to float precision."""
    trace = small_trace()
    session = Session()
    request = PredictionRequest(
        targets=("tpu-v5e",), core_counts=(1, 4), counts=COUNTS,
    )
    result = session.predict(trace, request)
    assert len(result) == 2
    for cell in result:
        assert set(cell.hit_rates) == {"VMEM"}
        assert 0.0 <= cell.hit_rates["VMEM"] <= 1.0
        assert cell.t_pred_s > 0
    exact = session.ground_truth_hit_rates(trace, TPU_V5E, 4)
    pred = result.one(cores=4).hit_rates
    assert pred["VMEM"] == pytest.approx(exact["VMEM"], abs=1e-9)


def test_exact_lru_as_session_cache_model():
    """Ground truth is itself a pluggable stage-3 model."""
    trace = small_trace()
    sess_pred = Session()
    sess_exact = Session(cache_model=ExactLRU())
    request = PredictionRequest(targets=(CPU_NAMES[0],), core_counts=(4,))
    exact_cell = sess_exact.predict(trace, request).one()
    gt = sess_pred.ground_truth_hit_rates(
        trace, resolve_target(CPU_NAMES[0]), 4
    )
    assert exact_cell.hit_rates == pytest.approx(gt)


def test_request_validation_and_grid_enumeration():
    with pytest.raises(ValueError, match="at least one target"):
        PredictionRequest(targets=())
    with pytest.raises(ValueError, match=">= 1"):
        PredictionRequest(targets=CPU_NAMES, core_counts=(0,))
    with pytest.raises(KeyError, match="unknown target"):
        PredictionRequest(targets=("not-a-cpu",)).resolved_targets()
    # i7 has 8 cores: a 16-core cell is dropped unless the limit is off
    req = PredictionRequest(targets=("i7-5960X",), core_counts=(8, 16))
    assert [c.cores for c in req.cells()] == [8]
    req = PredictionRequest(targets=("i7-5960X",), core_counts=(8, 16),
                            respect_core_limit=False)
    assert [c.cores for c in req.cells()] == [8, 16]


def test_prediction_set_table_json_select():
    trace = small_trace(iters=200)
    session = Session()
    request = PredictionRequest(
        targets=CPU_NAMES[:2], core_counts=(1, 2), counts=COUNTS,
        respect_core_limit=False,
    )
    result = session.predict(trace, request)
    table = result.to_table()
    assert "T_pred" in table and CPU_NAMES[0] in table
    import json

    payload = json.loads(result.to_json())
    assert len(payload["predictions"]) == 4
    assert payload["trace_id"] == result.trace_id
    sub = result.select(cores=2)
    assert len(sub) == 2 and all(p.cores == 2 for p in sub)
    with pytest.raises(LookupError):
        result.one(cores=2)  # two targets match


def test_cache_disabled_recomputes():
    trace = small_trace(iters=200)
    session = Session(cache=False)
    session.artifacts(trace, 2)
    session.artifacts(trace, 2)
    assert session.stats.profile_builds == 2
    assert session.stats.profile_hits == 0


def test_store_hit_skips_trace_materialization(tmp_path):
    """ISSUE-7 satellite: a cell served entirely from disk with a
    needs_traces=False cache model must not rebuild the trace or the
    mimicked privates — declared fingerprints key the store without
    materialization."""
    from repro.workloads import registry

    w = registry.resolve("polybench/atx", "smoke")
    request = PredictionRequest(
        targets=CPU_NAMES, core_counts=(1, 2), counts=COUNTS,
    )
    warm = Session(artifact_dir=tmp_path)
    first = warm.predict(w, request)
    assert warm.stats.trace_builds == 1
    assert warm.stats.store_puts > 0

    # fresh process stand-in: new Session, new source object, same store
    w2 = registry.resolve("polybench/atx", "smoke")
    cold = Session(artifact_dir=tmp_path)
    assert not getattr(cold.cache_model, "needs_traces", False)
    second = cold.predict(w2, request)
    assert cold.stats.trace_builds == 0, "store hit must not build traces"
    assert cold.stats.mimic_builds == 0
    assert cold.stats.interleave_builds == 0
    assert cold.stats.rd_builds == 0
    assert cold.stats.profile_builds == 0
    assert cold.stats.store_hits > 0
    assert [p.hit_rates for p in second.predictions] == \
        [p.hit_rates for p in first.predictions]
    # trace-consuming models still work afterwards (lazy materialization)
    gt = cold.ground_truth_hit_rates(w2, "i7-5960X", 2)
    assert cold.stats.trace_builds == 1
    assert 0.0 <= gt["L1"] <= 1.0
