"""Batched (padded + vmapped + jitted) SDCM vs the float64 oracle."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import AnalyticalSDCM, PredictionRequest, Session
from repro.api.batched import batched_hit_rates, batched_phit, pack_profiles
from repro.core import sdcm
from repro.core.reuse.distance import INF_RD
from repro.core.reuse.profile import profile_from_distances
from repro.core.runtime_model import OpCounts
from repro.core.trace.types import trace_from_blocks
from repro.hw.targets import (
    BROADWELL_E5_2699V4,
    HASWELL_I7_5960X,
    TPU_V5E,
    ZEN2_EPYC_7702P,
)

TABLE5 = (HASWELL_I7_5960X, BROADWELL_E5_2699V4, ZEN2_EPYC_7702P)
COUNTS = OpCounts(int_ops=3000, fp_ops=1500, div_ops=10, loads=3000,
                  stores=1500, total_bytes=4500 * 8)


def small_trace(iters=600, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


def test_batched_phit_matches_np_oracle_all_table5_geometries():
    """Every (level geometry x distance) cell within f32 log-space
    accuracy (2e-5 at D ~ 5e5) of the f64 oracle — including the INF
    bucket and the D <= A-1 plateau.  The Eq. 3 dot product against
    real profiles lands at <= 1e-6 (next test)."""
    rng = np.random.default_rng(0)
    d = np.concatenate([
        np.array([INF_RD, 0, 1, 7, 8, 19, 20, 21]),
        rng.integers(0, 500_000, 56),
    ]).astype(np.int64)
    geoms = []
    for t in TABLE5:
        for lvl in t.levels:
            geoms.append((lvl.effective_assoc, lvl.num_lines))
    vmem = TPU_V5E.levels[0]
    geoms.append((vmem.effective_assoc, vmem.num_lines))  # fully assoc

    rows = np.tile(d, (len(geoms), 1))
    assoc = np.array([a for a, _ in geoms])
    blocks = np.array([b for _, b in geoms])
    got = batched_phit(rows, assoc, blocks)
    for gi, (a, b) in enumerate(geoms):
        want = sdcm.phit_given_d_np(d, a, b)
        np.testing.assert_allclose(got[gi], want, atol=2e-5, rtol=0,
                                   err_msg=f"assoc={a} blocks={b}")


def test_batched_hit_rates_match_numpy_backend_on_real_profiles():
    """Grid acceptance: batched-vs-phit_given_d_np agreement <= 1e-6 on
    all three Table-5 targets (plus the TPU VMEM level)."""
    trace = small_trace()
    base = Session()
    request = PredictionRequest(
        targets=tuple(t.name for t in TABLE5) + (TPU_V5E.name,),
        core_counts=(1, 2, 4), counts=COUNTS, respect_core_limit=False,
    )
    ref = base.predict(trace, request)
    fast = Session(cache_model=AnalyticalSDCM(backend="batched"))
    got = fast.predict(trace, request)
    assert len(ref) == len(got) > 0
    for a, b in zip(ref, got):
        assert a.hit_rates.keys() == b.hit_rates.keys()
        for lvl in a.hit_rates:
            assert b.hit_rates[lvl] == pytest.approx(
                a.hit_rates[lvl], abs=1e-6
            ), (a.target, a.cores, lvl)


def test_single_jitted_call_covers_whole_grid():
    """batched_hit_rates consumes heterogeneous targets in one call."""
    trace = small_trace(iters=300)
    sess = Session()
    arts = {
        c: sess.artifacts(trace, c) for c in (1, 2)
    }
    art512 = sess.artifacts(trace, 2, line_size=512)
    items = [
        (HASWELL_I7_5960X, arts[1]),
        (ZEN2_EPYC_7702P, arts[2]),
        (TPU_V5E, art512),
    ]
    out = batched_hit_rates(items)
    assert [set(r) for r in out] == [
        {"L1", "L2", "L3"}, {"L1", "L2", "L3"}, {"VMEM"},
    ]
    for target, art, rates in ((t, a, r) for (t, a), r in zip(items, out)):
        ref = AnalyticalSDCM().hit_rates(target, art)
        for lvl in rates:
            assert rates[lvl] == pytest.approx(ref[lvl], abs=1e-6)


def test_pack_profiles_padding_is_inert():
    p1 = profile_from_distances(np.array([INF_RD, 0, 3, 3, 9]))
    p2 = profile_from_distances(np.array([1, 1, 1]))
    d, pr = pack_profiles([p1, p2])
    assert d.shape == pr.shape and d.shape[0] == 2
    np.testing.assert_allclose(pr.sum(axis=1), 1.0, atol=1e-6)
    # padded tail has zero probability mass
    assert pr[1, 1:].sum() == 0.0


def test_empty_profile_matches_oracle():
    empty = profile_from_distances(np.array([], dtype=np.int64))
    (rates,) = batched_hit_rates([(HASWELL_I7_5960X, _FakeArt(empty))])
    for lvl in HASWELL_I7_5960X.levels:
        assert rates[lvl.name] == 0.0
        assert sdcm.hit_rate(empty, lvl.effective_assoc, lvl.num_lines) == 0.0


class _FakeArt:
    def __init__(self, prof):
        self.prd = prof
        self.crd = prof
        self.cores = 1


# --- compile accounting (SessionStats.kernel_compiles) -----------------------


def test_warm_session_compiles_each_row_shape_exactly_once():
    """Three identical sweeps through one session: the first pays for
    exactly the row-shape signatures not yet in the process-wide
    compile cache; sweeps two and three compile NOTHING."""
    from repro.api.batched import (
        _pow2,
        _row_shape_key,
        compiled_signatures,
    )
    from repro.api.stages import shared_level_index

    trace = small_trace(iters=500, stride=16)
    sess = Session(cache_model=AnalyticalSDCM(backend="batched"))
    request = PredictionRequest(
        targets=tuple(t.name for t in TABLE5), core_counts=(1, 2),
        counts=COUNTS,
    )

    # predict the signatures this sweep needs from the rows alone
    rows = []
    for target in TABLE5:
        for cores in (1, 2):
            art = sess.artifacts(trace, cores)
            shared_idx = shared_level_index(target)
            for li, lvl in enumerate(target.levels):
                prof = art.crd if li >= shared_idx else art.prd
                rows.append(_row_shape_key(
                    prof, lvl.effective_assoc, lvl.num_lines
                ))
    groups: dict[tuple, int] = {}
    for key in rows:
        groups[key] = groups.get(key, 0) + 1
    expected = {
        ("grid", a_max, _pow2(n), m) for (a_max, m), n in groups.items()
    }
    fresh = expected - compiled_signatures()

    before = sess.stats.kernel_compiles
    sess.predict(trace, request)
    first_delta = sess.stats.kernel_compiles - before
    assert first_delta == len(fresh)

    for _ in range(2):
        warm = sess.stats.kernel_compiles
        sess.predict(trace, request)
        assert sess.stats.kernel_compiles == warm, (
            "a warm repeat sweep must not compile new kernels"
        )


def test_4096_mixed_shape_rows_bit_identical_to_per_row_eval():
    """Composition invariance at scale: 4096 rows of mixed profile
    lengths and geometries evaluated in ONE batched call return the
    same bits as evaluating every cell alone."""
    rng = np.random.default_rng(42)
    profiles = [
        profile_from_distances(np.concatenate([
            rng.integers(0, 1 << (8 + 2 * k), size=30 * (k + 1)),
            np.full(3, INF_RD),
        ]))
        for k in range(4)
    ]
    profiles.append(profile_from_distances(np.array([], dtype=np.int64)))
    targets = list(TABLE5) + [TPU_V5E]

    items = []
    levels = 0
    i = 0
    while levels < 4096:
        target = targets[i % len(targets)]
        items.append((target, _FakeArt(profiles[i % len(profiles)])))
        levels += len(target.levels)
        i += 1

    fused = batched_hit_rates(items)
    assert sum(len(r) for r in fused) >= 4096
    # spot-check a deterministic sample of cells one at a time; each
    # solo call must reproduce the fused bits exactly
    sample = rng.choice(len(items), size=64, replace=False)
    for ci in sample:
        (solo,) = batched_hit_rates([items[ci]])
        assert solo == fused[ci], (
            f"cell {ci} ({items[ci][0].name}) diverges when evaluated "
            "alone"
        )
