"""Session(sampled=R): SHARDS-sampled profiles through the full
prediction pipeline — accuracy within the declared error bound,
distinct store keys (exact / binned / sampled never collide), bound
round-trip through the disk store, and per-request rate overrides."""
import pytest

from repro.api import PredictionRequest, Session
from repro.api.stages import MimicProfileBuilder
from repro.hw.targets import resolve_target
from repro.validate.store import DEFAULT_BUILDER_FP, builder_fingerprint
from repro.workloads.polybench import make_atax

REQ = PredictionRequest(
    targets=("i7-5960X", "tpu-v5e"),
    core_counts=(1, 2),
    respect_core_limit=False,
)


@pytest.fixture(scope="module")
def workload():
    return make_atax(n=32)


def test_sampled_hit_rates_within_declared_bound(workload):
    exact = Session().predict(workload, REQ)
    sess = Session(sampled=0.5)
    sampled = sess.predict(workload, REQ)
    assert len(exact) == len(sampled)
    for pe, ps in zip(exact, sampled):
        assert (pe.target, pe.cores) == (ps.target, ps.cores)
        art = sess.artifacts(
            workload, pe.cores, strategy=ps.strategy,
            line_size=resolve_target(pe.target).levels[0].line_size,
        )
        bound = max(art.prd.error_bound or 0.0, art.crd.error_bound or 0.0)
        assert bound > 0.0
        for lvl, rate in pe.hit_rates.items():
            assert abs(rate - ps.hit_rates[lvl]) < bound, (pe.target, lvl)


def test_sampled_artifacts_flagged_and_bounded(workload):
    s = Session(sampled=0.5)
    art = s.artifacts(workload, 2)
    assert art.sampled == 0.5
    assert art.prd.error_bound is not None and art.prd.error_bound > 0
    assert art.crd.error_bound is not None and art.crd.error_bound > 0
    assert Session().artifacts(workload, 2).sampled is None


def test_sampled_rate_one_matches_exact(workload):
    """R == 1.0 reproduces the exact pipeline bit for bit (the sampled
    mode's correctness anchor), with a zero declared bound."""
    exact = Session().predict(workload, REQ)
    full = Session(sampled=1.0)
    res = full.predict(workload, REQ)
    for pe, pf in zip(exact, res):
        assert pe.hit_rates == pf.hit_rates
    assert full.artifacts(workload, 2).prd.error_bound == 0.0


def test_sampled_streaming_session(workload):
    """sampled + window_size: the constant-memory windowed sampled path
    produces the same profiles as the in-memory sampled pass."""
    mem = Session(sampled=0.5).predict(workload, REQ)
    win = Session(sampled=0.5, window_size=512).predict(workload, REQ)
    for pm, pw in zip(mem, win):
        assert pm.hit_rates == pw.hit_rates


def test_builder_fingerprints_distinct():
    assert (builder_fingerprint(MimicProfileBuilder(sampled=0.5))
            == DEFAULT_BUILDER_FP + "+sampled0.5")
    assert (builder_fingerprint(MimicProfileBuilder(sampled=0.25))
            == DEFAULT_BUILDER_FP + "+sampled0.25")
    # rate is part of the key: different rates never share cells
    assert (builder_fingerprint(MimicProfileBuilder(sampled=0.5))
            != builder_fingerprint(MimicProfileBuilder(sampled=0.25)))


def test_sampled_param_requires_default_builder():
    with pytest.raises(ValueError):
        Session(profile_builder=MimicProfileBuilder(), sampled=0.5)
    # a sampled builder passed explicitly is fine
    Session(profile_builder=MimicProfileBuilder(sampled=0.5), sampled=0.5)


def test_binned_and_sampled_mutually_exclusive():
    with pytest.raises(ValueError):
        MimicProfileBuilder(binned=True, sampled=0.5)
    with pytest.raises(ValueError):
        Session(binned=True, sampled=0.5)


def test_three_modes_coexist_in_store(tmp_path, workload):
    """Exact, binned, and sampled cells of ONE workload live under
    distinct keys in a shared store — no cross-mode serving."""
    Session(artifact_dir=tmp_path).predict(workload, REQ)
    binned = Session(artifact_dir=tmp_path, binned=True)
    binned.predict(workload, REQ)
    assert binned.stats.store_hits == 0
    sampled = Session(artifact_dir=tmp_path, sampled=0.5)
    sampled.predict(workload, REQ)
    assert sampled.stats.store_hits == 0
    assert sampled.stats.profile_builds > 0

    # warm reload: zero rebuilds, flag and error bound round-trip
    warm = Session(artifact_dir=tmp_path, sampled=0.5)
    res = warm.predict(workload, REQ)
    assert warm.stats.profile_builds == 0
    assert warm.stats.store_hits > 0
    art = warm.artifacts(workload, 2)
    assert art.sampled == 0.5
    assert art.prd.error_bound is not None and art.prd.error_bound > 0

    # served-from-disk results identical to freshly built ones
    fresh = Session(sampled=0.5).predict(workload, REQ)
    for pf, pd in zip(fresh, res):
        assert pf.hit_rates == pd.hit_rates

    # a different rate is a different key, even warm
    other = Session(artifact_dir=tmp_path, sampled=0.25)
    other.predict(workload, REQ)
    assert other.stats.store_hits == 0
    assert other.stats.profile_builds > 0


def test_per_request_sampled_rate_override(workload):
    """PredictionRequest.sampled_rate overrides the session mode cell
    by cell through a cached variant builder."""
    s = Session()
    req = PredictionRequest(
        targets=("i7-5960X",), core_counts=(1, 2), sampled_rate=0.5
    )
    s.predict(workload, req)
    art = s.artifacts(workload, 2, sampled=0.5)
    assert art.sampled == 0.5
    # the exact cell is untouched: separate in-memory key
    assert s.artifacts(workload, 2).sampled is None
    # override on a builder without with_sampled support fails loudly
    bad = Session()
    bad.builder = object()
    with pytest.raises(ValueError):
        bad._builder_for(0.5)


def test_request_sampled_rate_validation():
    with pytest.raises(ValueError):
        PredictionRequest(targets=("i7-5960X",), sampled_rate=0.0)
    with pytest.raises(ValueError):
        PredictionRequest(targets=("i7-5960X",), sampled_rate=1.5)
