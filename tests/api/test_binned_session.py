"""Session(binned=True): the fused device-binned profile mode through
the full prediction pipeline — accuracy vs the exact-profile oracle,
distinct store keys, and cross-process-style store reuse."""
import numpy as np
import pytest

from repro.api import PredictionRequest, Session
from repro.api.stages import MimicProfileBuilder
from repro.validate.store import DEFAULT_BUILDER_FP, builder_fingerprint
from repro.workloads.polybench import make_atax

REQ = PredictionRequest(
    targets=("i7-5960X", "tpu-v5e"),
    core_counts=(1, 2),
    respect_core_limit=False,
)


@pytest.fixture(scope="module")
def workload():
    return make_atax(n=32)


def test_binned_hit_rates_close_to_exact(workload):
    exact = Session().predict(workload, REQ)
    binned = Session(binned=True).predict(workload, REQ)
    assert len(exact) == len(binned)
    for pe, pb in zip(exact, binned):
        assert (pe.target, pe.cores) == (pb.target, pb.cores)
        for lvl, rate in pe.hit_rates.items():
            assert abs(rate - pb.hit_rates[lvl]) < 1e-3


def test_binned_artifacts_flagged(workload):
    s = Session(binned=True)
    art = s.artifacts(workload, 2)
    assert art.binned
    assert not Session().artifacts(workload, 2).binned


def test_binned_streaming_session(workload):
    """binned + window_size: the fused streaming path end to end."""
    exact = Session(window_size=512).predict(workload, REQ)
    binned = Session(window_size=512, binned=True).predict(workload, REQ)
    for pe, pb in zip(exact, binned):
        for lvl, rate in pe.hit_rates.items():
            assert abs(rate - pb.hit_rates[lvl]) < 1e-3


def test_builder_fingerprints_distinct():
    assert builder_fingerprint(MimicProfileBuilder()) == DEFAULT_BUILDER_FP
    assert (builder_fingerprint(MimicProfileBuilder(binned=True))
            == DEFAULT_BUILDER_FP + "+binned")


def test_binned_param_requires_default_builder():
    with pytest.raises(ValueError):
        Session(profile_builder=MimicProfileBuilder(), binned=True)
    # a binned builder passed explicitly is fine
    Session(profile_builder=MimicProfileBuilder(binned=True), binned=True)


def test_binned_and_exact_cells_coexist_in_store(tmp_path, workload):
    exact = Session(artifact_dir=tmp_path)
    exact.predict(workload, REQ)
    binned = Session(artifact_dir=tmp_path, binned=True)
    binned.predict(workload, REQ)
    # distinct keys: the binned session cannot be served exact cells
    assert binned.stats.store_hits == 0
    assert binned.stats.profile_builds > 0

    # warm reload in fresh sessions: zero rebuilds on both paths, and
    # the loaded binned cells keep their flag
    exact2 = Session(artifact_dir=tmp_path)
    exact2.predict(workload, REQ)
    assert exact2.stats.profile_builds == 0
    binned2 = Session(artifact_dir=tmp_path, binned=True)
    res = binned2.predict(workload, REQ)
    assert binned2.stats.profile_builds == 0
    assert binned2.stats.store_hits > 0
    art = binned2.artifacts(workload, 2)
    assert art.binned and art.prd.total > 0

    # served-from-disk binned results identical to freshly built ones
    fresh = Session(binned=True).predict(workload, REQ)
    for pf, pd in zip(fresh, res):
        assert pf.hit_rates == pd.hit_rates
