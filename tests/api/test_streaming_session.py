"""Streaming Session path + ISSUE-2 satellite bugfix regressions."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import (
    ExactLRU,
    MimicProfileBuilder,
    PredictionRequest,
    ProfileArtifacts,
    RooflineRuntimeModel,
    Session,
)
from repro.core.runtime_model import OpCounts
from repro.core.trace.interleave import interleave_traces
from repro.core.trace.types import LabeledTrace, trace_from_blocks
from repro.hw.targets import CPU_TARGETS, TPU_V5E, resolve_target

CPU_NAMES = tuple(CPU_TARGETS)
COUNTS = OpCounts(int_ops=3000, fp_ops=1500, div_ops=10, loads=3000,
                  stores=1500, total_bytes=4500 * 8)


def small_trace(iters=400, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


def mk(addrs):
    addrs = np.asarray(addrs, dtype=np.int64)
    return LabeledTrace(
        addrs, np.zeros(len(addrs), np.int32), np.zeros(len(addrs), bool)
    )


# --- streaming Session path -------------------------------------------------


def test_streaming_session_matches_in_memory_grid():
    """Session(window_size=...) must produce BIT-identical hit rates:
    the streaming profiles equal the in-memory ones exactly."""
    trace = small_trace()
    request = PredictionRequest(
        targets=CPU_NAMES, core_counts=(1, 2, 4), counts=COUNTS,
        respect_core_limit=False,
    )
    ref = Session().predict(trace, request)
    for ws in (128, 1 << 14):
        got = Session(window_size=ws).predict(trace, request)
        for cell in ref:
            other = got.one(target=cell.target, cores=cell.cores)
            assert other.hit_rates == cell.hit_rates  # exact, not approx
            assert other.t_pred_s == cell.t_pred_s


def test_streaming_artifacts_drop_shared_trace():
    trace = small_trace()
    session = Session(window_size=256)
    art = session.artifacts(trace, 4)
    assert art.window_size == 256
    assert art.shared is None          # never materialized
    assert len(art.privates) == 4
    assert session.stats.streaming_builds == 1
    # cores=1 keeps the (already in-memory) source trace
    assert session.artifacts(trace, 1).shared is trace


def test_request_window_size_overrides_session_default():
    trace = small_trace(iters=150)
    session = Session()  # in-memory default
    request = PredictionRequest(
        targets=(CPU_NAMES[0],), core_counts=(2,), window_size=200,
    )
    session.predict(trace, request)
    assert session.stats.streaming_builds == 1
    # window_size=0 forces the in-memory path on a streaming session
    streaming = Session(window_size=128)
    req0 = PredictionRequest(
        targets=(CPU_NAMES[0],), core_counts=(2,), window_size=0,
    )
    streaming.predict(trace, req0)
    assert streaming.stats.streaming_builds == 0
    # builder-level window_size is honored by the Session too
    sess_b = Session(profile_builder=MimicProfileBuilder(window_size=64))
    sess_b.artifacts(trace, 2)
    assert sess_b.stats.streaming_builds == 1


def test_streaming_uniform_strategy_still_exact():
    """uniform cannot stream the interleave; the Session falls back to
    materializing the shared trace but still streams the RD pass."""
    trace = small_trace(iters=200)
    ref = Session().hit_rates(trace, CPU_NAMES[0], 2, strategy="uniform")
    session = Session(window_size=128)
    got = session.hit_rates(trace, CPU_NAMES[0], 2, strategy="uniform")
    assert got == ref
    assert session.artifacts(
        trace, 2, strategy="uniform",
        line_size=resolve_target(CPU_NAMES[0]).levels[0].line_size,
    ).shared is not None


# --- ExactLRU all-cores aggregation (satellite bugfix) ----------------------


def heterogeneous_artifacts(cores=2):
    """Hand-built artifacts with ASYMMETRIC private traces: core 0
    streams (never reuses), core 1 hammers one line."""
    rng = np.random.default_rng(0)
    stream = mk(np.arange(4096) * 64)                # all misses
    hot = mk(np.zeros(4096, dtype=np.int64))         # all hits after 1st
    privates = [stream, hot]
    shared = interleave_traces(privates, "round_robin")
    prof = None  # ExactLRU never touches the profiles
    return ProfileArtifacts(
        trace_id="het", cores=cores, strategy="round_robin", seed=0,
        line_size=64, privates=privates, shared=shared, prd=prof, crd=prof,
    )


def test_exact_lru_aggregates_private_levels_across_cores():
    target = resolve_target(CPU_NAMES[0])
    art = heterogeneous_artifacts()
    rates = ExactLRU().hit_rates(target, art)
    # core 0 hits ~0% privately, core 1 hits ~100%: the aggregate L1
    # rate must sit near 50%, not at either core's extreme
    assert 0.4 < rates["L1"] < 0.6
    # regression: the old code returned core 0's (streaming) rate
    from repro.core.cachesim import simulate_hierarchy

    core0_only = simulate_hierarchy(
        art.privates[0].addresses, list(target.levels)[:2]
    )[0].cumulative_hit_rate
    assert rates["L1"] != pytest.approx(core0_only)


def test_exact_lru_symmetric_cores_unchanged():
    """For symmetric mimicked traces the aggregate equals core 0's rate
    — the fix must not move the existing ground-truth numbers."""
    trace = small_trace()
    target = resolve_target(CPU_NAMES[0])
    session = Session()
    art = session.artifacts(
        trace, 4, line_size=target.levels[0].line_size
    )
    rates = ExactLRU().hit_rates(target, art)
    from repro.core.cachesim import simulate_hierarchy

    shared_idx = 2  # L3
    res0 = simulate_hierarchy(
        art.privates[0].addresses, list(target.levels)[:shared_idx]
    )
    for r in res0:
        assert rates[r.name] == pytest.approx(r.cumulative_hit_rate)


def test_exact_lru_rejects_streaming_artifacts():
    trace = small_trace()
    art = Session(window_size=256).artifacts(trace, 2)
    with pytest.raises(ValueError, match="streaming"):
        ExactLRU().hit_rates(resolve_target(CPU_NAMES[0]), art)


def test_ground_truth_works_on_streaming_session():
    """ground_truth_hit_rates forces in-memory artifacts, so a
    streaming Session still serves exact-LRU validation."""
    trace = small_trace()
    target = resolve_target(CPU_NAMES[0])
    ref = Session().ground_truth_hit_rates(trace, target, 4)
    got = Session(window_size=256).ground_truth_hit_rates(trace, target, 4)
    assert got == pytest.approx(ref)


def test_streaming_uniform_goes_through_shared_trace_cache():
    """The uniform fallback must reuse the Session's cached interleave
    across line sizes instead of re-drawing it per target."""
    trace = small_trace(iters=150)
    session = Session(window_size=128)
    session.artifacts(trace, 2, strategy="uniform", line_size=64)
    session.artifacts(trace, 2, strategy="uniform", line_size=512)
    assert session.stats.interleave_builds == 1


# --- Roofline runtime model fixes (satellite bugfix) ------------------------


def test_roofline_uses_named_level_not_dict_order():
    model = RooflineRuntimeModel()
    counts = OpCounts(fp_ops=1e9, total_bytes=1e9)
    # VMEM deliberately NOT first in the dict; the old
    # next(iter(...)) picked 0.99 and underestimated t_mem
    rates = {"bogus": 0.99, "VMEM": 0.25}
    out = model.runtime(TPU_V5E, rates, counts, 1)
    ref = model.runtime(TPU_V5E, {"VMEM": 0.25}, counts, 1)
    assert out["t_mem_s"] == ref["t_mem_s"]
    miss_bytes = 0.75 * counts.total_bytes
    expected = miss_bytes / TPU_V5E.hbm_bandwidth + TPU_V5E.vmem_latency_s
    assert out["t_mem_s"] == pytest.approx(expected)


def test_roofline_no_latency_term_without_misses():
    model = RooflineRuntimeModel()
    counts = OpCounts(fp_ops=1e9, total_bytes=1e9)
    out = model.runtime(TPU_V5E, {"VMEM": 1.0}, counts, 1)
    assert out["t_mem_s"] == 0.0  # all-hit: no HBM traffic, no latency
    assert out["t_pred_s"] == pytest.approx(
        counts.fp_ops / TPU_V5E.peak_flops_bf16
    )
