"""The documented entrypoint end-to-end (ISSUE-4 acceptance): the
``python -m repro.service --selftest`` CLI passes, and a second service
process pointed at the same artifact_dir performs zero profile
rebuilds — profiles are served from the shared disk store."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]


def run_selftest(artifact_dir: Path) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service", "--selftest",
         "--artifact-dir", str(artifact_dir)],
        capture_output=True, text=True, timeout=600, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return json.loads(proc.stdout)


@pytest.mark.slow
def test_second_service_process_rebuilds_nothing(tmp_path):
    store = tmp_path / "artifacts"

    first = run_selftest(store)
    assert first["selftest"] == "ok"
    assert first["session"]["profile_builds"] > 0
    assert first["session"]["store_puts"] == first["session"]["profile_builds"]
    assert first["service"]["completed"] == first["requests"]

    second = run_selftest(store)
    assert second["selftest"] == "ok"
    # the acceptance property: a warm store means a fresh service
    # process never rebuilds a reuse profile or distance pass
    assert second["session"]["profile_builds"] == 0
    assert second["session"]["rd_builds"] == 0
    assert second["session"]["store_hits"] == first["session"]["store_puts"]
    assert second["service"]["completed"] == second["requests"]
    # coalescing really happened under concurrent clients
    assert second["service"]["deduped"] > 0
