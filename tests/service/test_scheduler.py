"""Microbatcher semantics (ISSUE-4 satellite): dedup fan-out, max-wait
partial flush, bounded-queue load shed."""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.api import AnalyticalSDCM, PredictionRequest, Session
from repro.core.trace.types import trace_from_blocks
from repro.service import (
    MicroBatcher,
    PendingRequest,
    PredictionService,
    ServiceConfig,
    ServiceOverloadedError,
    coalesce,
)


def small_trace(iters=200, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


def request(targets=("i7-5960X",), cores=(1, 2)):
    return PredictionRequest(
        targets=targets, core_counts=cores, respect_core_limit=False
    )


def pending(source, req, key):
    return PendingRequest(source, req, key, Future(), time.monotonic())


# --- pure coalescing logic ---------------------------------------------------


def test_coalesce_dedups_by_key_preserving_order():
    t = small_trace()
    r = request()
    items = [pending(t, r, "a"), pending(t, r, "b"), pending(t, r, "a"),
             pending(t, r, "a")]
    comps = coalesce(items)
    assert [c.key for c in comps] == ["a", "b"]
    assert len(comps[0].waiters) == 3
    assert len(comps[1].waiters) == 1


def test_kernel_compatibility_grouping_lives_in_the_batched_kernel():
    """The scheduler does NOT split batches by cache geometry — the
    batched kernel buckets rows by their own (A_MAX, padded-M) shape,
    so mixed geometries coexist in one predict_many call without
    recompiling each other's kernels."""
    from repro.api.batched import _row_shape_key
    from repro.hw.targets import resolve_target

    session = Session()
    art = session.artifacts(small_trace(), 1)
    i7 = resolve_target("i7-5960X")      # 16-way L3: bucket 16
    tpu = resolve_target("tpu-v5e")      # fully associative: min bucket
    key_cpu = _row_shape_key(art.prd, i7.levels[-1].effective_assoc,
                             i7.levels[-1].num_lines)
    key_tpu = _row_shape_key(art.prd, tpu.levels[0].effective_assoc,
                             tpu.levels[0].num_lines)
    assert key_cpu[0] != key_tpu[0]      # distinct jit buckets per row


# --- MicroBatcher ------------------------------------------------------------


def test_offer_returns_false_when_queue_full():
    mb = MicroBatcher(lambda batch: None, max_batch=4, max_wait_s=0.01,
                      queue_size=2)
    t, r = small_trace(), request()
    assert mb.offer(pending(t, r, 1))
    assert mb.offer(pending(t, r, 2))
    assert not mb.offer(pending(t, r, 3))  # full: caller sheds


def test_max_wait_flushes_partial_batch():
    """A lone request must not wait for max_batch company: the window
    closes and the partial batch flushes."""
    batches = []
    done = threading.Event()

    def executor(batch):
        batches.append(len(batch))
        done.set()

    mb = MicroBatcher(executor, max_batch=64, max_wait_s=0.05,
                      queue_size=16)
    mb.start()
    try:
        t0 = time.monotonic()
        assert mb.offer(pending(small_trace(), request(), "only"))
        assert done.wait(timeout=5.0), "partial batch never flushed"
        assert time.monotonic() - t0 < 4.0
        assert batches == [1]
    finally:
        mb.stop()


def test_batch_budget_flushes_before_window_closes():
    batches = []
    done = threading.Event()

    def executor(batch):
        batches.append(len(batch))
        if sum(batches) == 6:
            done.set()

    # window far larger than the test budget: only max_batch can flush
    mb = MicroBatcher(executor, max_batch=3, max_wait_s=30.0, queue_size=16)
    t, r = small_trace(), request()
    for i in range(6):
        assert mb.offer(pending(t, r, i))
    mb.start()
    try:
        assert done.wait(timeout=5.0)
        assert batches == [3, 3]
    finally:
        mb.stop()


# --- service-level dedup / shed ---------------------------------------------


class GatedSDCM(AnalyticalSDCM):
    """Blocks every grid evaluation until the test releases it."""

    def __init__(self):
        super().__init__(backend="numpy")
        self.entered = threading.Event()
        self.release = threading.Event()

    def hit_rates_grid(self, items):
        self.entered.set()
        assert self.release.wait(timeout=30.0)
        return super().hit_rates_grid(items)


def test_duplicate_requests_compute_once_and_fan_out():
    """K identical submissions in one batch: ONE computation, K futures
    all carrying the same (equal-bits) result."""
    trace, req = small_trace(), request()
    gate = GatedSDCM()
    service = PredictionService(
        Session(cache_model=gate),
        config=ServiceConfig(max_batch=32, max_wait_ms=50, queue_size=64),
    )
    with service:
        plug = service.submit(small_trace(50), request(cores=(1,)))
        assert gate.entered.wait(timeout=10.0)  # worker busy on the plug
        gate.entered.clear()
        futs = [service.submit(trace, req) for _ in range(5)]
        gate.release.set()
        responses = [f.result(timeout=30.0) for f in futs]
        plug.result(timeout=30.0)

    first = responses[0].result
    for resp in responses[1:]:
        for a, b in zip(first, resp.result):
            assert a.hit_rates == b.hit_rates
        assert resp.timing.shared
    assert service.stats.deduped == 4
    assert service.stats.submitted == 6
    assert service.stats.completed == 6
    # the 5 duplicates were one batch, one computation, one kernel call
    assert 5 in service.stats.recent_batch_sizes
    assert service.stats.max_batch_size == 5
    # plug (1 cell) + the deduped request (2 core counts) — never 5x
    assert service.session.stats.profile_builds == 3


def test_full_queue_sheds_with_documented_error():
    trace, req = small_trace(), request()
    gate = GatedSDCM()
    service = PredictionService(
        Session(cache_model=gate),
        config=ServiceConfig(max_batch=1, max_wait_ms=1, queue_size=2),
    )
    with service:
        plug = service.submit(trace, req, key="plug")
        assert gate.entered.wait(timeout=10.0)  # worker blocked mid-batch
        queued = [service.submit(trace, req, key=i) for i in range(2)]
        with pytest.raises(ServiceOverloadedError, match="queue is full"):
            service.submit(trace, req, key="overflow")
        assert service.stats.shed == 1
        gate.release.set()
        plug.result(timeout=30.0)
        for f in queued:
            f.result(timeout=30.0)
    assert service.stats.completed == 3


def test_submit_rejects_empty_grid_before_queueing():
    service = PredictionService(config=ServiceConfig(max_wait_ms=1))
    with service:
        with pytest.raises(ValueError, match="no grid cells"):
            # i7-5960X has 8 cores; respect_core_limit drops the cell
            service.submit(small_trace(), PredictionRequest(
                targets=("i7-5960X",), core_counts=(512,),
            ))
    assert service.stats.submitted == 0


def test_submit_after_stop_raises():
    service = PredictionService()
    service.start()
    service.stop()
    with pytest.raises(RuntimeError, match="not running"):
        service.submit(small_trace(), request())


def test_cancelled_future_does_not_kill_worker():
    """A caller cancelling its queued future must not wedge the
    service: the worker skips it and keeps serving later batches."""
    trace, req = small_trace(), request()
    gate = GatedSDCM()
    service = PredictionService(
        Session(cache_model=gate),
        config=ServiceConfig(max_batch=8, max_wait_ms=20, queue_size=64),
    )
    with service:
        plug = service.submit(trace, req, key="plug")
        assert gate.entered.wait(timeout=10.0)
        doomed = service.submit(trace, req, key="doomed")
        assert doomed.cancel()  # still queued: cancel succeeds
        gate.release.set()
        plug.result(timeout=30.0)
        # the worker survived: a fresh request still round-trips
        after = service.predict(trace, req, key="after", timeout=30.0)
        assert after.result.predictions
    assert service.stats.cancelled == 1
    assert service.stats.completed == 2


def test_offer_after_stop_raises_instead_of_stranding():
    mb = MicroBatcher(lambda batch: None, max_batch=4, max_wait_s=0.01,
                      queue_size=4)
    mb.start()
    mb.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        mb.offer(pending(small_trace(50), request(), "late"))


def test_stop_discards_strand_candidates_with_failed_futures():
    """Belt-and-braces path: anything left in the queue after the
    worker exits resolves with an error, never hangs its waiter."""
    discarded = []
    mb = MicroBatcher(lambda batch: None, max_batch=4, max_wait_s=0.01,
                      queue_size=4, on_discard=discarded.extend)
    item = pending(small_trace(50), request(), "stranded")
    assert mb.offer(item)
    # worker never started: stop() must still hand the item back
    mb._thread = threading.Thread(target=lambda: None)
    mb._thread.start()
    mb.stop()
    assert discarded == [item]

    service = PredictionService(config=ServiceConfig(max_wait_ms=1))
    service._discard([item])
    with pytest.raises(RuntimeError, match="stopped before"):
        item.future.result(timeout=1.0)
    assert service.stats.failed == 1
