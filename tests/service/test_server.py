"""HTTP front door: endpoints, error mapping, concurrent clients."""
from __future__ import annotations

import threading

import pytest

from repro.service import PredictionService, ServiceConfig
from repro.service.client import ServiceClient, ServiceError
from repro.service.server import PredictionServer


@pytest.fixture()
def served():
    service = PredictionService(
        config=ServiceConfig(max_batch=16, max_wait_ms=10, queue_size=64)
    )
    with service:
        server = PredictionServer(service, "127.0.0.1", 0)
        server.serve_background()
        client = ServiceClient(server.url)
        client.wait_ready()
        try:
            yield service, client
        finally:
            server.shutdown()
            server.server_close()


def test_healthz_and_stats(served):
    service, client = served
    assert client.healthz() == {"ok": True}
    stats = client.stats()
    assert {"service", "session"} <= set(stats)
    assert stats["service"]["submitted"] == 0


def test_predict_over_http(served):
    _service, client = served
    out = client.predict("atx", sizes="smoke", core_counts=[1, 2],
                         targets=["i7-5960X"])
    # legacy abbreviation resolves; response carries the canonical name
    assert out["workload"] == "polybench/atx"
    assert out["requested"] == "atx"
    assert len(out["predictions"]) == 2
    for cell in out["predictions"]:
        assert cell["target"] == "i7-5960X"
        assert 0.0 <= cell["hit_rates"]["L1"] <= 1.0
        assert cell["t_pred_s"] > 0
    assert out["timing"]["batch_size"] >= 1


def test_registry_names_and_aliases_coalesce(served):
    """The canonical name and its legacy alias resolve to ONE source
    object, one trace id, and bit-identical predictions."""
    service, client = served
    a = client.predict("polybench/atx", sizes="smoke", core_counts=[1, 2],
                       targets=["i7-5960X"])
    b = client.predict("atx", sizes="smoke", core_counts=[1, 2],
                       targets=["i7-5960X"])
    assert a["workload"] == b["workload"] == "polybench/atx"
    assert a["trace_id"] == b["trace_id"]
    assert a["predictions"] == b["predictions"]
    # second spelling was served from the same Session artifact set
    assert service.session.stats.trace_builds <= 1


def test_concurrent_clients_coalesce(served):
    service, client = served
    errors = []

    def go():
        try:
            out = client.predict("atx", sizes="smoke", core_counts=[1, 2])
            assert len(out["predictions"]) == 6  # 3 CPU targets x 2 cores
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=go) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    stats = client.stats()
    assert stats["service"]["completed"] == 8
    # equal specs share one workload object and one dedup key: at most
    # a few unique computations ever ran
    assert stats["service"]["coalesced"] <= stats["service"]["submitted"]
    assert stats["session"]["profile_builds"] <= 2


def test_model_workload_over_http(served):
    """ISSUE-7 payoff: a model/<arch>/<step> workload returns TPU VMEM
    hit rates through the same HTTP schema."""
    _service, client = served
    out = client.predict("model/llama3_8b/decode", sizes="smoke",
                         core_counts=[1], targets=["tpu-v5e"])
    assert out["workload"] == "model/llama3_8b/decode"
    assert len(out["predictions"]) == 1
    cell = out["predictions"][0]
    assert cell["target"] == "tpu-v5e"
    assert 0.0 <= cell["hit_rates"]["VMEM"] <= 1.0
    assert cell["t_pred_s"] > 0


def test_error_mapping(served):
    _service, client = served
    with pytest.raises(ServiceError, match="unknown workload") as ei:
        client.predict("nope")
    assert ei.value.status == 400
    with pytest.raises(ServiceError, match="unknown size preset") as ei:
        client.predict("atx", sizes="enormous")
    assert ei.value.status == 400
    with pytest.raises(ServiceError) as ei:
        client._call("/nowhere", {})
    assert ei.value.status == 404
