"""Service results must be bit-identical to sequential Session.predict
(ISSUE-4 acceptance) — coalescing, dedup, and batch composition must
never change a single bit of any prediction."""
from __future__ import annotations

import random
import threading

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AnalyticalSDCM, PredictionRequest, Session
from repro.api.batched import batched_hit_rates
from repro.core.trace.types import trace_from_blocks
from repro.service import PredictionService, ServiceConfig

CPU = ("i7-5960X", "Xeon E5-2699 v4", "EPYC 7702P")


def make_trace(iters, stride, seed):
    rng = np.random.default_rng(seed)
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i,
                      B0 + stride * int(rng.integers(0, 64)), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


REQUESTS = [
    PredictionRequest(targets=CPU, core_counts=(1, 2, 4),
                      respect_core_limit=False),
    PredictionRequest(targets=("i7-5960X",), core_counts=(1, 8),
                      strategies=("round_robin", "chunked"),
                      respect_core_limit=False),
    PredictionRequest(targets=("tpu-v5e", "EPYC 7702P"), core_counts=(2,),
                      respect_core_limit=False),
    PredictionRequest(targets=CPU[:1], core_counts=(1, 4),
                      window_size=1 << 10, respect_core_limit=False),
]


def assert_bit_identical(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert (x.target, x.cores, x.strategy, x.mode) == \
               (y.target, y.cores, y.strategy, y.mode)
        assert x.hit_rates == y.hit_rates          # exact float equality
        assert x.t_pred_s == y.t_pred_s


def test_concurrent_service_matches_sequential_predict_exactly():
    traces = [make_trace(150, 8, 0), make_trace(220, 16, 1)]
    pairs = [(t, r) for t in traces for r in REQUESTS]

    sequential = Session(cache_model=AnalyticalSDCM(backend="batched"))
    expected = {i: sequential.predict(t, r)
                for i, (t, r) in enumerate(pairs)}

    service = PredictionService(
        config=ServiceConfig(max_batch=16, max_wait_ms=25, queue_size=256)
    )
    jobs = [(i, t, r) for i, (t, r) in enumerate(pairs)] * 3
    random.Random(7).shuffle(jobs)
    results: dict[int, list] = {}
    lock = threading.Lock()

    def client(chunk):
        for i, t, r in chunk:
            resp = service.predict(t, r, timeout=120.0)
            with lock:
                results.setdefault(i, []).append(resp.result)

    with service:
        step = max(1, len(jobs) // 8)
        chunks = [jobs[k:k + step] for k in range(0, len(jobs), step)]
        threads = [threading.Thread(target=client, args=(c,))
                   for c in chunks]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert sum(len(v) for v in results.values()) == len(jobs)
    for i, copies in results.items():
        for got in copies:
            assert_bit_identical(expected[i], got)
    # the scheduler actually coalesced (not a degenerate 1-per-batch run)
    assert service.stats.batches < service.stats.submitted


_POOL: list | None = None


def _pool() -> list:
    """Fixed (target, artifacts) cells the property test composes —
    built once so hypothesis examples don't recompile trace scans."""
    global _POOL
    if _POOL is None:
        from repro.hw.targets import resolve_target

        session = Session()
        traces = [make_trace(150, 8, 0), make_trace(220, 16, 1),
                  make_trace(90, 24, 2)]
        arts = [session.artifacts(t, c) for t in traces for c in (1, 2)]
        targets = [resolve_target(n) for n in CPU + ("tpu-v5e",)]
        _POOL = [(tg, a) for a in arts for tg in targets]
    return _POOL


@settings(max_examples=25, deadline=None)
@given(idx=st.lists(st.integers(min_value=0, max_value=63), min_size=1,
                    max_size=8))
def test_batched_rows_are_composition_invariant(idx):
    """Property behind the service guarantee: a (target, artifacts)
    cell evaluates to identical bits alone and inside any batch."""
    pool = _pool()
    items = [pool[i % len(pool)] for i in idx]
    together = batched_hit_rates(items)
    alone = [batched_hit_rates([item])[0] for item in items]
    assert together == alone
