"""The /explore lane: bounded worker pool + service + HTTP endpoint.

The contract under test: explore jobs run on their OWN small worker
lane with load-shedding backpressure, and a long-running sweep can
never starve /predict microbatches.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import pytest

from repro.api import PredictionRequest
from repro.service import PredictionService, ServiceConfig
from repro.service.client import ServiceClient, ServiceError
from repro.service.scheduler import BoundedWorkerPool
from repro.service.server import PredictionServer

SPACE = {"sets": [512, 4096], "ways": [4, 8], "cores": [1, 2]}


# --- BoundedWorkerPool -------------------------------------------------------


def test_pool_runs_jobs_and_counts():
    pool = BoundedWorkerPool(max_workers=1, max_pending=4)
    pool.start()
    try:
        futures = [pool.try_submit(lambda i=i: i * i) for i in range(3)]
        assert all(f is not None for f in futures)
        assert [f.result(5) for f in futures] == [0, 1, 4]
        stats = pool.stats_dict()
        assert stats["submitted"] == 3
        assert stats["completed"] == 3
        assert stats["active"] == 0
    finally:
        pool.stop()


def test_pool_sheds_when_pending_full():
    gate = threading.Event()
    pool = BoundedWorkerPool(max_workers=1, max_pending=1)
    pool.start()
    try:
        running = pool.try_submit(gate.wait)      # occupies the worker
        queued = None
        deadline = time.monotonic() + 5
        while queued is None and time.monotonic() < deadline:
            # the running job may still be in the queue; keep trying
            # until exactly one job is pending and the next one sheds
            queued = pool.try_submit(gate.wait)
            if queued is None:
                time.sleep(0.01)
        assert queued is not None
        shed = None
        while shed is None and time.monotonic() < deadline:
            probe = pool.try_submit(lambda: None)
            if probe is None:
                shed = True
                break
            time.sleep(0.01)
        assert shed, "pool never shed with a full pending lane"
        assert pool.stats_dict()["shed"] >= 1
        gate.set()
        assert running.result(5) is not None or True
        assert queued.result(5) is not None or True
    finally:
        gate.set()
        pool.stop()


def test_pool_forwards_exceptions_without_dying():
    pool = BoundedWorkerPool(max_workers=1, max_pending=4)
    pool.start()
    try:
        bad = pool.try_submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            bad.result(5)
        ok = pool.try_submit(lambda: "alive")
        assert ok.result(5) == "alive"
        stats = pool.stats_dict()
        assert stats["failed"] == 1 and stats["completed"] == 1
    finally:
        pool.stop()


def test_pool_stop_drains_and_rejects_late_submits():
    pool = BoundedWorkerPool(max_workers=1, max_pending=4)
    pool.start()
    f = pool.try_submit(lambda: 42)
    pool.stop()
    assert f.result(5) == 42
    with pytest.raises(RuntimeError, match="stopped"):
        pool.try_submit(lambda: None)


def test_pool_stop_before_start_fails_pending_futures():
    pool = BoundedWorkerPool(max_workers=1, max_pending=4)
    f = pool.try_submit(lambda: 1)
    pool.stop()
    with pytest.raises(RuntimeError, match="stopped before"):
        f.result(1)


def test_pool_cancel_only_wins_while_pending():
    gate = threading.Event()
    pool = BoundedWorkerPool(max_workers=1, max_pending=2)
    pool.start()
    try:
        blocker = pool.try_submit(gate.wait)
        victim = pool.try_submit(lambda: "ran")
        assert victim.cancel()
        gate.set()
        assert blocker.result(5) is not None or True
        deadline = time.monotonic() + 5
        while pool.stats_dict()["cancelled"] < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
    finally:
        gate.set()
        pool.stop()


# --- service integration -----------------------------------------------------


@pytest.fixture()
def service(tmp_path):
    svc = PredictionService(
        config=ServiceConfig(max_batch=16, max_wait_ms=5, queue_size=64,
                             explore_workers=1, explore_pending=1,
                             explore_budget_cap=64),
        artifact_dir=str(tmp_path),
    )
    with svc:
        yield svc


def resolve(name="polybench/atx", sizes="smoke"):
    from repro.workloads import registry

    return registry.resolve(name, sizes)


def test_submit_explore_resolves_with_result(service):
    from repro.explore import SearchSpace

    workload = resolve()
    fut = service.submit_explore(
        workload, SearchSpace.from_json(SPACE), agent="random",
        budget=8, workload="polybench/atx",
    )
    assert isinstance(fut, Future)
    res = fut.result(120)
    assert res["best"]["config"]["size_bytes"] > 0
    assert res["trajectory"]["evaluations"] <= 8
    snap = service.snapshot()
    assert snap["explore"]["completed"] == 1
    # the predict Session was never touched by the explore job
    assert service.session.stats.profile_builds == 0


def test_submit_explore_validates_before_queueing(service):
    from repro.explore import SearchSpace

    space = SearchSpace.from_json(SPACE)
    workload = resolve()
    with pytest.raises(ValueError, match="budget"):
        service.submit_explore(workload, space, budget=65)
    with pytest.raises(ValueError, match="unknown agent"):
        service.submit_explore(workload, space, agent="anneal", budget=4)
    assert service.snapshot()["explore"]["submitted"] == 0


def test_explore_does_not_starve_predict(service):
    """While a sweep occupies the explore lane, /predict latency stays
    bounded by its own microbatch window."""
    from repro.explore import SearchSpace

    workload = resolve()
    fut = service.submit_explore(
        workload, SearchSpace.from_json(SPACE), agent="random",
        budget=16, workload="polybench/atx",
    )
    request = PredictionRequest(targets=("i7-5960X",), core_counts=(1,))
    t0 = time.monotonic()
    resp = service.predict(workload, request, timeout=60)
    predict_s = time.monotonic() - t0
    assert resp.result is not None
    fut.result(120)
    # the predict path went through its own worker while the explore
    # job held the explore worker; it must not have waited for it
    assert predict_s < 60


# --- HTTP --------------------------------------------------------------------


@pytest.fixture()
def served(tmp_path):
    svc = PredictionService(
        config=ServiceConfig(max_batch=16, max_wait_ms=5, queue_size=64,
                             explore_workers=1, explore_pending=1,
                             explore_budget_cap=64),
        artifact_dir=str(tmp_path),
    )
    with svc:
        server = PredictionServer(svc, "127.0.0.1", 0)
        server.serve_background()
        client = ServiceClient(server.url, timeout=120)
        client.wait_ready()
        try:
            yield svc, client
        finally:
            server.shutdown()
            server.server_close()


def test_explore_over_http(served):
    svc, client = served
    out = client.explore("atx", sizes="smoke", space=SPACE,
                         agent="random", budget=8)
    assert out["workload"] == "polybench/atx"
    assert out["cached"] is False
    assert out["best"]["score"] > 0
    assert out["space"]["sets"] == SPACE["sets"]
    # warm: the same search comes back from the shared store
    again = client.explore("atx", sizes="smoke", space=SPACE,
                           agent="random", budget=8)
    assert again["cached"] is True
    assert again["best"] == out["best"]
    stats = client.stats()
    assert stats["explore"]["completed"] == 2


def test_explore_http_error_mapping(served):
    _svc, client = served
    with pytest.raises(ServiceError) as err:
        client.explore("atx", sizes="smoke",
                       space={"sets": [512], "bogus_axis": [1]})
    assert err.value.status == 400
    with pytest.raises(ServiceError) as err:
        client.explore("no/such/workload", space=SPACE)
    assert err.value.status == 400
    with pytest.raises(ServiceError) as err:
        client.explore("atx", sizes="smoke", space=SPACE, budget=10_000)
    assert err.value.status == 400
